"""Batched serving with SEDAR dual-replica detection on the decode path.

    PYTHONPATH=src python examples/serve_batched.py [--arch recurrentgemma-2b]

Generates greedily from a batch of prompts; with --dual each decode step is
executed twice and logits fingerprints compared before the token is emitted
(validate-before-send). With --inject a bit-flip lands on replica 1 mid-
generation: the server detects it, retries the step and the output stream is
identical to the clean run.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, TrainConfig, get_config, reduce_for_smoke
from repro.core.injection import InjectionSpec
from repro.runtime.serve import SedarServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--dual", action="store_true", default=True)
    ap.add_argument("--inject", action="store_true")
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    rc = RunConfig(model=cfg, train=TrainConfig())
    spec = None
    if args.inject:
        # exponent-bit flip: a mantissa flip of a 0.0 bias would be a
        # denormal -> a true LE (no logits change, nothing to detect)
        spec = InjectionSpec(leaf_idx=3, flat_idx=9, bit=30,
                             step=args.prompt_len + 4, replica=1,
                             target="params")
    srv = SedarServer(rc, dual=args.dual, inj_spec=spec)
    params = srv.model.init(jax.random.PRNGKey(0))
    prompts = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, min(cfg.vocab_size, 200),
                                         (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.frontend:
        prompts["frontend_embeds"] = 0.1 * jnp.ones(
            (args.batch, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)

    toks, rep = srv.generate(params, prompts, steps=args.steps)
    print(f"arch={args.arch} emitted={rep.tokens_emitted} tokens "
          f"in {rep.wall_s:.2f}s (dual={args.dual})")
    if rep.detections:
        print(f"SDC detected at positions {[e.step for e in rep.detections]}; "
              f"{rep.retries} step(s) recomputed — output stream clean.")
    print("first sequence:", toks[0].tolist())


if __name__ == "__main__":
    main()
