"""Quickstart: SEDAR-protected training in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-0.5b] [--steps 8]

Trains a reduced config of any assigned architecture under L3 protection
(single validated checkpoint) and prints the run report.
"""
import argparse
import shutil

from repro.configs import (RunConfig, SedarConfig, TrainConfig, get_config,
                           reduce_for_smoke)
from repro.runtime.train import SedarTrainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--workdir", default="/tmp/sedar_quickstart")
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    run = RunConfig(
        model=cfg,
        train=TrainConfig(global_batch=4, seq_len=16, steps=args.steps,
                          warmup_steps=2, lr=1e-3),
        sedar=SedarConfig(level=3, replication="sequential",
                          checkpoint_interval=4, param_validate_interval=4),
    )
    shutil.rmtree(args.workdir, ignore_errors=True)
    trainer = SedarTrainer(run, args.workdir)
    _, report = trainer.run(args.steps)
    print(report.summary())
    print(f"losses: {[round(l, 4) for l in report.losses]}")
    print(f"validated checkpoints at: {report.checkpoints}")


if __name__ == "__main__":
    main()
