"""Protection-strategy study: the paper's temporal model as a planning tool.

    PYTHONPATH=src python examples/temporal_study.py --tprog 10 --mtbe 6

Given your job length and the system MTBE, prints the AET of every SEDAR
strategy, the advisor's pick, the optimal checkpoint interval (Daly), and
the Sec.-4.4 dynamic-protection schedule.
"""
import argparse

from repro.core import temporal_model as tm
from repro.core.policy import advise


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tprog", type=float, default=10.0, help="job hours")
    ap.add_argument("--mtbe", type=float, default=6.0, help="system MTBE h")
    ap.add_argument("--fd", type=float, default=0.005)
    ap.add_argument("--tcs", type=float, default=12.0, help="sys ckpt s")
    ap.add_argument("--tca", type=float, default=8.0, help="app ckpt s")
    args = ap.parse_args()

    p = tm.SedarParams(T_prog=args.tprog, T_comp=5 / 3600,
                       T_rest=args.tcs / 3600, f_d=args.fd,
                       t_cs=args.tcs / 3600, t_ca=args.tca / 3600,
                       T_compA=5 / 3600, t_i=1.0)
    print(f"job={args.tprog}h MTBE={args.mtbe}h "
          f"P(fault)={tm.fault_probability(args.tprog, args.mtbe):.1%}\n")
    print(f"{'strategy':14s} {'AET (h)':>9s} {'overhead vs no-fault':>22s}")
    for s in ("baseline", "detection", "multi_ckpt", "single_ckpt"):
        aet = tm.aet_strategy(p, s, args.mtbe)
        print(f"{s:14s} {aet:9.2f} {aet / args.tprog - 1:21.1%}")

    a = advise(p, args.mtbe)
    print(f"\nadvisor: use SEDAR L{a.level} ({a.strategy}) with "
          f"t_i={a.t_i:.2f}h")
    print(f"dynamic protection (Sec. 4.4): don't checkpoint before "
          f"{a.start_checkpointing_at:.1%} progress; keep >=2 rollback "
          f"candidates after {a.keep_two_checkpoints_at:.1%}")
    if a.notes:
        print(f"notes: {a.notes}")


if __name__ == "__main__":
    main()
