"""End-to-end driver: train a language model under SEDAR protection, inject a
real bit-flip mid-run, watch detection + automatic recovery, and verify the
final state is bit-identical to a fault-free run.

    PYTHONPATH=src python examples/train_with_recovery.py --profile ci
    PYTHONPATH=src python examples/train_with_recovery.py --profile paper

Profiles:
    ci     — ~0.5M params, 24 steps (seconds on CPU; used by the harness)
    paper  — ~100M params, 300 steps (the deliverable-scale run; hours on
             this CPU container, minutes on real accelerators)
"""
import argparse
import dataclasses
import shutil
import time

import numpy as np

from repro.configs import (ModelConfig, RunConfig, SedarConfig, TrainConfig,
                           get_config, reduce_for_smoke)
from repro.core.injection import InjectionSpec
from repro.runtime.train import SedarTrainer

PROFILES = {
    "ci": dict(
        model=reduce_for_smoke(get_config("paper-testapp")),
        train=TrainConfig(global_batch=4, seq_len=16, steps=24,
                          warmup_steps=4, lr=1e-3),
        inject_step=9, ckpt=6, validate=6,
    ),
    "paper": dict(
        model=ModelConfig(name="sedar-100m", family="dense", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          head_dim=64, d_ff=3072, vocab_size=32_000,
                          dtype="float32", param_dtype="float32",
                          remat="none"),
        train=TrainConfig(global_batch=8, seq_len=256, steps=300,
                          warmup_steps=30, lr=3e-4),
        inject_step=120, ckpt=25, validate=25,
    ),
}


def run(level: int, profile: dict, workdir: str, inject: bool):
    shutil.rmtree(workdir, ignore_errors=True)
    rc = RunConfig(
        model=profile["model"],
        train=profile["train"],
        # 3-tier checkpoint hierarchy (DESIGN.md §12): an on-device snapshot
        # ring every step (instant rollback, zero disk reads), a host-RAM
        # ring, and the durable async disk store with delta checkpoints —
        # the restore planner picks the cheapest tier holding a pre-fault
        # version
        sedar=SedarConfig(level=level, replication="sequential",
                          checkpoint_interval=profile["ckpt"],
                          param_validate_interval=profile["validate"],
                          ckpt_tiers="device,host,disk",
                          device_ring_slots=4, host_ring_slots=4,
                          ckpt_delta=True))
    spec = None
    if inject:
        spec = InjectionSpec(leaf_idx=3, flat_idx=17, bit=21,
                             step=profile["inject_step"], replica=1,
                             target="grads")
    tr = SedarTrainer(rc, workdir, inj_spec=spec)
    t0 = time.time()
    _, rep = tr.run(profile["train"].steps)
    print(f"  [{('faulty' if inject else 'clean')}] {rep.summary()}")
    for e in rep.detections:
        print(f"    detection: step={e.step} boundary={e.boundary} "
              f"effect={e.effect}")
    for r in rep.recoveries:
        tier = f" from tier {r['tier']!r}" if r.get("tier") else ""
        print(f"    recovery:  {r['kind']} -> ckpt@{r['step']}{tier} "
              f"(rollback #{r['rollbacks']})")
    if inject and rep.restored_from:
        print(f"    planner: restore served by tier(s) "
              f"{rep.restored_from} — ring hits need zero disk reads")
    return rep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=list(PROFILES), default="ci")
    ap.add_argument("--level", type=int, default=3, choices=(1, 2, 3))
    args = ap.parse_args()
    profile = PROFILES[args.profile]
    n_params = profile["model"].param_count()
    print(f"model: {profile['model'].name} ({n_params/1e6:.1f}M params), "
          f"SEDAR L{args.level}, {profile['train'].steps} steps")

    print("fault-free reference run:")
    clean = run(args.level, profile, f"/tmp/sedar_ex_clean_{args.profile}",
                inject=False)
    print("run with injected bit-flip:")
    faulty = run(args.level, profile, f"/tmp/sedar_ex_fault_{args.profile}",
                 inject=True)

    same = np.array_equal(clean.final_state_fp[:, :2],
                          faulty.final_state_fp[:, :2])
    print(f"\nfinal-state fingerprints identical to clean run: {same}")
    if args.level >= 2:
        assert same, "recovery must reproduce the fault-free trajectory"
        print("=> SEDAR detected the silent corruption and recovered "
              "bit-exactly. (paper Secs. 3.2/3.3)")


if __name__ == "__main__":
    main()
