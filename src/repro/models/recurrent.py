"""Griffin-style recurrent block (RG-LRU) for recurrentgemma [arXiv:2402.19427].

Block: x -> (W_gelu branch) * (conv1d -> RG-LRU branch) -> W_out.

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses jax.lax.associative_scan (log-depth, fully counted by
HLO cost analysis — no scan-body undercount); decode is the O(1) recurrence.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init

RG_LRU_C = 8.0


def init_recurrent_block(key, cfg, layers: Optional[int] = None):
    D, R, W = cfg.d_model, cfg.d_rnn, cfg.conv_width
    L = (layers,) if layers else ()
    lax_pref = ("layers",) if layers else ()
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    p = {
        "w_gelu": normal_init(ks[0], L + (D, R), pdt, 1.0 / math.sqrt(D)),
        "w_in":   normal_init(ks[1], L + (D, R), pdt, 1.0 / math.sqrt(D)),
        "w_out":  normal_init(ks[2], L + (R, D), pdt, 1.0 / math.sqrt(R)),
        "conv_w": normal_init(ks[3], L + (W, R), pdt, 1.0 / math.sqrt(W)),
        "conv_b": jnp.zeros(L + (R,), pdt),
        "wa":     normal_init(ks[4], L + (R, R), pdt, 1.0 / math.sqrt(R)),
        "ba":     jnp.zeros(L + (R,), pdt),
        "wx":     normal_init(ks[5], L + (R, R), pdt, 1.0 / math.sqrt(R)),
        "bx":     jnp.zeros(L + (R,), pdt),
        # Lambda init so that a^c in [0.9, 0.999] (paper init)
        "lam":    normal_init(ks[6], L + (R,), pdt, 0.0) + 0.7,
    }
    ax = {
        "w_gelu": lax_pref + ("embed", "rnn"),
        "w_in":   lax_pref + ("embed", "rnn"),
        "w_out":  lax_pref + ("rnn", "embed"),
        "conv_w": lax_pref + (None, "rnn"),
        "conv_b": lax_pref + ("rnn",),
        "wa":     lax_pref + ("embed", "rnn"),
        "ba":     lax_pref + ("rnn",),
        "wx":     lax_pref + ("embed", "rnn"),
        "bx":     lax_pref + ("rnn",),
        "lam":    lax_pref + ("rnn",),
    }
    return p, ax


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,R); w: (W,R); state: (B,W-1,R) or None.

    Returns (y, new_state). With state, the conv sees [state, x]."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, R)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):  # W is tiny (4): unrolled taps
        y = y + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = (y + b.astype(jnp.float32)).astype(x.dtype)
    new_state = xp[:, -(W - 1):, :] if W > 1 else None
    return y, new_state


def _rg_lru_gates(p, u):
    """u: (B,S,R) post-conv branch -> (a, beta_x) in f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", uf, p["wa"].astype(jnp.float32))
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", uf, p["wx"].astype(jnp.float32))
                       + p["bx"].astype(jnp.float32))
    log_a = -RG_LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def rg_lru_scan(p, u, h0=None):
    """Full-sequence RG-LRU via associative scan. u: (B,S,R) -> (y, h_last)."""
    a, b = _rg_lru_gates(p, u)
    if h0 is not None:
        # fold the carry state into the first step: b_0 += a_0 * h0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1, :]


def rg_lru_step(p, u_t, h):
    """Single decode step. u_t: (B,R); h: (B,R) f32 -> (y_t, h_new)."""
    a, b = _rg_lru_gates(p, u_t[:, None, :])
    h_new = a[:, 0, :] * h + b[:, 0, :]
    return h_new.astype(u_t.dtype), h_new


def recurrent_block(cfg, p, x, *, conv_state=None, h_state=None, decode=False):
    """Griffin recurrent temporal-mixing block.

    Train/prefill: x (B,S,D) -> (y, (conv_state, h_last)).
    Decode: x (B,1,D), states given -> (y, new states)."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gelu"].astype(dt))
                       .astype(jnp.float32)).astype(dt)
    u = jnp.einsum("bsd,dr->bsr", x, p["w_in"].astype(dt))
    u, conv_state_new = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    if decode:
        y_t, h_new = rg_lru_step(p, u[:, 0, :], h_state)
        y = y_t[:, None, :]
    else:
        y, h_new = rg_lru_scan(p, u, h_state)
    out = jnp.einsum("bsr,rd->bsd", gate * y, p["w_out"].astype(dt))
    return out, (conv_state_new, h_new)


def init_recurrent_state(cfg, batch: int, dtype=jnp.float32):
    """Decode state for one recurrent layer: (conv_state, h)."""
    return (jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype),
            jnp.zeros((batch, cfg.d_rnn), jnp.float32))
