"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory, sequential) — attention-free, O(1)-state decode.

mLSTM training uses an exact *chunkwise* form (TFLA-style): intra-chunk
quadratic attention-like compute + inter-chunk recurrent (C, n, m) state,
stabilized in log space. This keeps prefill_32k sub-quadratic
(O(S * chunk + S * d^2)) instead of O(S^2).

    true state:  C_t = f_t C_{t-1} + i_t k_t v_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    stabilized:  C = Cbar * exp(m); per chunk, with lf = logsigmoid(f_raw),
                 cum_j = inclusive-cumsum(lf), M = max(m_prev, max_j(i_j - cum_j)):
                 w_j   = exp(i_j - cum_j - M)                (intra weights)
                 Cbar' = exp(m_prev - M) Cbar + sum_j w_j k_j v_j^T
                 m'    = cum_C + M
                 h_t   = num_t / max(|q_t . n_t|, exp(-m_loc_t)), m_loc_t = cum_t + M

The quadratic reference (ref_mlstm_quadratic) and the sequential reference
(ref_mlstm_sequential) are used to validate the chunkwise form in tests.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rms_norm
from repro.models.recurrent import _causal_conv


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg, layers: Optional[int] = None):
    D, H, hd, W = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.conv_width
    assert H * hd == D, ("mLSTM inner dim must equal d_model", H, hd, D)
    L = (layers,) if layers else ()
    lax_pref = ("layers",) if layers else ()
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    s = 1.0 / math.sqrt(D)
    p = {
        "w_up":   normal_init(ks[0], L + (D, 2 * D), pdt, s),
        "conv_w": normal_init(ks[1], L + (W, D), pdt, 1.0 / math.sqrt(W)),
        "conv_b": jnp.zeros(L + (D,), pdt),
        "wq":     normal_init(ks[2], L + (D, H, hd), pdt, s),
        "wk":     normal_init(ks[3], L + (D, H, hd), pdt, s),
        "wv":     normal_init(ks[4], L + (D, H, hd), pdt, s),
        "wi":     normal_init(ks[5], L + (D, H), pdt, s),
        "bi":     jnp.zeros(L + (H,), pdt),
        "wf":     normal_init(ks[6], L + (D, H), pdt, s),
        "bf":     jnp.full(L + (H,), 3.0, pdt),   # forget-gate bias init: remember
        "gn":     jnp.zeros(L + (D,), pdt),
        "w_down": normal_init(ks[7], L + (D, D), pdt, s),
    }
    ax = {
        "w_up":   lax_pref + ("embed", "inner"),
        "conv_w": lax_pref + (None, "inner"),
        "conv_b": lax_pref + ("inner",),
        "wq":     lax_pref + ("embed", "heads", "head_dim"),
        "wk":     lax_pref + ("embed", "heads", "head_dim"),
        "wv":     lax_pref + ("embed", "heads", "head_dim"),
        "wi":     lax_pref + ("embed", "heads"),
        "bi":     lax_pref + ("heads",),
        "wf":     lax_pref + ("embed", "heads"),
        "bf":     lax_pref + ("heads",),
        "gn":     lax_pref + ("inner",),
        "w_down": lax_pref + ("inner", "embed"),
    }
    return p, ax


def init_slstm_block(key, cfg, layers: Optional[int] = None):
    D, H, hd, W = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.conv_width
    L = (layers,) if layers else ()
    lax_pref = ("layers",) if layers else ()
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(D)
    sr = 1.0 / math.sqrt(hd)
    F = int(cfg.proj_factor * D)
    p = {
        "conv_w": normal_init(ks[0], L + (W, D), pdt, 1.0 / math.sqrt(W)),
        "conv_b": jnp.zeros(L + (D,), pdt),
        "wz": normal_init(ks[1], L + (D, D), pdt, s),
        "wi": normal_init(ks[2], L + (D, D), pdt, s),
        "wf": normal_init(ks[3], L + (D, D), pdt, s),
        "wo": normal_init(ks[4], L + (D, D), pdt, s),
        "rz": normal_init(ks[5], L + (H, hd, hd), pdt, sr),
        "ri": normal_init(ks[6], L + (H, hd, hd), pdt, sr),
        "rf": normal_init(ks[7], L + (H, hd, hd), pdt, sr),
        "ro": normal_init(ks[8], L + (H, hd, hd), pdt, sr),
        "bz": jnp.zeros(L + (D,), pdt),
        "bi": jnp.zeros(L + (D,), pdt),
        "bf": jnp.full(L + (D,), 3.0, pdt),
        "bo": jnp.zeros(L + (D,), pdt),
        "gn": jnp.zeros(L + (D,), pdt),
        # gated FFN
        "w_gate": normal_init(ks[9], L + (D, F), pdt, s),
        "w_upf":  normal_init(ks[10], L + (D, F), pdt, s),
        "w_downf": normal_init(ks[11], L + (F, D), pdt, 1.0 / math.sqrt(F)),
    }
    ax = {
        "conv_w": lax_pref + (None, "inner"),
        "conv_b": lax_pref + ("inner",),
        "wz": lax_pref + ("embed", "inner"),
        "wi": lax_pref + ("embed", "inner"),
        "wf": lax_pref + ("embed", "inner"),
        "wo": lax_pref + ("embed", "inner"),
        "rz": lax_pref + ("heads", "head_dim", None),
        "ri": lax_pref + ("heads", "head_dim", None),
        "rf": lax_pref + ("heads", "head_dim", None),
        "ro": lax_pref + ("heads", "head_dim", None),
        "bz": lax_pref + ("inner",),
        "bi": lax_pref + ("inner",),
        "bf": lax_pref + ("inner",),
        "bo": lax_pref + ("inner",),
        "gn": lax_pref + ("inner",),
        "w_gate": lax_pref + ("embed", "mlp"),
        "w_upf":  lax_pref + ("embed", "mlp"),
        "w_downf": lax_pref + ("mlp", "embed"),
    }
    return p, ax


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise (training / prefill)
# ---------------------------------------------------------------------------

def mlstm_chunk_body(carry, xs):
    """One chunk of the chunkwise mLSTM (scan body; also a dry-run cost probe).

    carry = (Cbar, nbar, m); xs = (q, k, v, i_raw, f_raw) with q/k/v
    (B,H,c,hd) and gates (B,H,c) f32."""
    Cbar, nbar, m = carry
    qq, kk, vv, ii, ff = xs
    chunk = qq.shape[-2]
    lf = jax.nn.log_sigmoid(ff)         # (B,H,c)
    cum = jnp.cumsum(lf, axis=-1)       # inclusive
    total = cum[..., -1]                # (B,H)
    M = jnp.maximum(m, jnp.max(ii - cum, axis=-1))          # (B,H)
    w = jnp.exp(ii - cum - M[..., None])                    # (B,H,c)
    m_loc = cum + M[..., None]                              # (B,H,c)

    qf = qq.astype(jnp.float32)
    kf = kk.astype(jnp.float32)
    vf = vv.astype(jnp.float32)

    # intra-chunk: weight of pair (t,j), j<=t, after exp(-m_loc_t) scaling,
    # is exp(i_j - cum_j - M) = w_j (independent of t).
    s_tj = jnp.einsum("bhtd,bhjd->bhtj", qf, kf) * w[..., None, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    s_tj = jnp.where(tri, s_tj, 0.0)
    num_intra = jnp.einsum("bhtj,bhjd->bhtd", s_tj, vf)

    # inter-chunk: exp(m_prev - M) carried state
    inter_scale = jnp.exp(m - M)[..., None, None]           # (B,H,1,1)
    num_inter = jnp.einsum("bhtd,bhde->bhte", qf, Cbar) * inter_scale
    qn_inter = jnp.einsum("bhtd,bhd->bht", qf, nbar)[..., None] * inter_scale

    num = num_intra + num_inter                             # (B,H,c,hd)
    # denominator: q.n_t = sum_{j<=t} (q.k_j) w_j + e^{m-M} q.nbar
    qn = jnp.sum(s_tj, axis=-1)[..., None] + qn_inter       # (B,H,c,1)
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_loc)[..., None])
    h = num / den                                           # (B,H,c,hd)

    # state update: with m_new = total + M,
    #   carry scale  exp(m + total - m_new) = exp(m - M)
    #   token weight exp(i_j + total - cum_j - m_new) = w_j
    m_new = total + M
    carry_scale = jnp.exp(m - M)
    Cbar_new = (carry_scale[..., None, None] * Cbar
                + jnp.einsum("bhj,bhjd,bhje->bhde", w, kf, vf))
    nbar_new = (carry_scale[..., None] * nbar
                + jnp.einsum("bhj,bhjd->bhd", w, kf))
    return (Cbar_new, nbar_new, m_new), h


def mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk: int,
                    state: Optional[Tuple] = None):
    """Exact chunkwise mLSTM. q,k,v: (B,H,S,hd); gates (B,H,S) f32.

    Returns (h (B,H,S,hd), (Cbar, nbar, m) final state)."""
    B, H, S, hd = q.shape
    assert S % chunk == 0, (S, chunk)
    NC = S // chunk

    qc = q.reshape(B, H, NC, chunk, hd).transpose(2, 0, 1, 3, 4)  # (NC,B,H,c,hd)
    kc = k.reshape(B, H, NC, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, NC, chunk, hd).transpose(2, 0, 1, 3, 4)
    ic = i_raw.reshape(B, H, NC, chunk).transpose(2, 0, 1, 3)     # (NC,B,H,c)
    fc = f_raw.reshape(B, H, NC, chunk).transpose(2, 0, 1, 3)

    if state is None:
        Cbar = jnp.zeros((B, H, hd, hd), jnp.float32)
        nbar = jnp.zeros((B, H, hd), jnp.float32)
        m = jnp.full((B, H), -1e30, jnp.float32)
    else:
        Cbar, nbar, m = state

    body = jax.checkpoint(mlstm_chunk_body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (Cbar, nbar, m), hs = jax.lax.scan(body, (Cbar, nbar, m),
                                       (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    return h, (Cbar, nbar, m)


def mlstm_step(q_t, k_t, v_t, i_t, f_t, state):
    """Single-token mLSTM recurrence (decode).

    q/k/v_t: (B,H,hd); i/f_t: (B,H) f32; state=(Cbar,nbar,m)."""
    Cbar, nbar, m = state
    qf, kf, vf = (a.astype(jnp.float32) for a in (q_t, k_t, v_t))
    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + m, i_t)
    fg = jnp.exp(lf + m - m_new)          # (B,H)
    ig = jnp.exp(i_t - m_new)
    Cbar = fg[..., None, None] * Cbar + ig[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    nbar = fg[..., None] * nbar + ig[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, Cbar)
    qn = jnp.einsum("bhd,bhd->bh", qf, nbar)
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))[..., None]
    h = (num / den).astype(q_t.dtype)
    return h, (Cbar, nbar, m_new)


def ref_mlstm_sequential(q, k, v, i_raw, f_raw):
    """Token-by-token oracle for tests. q,k,v: (B,H,S,hd)."""
    B, H, S, hd = q.shape
    state = (jnp.zeros((B, H, hd, hd), jnp.float32),
             jnp.zeros((B, H, hd), jnp.float32),
             jnp.full((B, H), -1e30, jnp.float32))

    def body(st, xs):
        qt, kt, vt, it, ft = xs
        h, st = mlstm_step(qt, kt, vt, it, ft, st)
        return st, h

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), i_raw.transpose(2, 0, 1),
          f_raw.transpose(2, 0, 1))
    _, hs = jax.lax.scan(body, state, xs)
    return hs.transpose(1, 2, 0, 3)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _head_groupnorm(h, scale, eps=1e-6):
    """Per-head RMS norm. h: (B,S,H,hd); scale: (H*hd,)."""
    B, S, H, hd = h.shape
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    y = hf * jax.lax.rsqrt(var + eps)
    y = y.reshape(B, S, H * hd) * (1.0 + scale.astype(jnp.float32))
    return y.astype(h.dtype)


def mlstm_block(cfg, p, x, *, state=None, decode=False):
    """x: (B,S,D) -> (y, new_state). State = (conv_state, (Cbar, nbar, m))."""
    dt = x.dtype
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(dt))
    u, g = up[..., :D], up[..., D:]

    conv_state = state[0] if state is not None else None
    uc, conv_state_new = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    uc = jax.nn.silu(uc.astype(jnp.float32)).astype(dt)
    q = jnp.einsum("bsd,dhk->bhsk", uc, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", uc, p["wk"].astype(dt)) / math.sqrt(hd)
    v = jnp.einsum("bsd,dhk->bhsk", u, p["wv"].astype(dt))
    i_raw = (jnp.einsum("bsd,dh->bhs", uc, p["wi"].astype(dt))
             + p["bi"].astype(dt)[:, None]).astype(jnp.float32)
    f_raw = (jnp.einsum("bsd,dh->bhs", uc, p["wf"].astype(dt))
             + p["bf"].astype(dt)[:, None]).astype(jnp.float32)

    cell_state = state[1] if state is not None else None
    if decode:
        h_t, cell_state_new = mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                         i_raw[:, :, 0], f_raw[:, :, 0], cell_state)
        h = h_t[:, :, None, :]                      # (B,H,1,hd)
    else:
        chunk = min(cfg.mlstm_chunk, S)
        h, cell_state_new = mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk, cell_state)
    h = h.transpose(0, 2, 1, 3).astype(dt)          # (B,S,H,hd), back to compute dtype
    h = _head_groupnorm(h, p["gn"])
    y = h * jax.nn.silu(g.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bsd,de->bse", y, p["w_down"].astype(dt))
    return out, (conv_state_new, cell_state_new)


def init_mlstm_state(cfg, batch: int):
    B, H, hd = batch, cfg.num_heads, cfg.head_dim
    conv = jnp.zeros((B, cfg.conv_width - 1, cfg.d_model), jnp.float32)
    return (conv, (jnp.zeros((B, H, hd, hd), jnp.float32),
                   jnp.zeros((B, H, hd), jnp.float32),
                   jnp.full((B, H), -1e30, jnp.float32)))


def slstm_cell_scan(cfg, p, x, xc, state=None):
    """sLSTM over a sequence. x, xc: (B,S,D); returns (h_seq, state).

    State = (c, n, h, m) each (B,D) (viewed per-head for the R matmuls)."""
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    dt = x.dtype
    f32 = jnp.float32

    # precompute input-driven gate terms for the whole sequence
    gz = jnp.einsum("bsd,de->bse", x, p["wz"].astype(dt)).astype(f32) + p["bz"].astype(f32)
    gi = jnp.einsum("bsd,de->bse", xc, p["wi"].astype(dt)).astype(f32) + p["bi"].astype(f32)
    gf = jnp.einsum("bsd,de->bse", xc, p["wf"].astype(dt)).astype(f32) + p["bf"].astype(f32)
    go = jnp.einsum("bsd,de->bse", x, p["wo"].astype(dt)).astype(f32) + p["bo"].astype(f32)

    rz, ri, rf, ro = (p[k].astype(f32) for k in ("rz", "ri", "rf", "ro"))

    if state is None:
        zeros = jnp.zeros((B, D), f32)
        state = (zeros, zeros, zeros, jnp.full((B, D), -1e30, f32))

    def body(carry, xs):
        return slstm_token_body((rz, ri, rf, ro), (H, hd), carry, xs)

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (gz.transpose(1, 0, 2), gi.transpose(1, 0, 2),
          gf.transpose(1, 0, 2), go.transpose(1, 0, 2))
    state, hs = jax.lax.scan(body, state, xs)
    return hs.transpose(1, 0, 2).astype(dt), state


def slstm_token_body(r_mats, head_shape, carry, xs):
    """One sLSTM token step (scan body; also a dry-run cost probe).

    r_mats = (rz, ri, rf, ro) each (H,hd,hd) f32; carry = (c,n,h,m) each
    (B,D) f32; xs = per-token input-gate preactivations (z,i,f,o) each (B,D)."""
    rz, ri, rf, ro = r_mats
    H, hd = head_shape
    c, n, h, m = carry
    B, D = c.shape
    z_t, i_t, f_t, o_t = xs

    def rmul(r, hh):
        return jnp.einsum("bhk,hkq->bhq", hh.reshape(B, H, hd), r).reshape(B, D)

    z = jnp.tanh(z_t + rmul(rz, h))
    it = i_t + rmul(ri, h)
    ft = f_t + rmul(rf, h)
    o = jax.nn.sigmoid(o_t + rmul(ro, h))
    lf = jax.nn.log_sigmoid(ft)          # exp-gate via logsigmoid (stable)
    m_new = jnp.maximum(lf + m, it)
    fg = jnp.exp(lf + m - m_new)
    ig = jnp.exp(it - m_new)
    c_new = fg * c + ig * z
    n_new = fg * n + ig
    h_new = o * (c_new / jnp.maximum(n_new, 1e-12))
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(cfg, p, x, *, state=None, decode=False):
    """x: (B,S,D) -> (y, new_state). State = (conv_state, (c,n,h,m))."""
    dt = x.dtype
    conv_state = state[0] if state is not None else None
    xc, conv_state_new = _causal_conv(x, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(dt)
    cell_state = state[1] if state is not None else None
    h, cell_state_new = slstm_cell_scan(cfg, p, x, xc, cell_state)
    B, S, D = h.shape
    h = _head_groupnorm(h.reshape(B, S, cfg.num_heads, cfg.head_dim), p["gn"])
    # gated FFN
    g = jnp.einsum("bsd,df->bsf", h, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", h, p["w_upf"].astype(dt))
    y = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    out = jnp.einsum("bsf,fd->bsd", y, p["w_downf"].astype(dt))
    return out, (conv_state_new, cell_state_new)


def init_slstm_state(cfg, batch: int):
    B, D = batch, cfg.d_model
    zeros = jnp.zeros((B, D), jnp.float32)
    conv = jnp.zeros((B, cfg.conv_width - 1, D), jnp.float32)
    return (conv, (zeros, zeros, zeros, jnp.full((B, D), -1e30, jnp.float32)))
