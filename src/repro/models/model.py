"""Model facade: build_model(cfg) -> uniform API over every assigned family.

API:
    model.init(key)                       -> params (real arrays)
    model.abstract_params()               -> (ShapeDtypeStruct pytree, logical-axes pytree)
    model.loss(params, batch, ctx)        -> (loss, metrics)
    model.prefill(params, batch, max_len, ctx) -> (logits, cache)
    model.decode_step(params, cache, tokens, pos, ctx) -> (logits, cache)
    model.init_cache(batch, max_len)      -> (cache, logical-axes)
    model.probes(shape)                   -> scan-cost-correction probes (see
                                             DESIGN.md §7 / launch/dryrun.py)

Probes: XLA's cost_analysis counts each lax.scan body ONCE. Every model
therefore describes its scan structure as a list of Probe(name, fn,
arg_specs, multiplier): total_cost = cost(full_program)
+ sum_i multiplier_i * cost(probe_i). Probe functions are the *same* code
objects used inside the scans, so the correction is exact.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec as encdec_lib
from repro.models import layers as nn
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# Parameter counting (analytic; mirrors the init functions exactly)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    D, H, KV, hd, F, V = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                          cfg.head_dim, cfg.d_ff, cfg.vocab_size)

    def attn():
        n = D * H * hd + 2 * D * KV * hd + H * hd * D
        if cfg.qkv_bias:
            n += H * hd + 2 * KV * hd
        return n

    def mlp():
        if cfg.mlp_act == "swiglu":
            return 3 * D * F
        return 2 * D * F + F + D

    def moe():
        E = cfg.num_experts
        k = cfg.experts_per_token
        per_expert = 3 * D * F
        router = D * E
        if active_only:
            return router + k * per_expert
        return router + E * per_expert

    def recurrent():
        R, W = cfg.d_rnn, cfg.conv_width
        return (2 * D * R + R * D + W * R + R          # branches + conv
                + 2 * (R * R + R) + R)                  # gates + Lambda

    def mlstm():
        return (D * 2 * D + cfg.conv_width * D + D      # up + conv
                + 3 * D * H * hd + 2 * (D * H + H)      # qkv + gates
                + D + D * D)                            # gn + down

    def slstm():
        Fp = int(cfg.proj_factor * D)
        return (cfg.conv_width * D + D                  # conv
                + 4 * (D * D + D) + 4 * H * hd * hd     # gates + recurrent
                + D + 3 * D * Fp)                       # gn + ffn (w_downf: Fp*D)

    total = V * D + D                                    # embed + final_ln
    if not cfg.tie_embeddings:
        total += D * V

    if cfg.family == "audio":
        total -= D   # enc-dec has per-stack final_lns, no global one
        layer = attn() + mlp() + 2 * D
        xlayer = attn() + D
        total += cfg.encoder_layers * layer + D
        total += cfg.num_layers * (layer + xlayer) + D
        return total

    if cfg.block_pattern:
        per_kind = {"attention": attn() + D, "recurrent": recurrent() + D,
                    "mlstm": mlstm() + D, "slstm": slstm() + D}
        if cfg.d_ff:
            per_kind["attention"] += mlp() + D
            per_kind["recurrent"] += mlp() + D
        pat = tuple(cfg.block_pattern)
        G = cfg.num_layers // len(pat)
        counts = list(pat) * G + list(pat[:cfg.num_layers - G * len(pat)])
        total += sum(per_kind[k] for k in counts)
        return total

    per_layer = attn() + 2 * D
    per_layer += moe() if (cfg.family == "moe" and cfg.num_experts) else mlp()
    total += cfg.num_layers * per_layer
    return total


# ---------------------------------------------------------------------------
# Probe descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Probe:
    name: str
    fn: Callable                 # positional args matching arg_specs
    arg_specs: Tuple[Any, ...]   # pytrees of ShapeDtypeStruct
    arg_axes: Tuple[Any, ...]    # matching pytrees of logical-axis tuples
    multiplier: float            # cost weight added on top of the full program


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _slice_axes(axes_tree):
    """Drop the leading 'layers' entry from every axes tuple (stack -> slice)."""
    def f(t):
        if isinstance(t, tuple) and len(t) and t[0] == "layers":
            return t[1:]
        return t
    return jax.tree.map(f, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))


def _slice_specs(spec_tree):
    """Drop the leading stack dim from every ShapeDtypeStruct."""
    return jax.tree.map(lambda s: _sds(s.shape[1:], s.dtype), spec_tree)


def _grad_probe(fn, remat: bool = False):
    """fwd+bwd probe: cost of value_and_grad of sum(fn(...)) wrt the FLOAT
    args (integer args — positions, indices — are closed over). remat=True
    wraps fn in the same nothing_saveable checkpoint the real scan bodies
    use, so the probe's bwd includes the recompute."""
    if remat:
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    def probe(*args):
        leaves, treedef = jax.tree_util.tree_flatten(args)
        is_float = [jnp.issubdtype(l.dtype, jnp.floating) for l in leaves]
        floats = [l for l, m in zip(leaves, is_float) if m]

        def scalar(fl):
            it = iter(fl)
            full = [next(it) if m else l for l, m in zip(leaves, is_float)]
            out = fn(*jax.tree_util.tree_unflatten(treedef, full))
            outs = [l for l in jax.tree.leaves(out)
                    if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating)]
            return sum(jnp.sum(l.astype(jnp.float32)) for l in outs)

        return jax.value_and_grad(scalar)(floats)
    return probe


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    _init: Callable
    _loss: Callable
    _prefill: Callable
    _decode: Callable
    _init_cache: Callable
    _probes: Callable

    def init(self, key):
        return self._init(key)[0]

    def abstract_params(self):
        holder = {}

        def f(k):
            p, ax = self._init(k)
            holder["ax"] = ax
            return p

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, holder["ax"]

    def loss(self, params, batch, ctx=None):
        return self._loss(params, batch, ctx)

    def prefill(self, params, batch, max_len, ctx=None):
        return self._prefill(params, batch, max_len, ctx)

    def decode_step(self, params, cache, tokens, pos, ctx=None):
        return self._decode(params, cache, tokens, pos, ctx)

    def init_cache(self, batch, max_len, cache_dtype=jnp.bfloat16):
        return self._init_cache(batch, max_len, cache_dtype)

    def probes(self, shape: ShapeSpec) -> List[Probe]:
        return self._probes(shape)

    def param_count(self) -> int:
        return count_params_analytic(self.cfg)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return _build_encdec(cfg)
    return _build_lm(cfg)


# -- decoder-only families ----------------------------------------------------

def _build_lm(cfg: ModelConfig) -> Model:
    def init(key):
        return tfm.init_lm(key, cfg)

    def loss(params, batch, ctx):
        return tfm.lm_loss(cfg, params, batch, ctx)

    def prefill(params, batch, max_len, ctx):
        return tfm.lm_prefill(cfg, params, batch["tokens"], max_len, ctx,
                              batch.get("frontend_embeds"),
                              lengths=batch.get("lengths"))

    def decode(params, cache, tokens, pos, ctx):
        return tfm.lm_decode_step(cfg, params, cache, tokens, pos, ctx)

    def init_cache(batch, max_len, cache_dtype):
        return tfm.init_cache(cfg, batch, max_len, cache_dtype)

    def probes(shape: ShapeSpec) -> List[Probe]:
        return _lm_probes(cfg, shape)

    return Model(cfg, init, loss, prefill, decode, init_cache, probes)


def _lm_probes(cfg: ModelConfig, shape: ShapeSpec) -> List[Probe]:
    """Scan-body probes for the decoder-only families."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    model = build_model(cfg)
    pshapes, paxes = model.abstract_params()
    kind = shape.kind
    if kind in ("train", "prefill"):
        # the real programs run on bf16 weights (train pre-casts the f32
        # masters before the FSDP gathers; serving deploys bf16)
        pshapes = jax.tree.map(lambda t: _sds(t.shape, jnp.bfloat16), pshapes)
    out: List[Probe] = []

    if kind in ("train", "prefill"):
        P = cfg.frontend_seq if cfg.frontend else 0
        Sfull = S + P if cfg.family == "vlm" else S
        x_spec = _sds((B, Sfull, D), dt)
        x_axes = ("batch", "seq", None)
        sin_spec = _sds((Sfull, cfg.head_dim // 2), jnp.float32)

        if cfg.block_pattern:
            pat = tuple(cfg.block_pattern)
            G = cfg.num_layers // len(pat)
            gspecs = _slice_specs(jax.tree.map(
                lambda s: s, pshapes["groups"]))
            gaxes = _slice_axes(paxes["groups"])

            def group_fwd(gp, x, sin, cos):
                return tfm._hybrid_group_full(cfg, gp, x, sin, cos, None, pat)[0]

            fn = _grad_probe(group_fwd) if kind == "train" else group_fwd
            out.append(Probe("group", fn,
                             (gspecs, x_spec, sin_spec, sin_spec),
                             (gaxes, x_axes, (None, None), (None, None)),
                             multiplier=G - 1))

            # inner scan probes (xlstm): chunk body + token body
            chunk = min(cfg.mlstm_chunk, Sfull)
            NC = max(Sfull // chunk, 1)
            H, hd = cfg.num_heads, cfg.head_dim
            n_mlstm = sum(1 for k in pat if k == "mlstm")
            n_slstm = sum(1 for k in pat if k == "slstm")
            if n_mlstm and NC > 1:
                carry = ((_sds((B, H, hd, hd), jnp.float32),
                          _sds((B, H, hd), jnp.float32),
                          _sds((B, H), jnp.float32)))
                xs = (_sds((B, H, chunk, hd), dt), _sds((B, H, chunk, hd), dt),
                      _sds((B, H, chunk, hd), dt), _sds((B, H, chunk), jnp.float32),
                      _sds((B, H, chunk), jnp.float32))
                from repro.models.xlstm import mlstm_chunk_body
                fn = (_grad_probe(mlstm_chunk_body, remat=True)
                      if kind == "train" else mlstm_chunk_body)
                ca = (("batch", "heads", "head_dim", None),
                      ("batch", "heads", "head_dim"), ("batch", "heads"))
                xa = (("batch", "heads", None, "head_dim"),) * 3 + \
                     (("batch", "heads", None),) * 2
                out.append(Probe("mlstm_chunk", fn, (carry, xs), (ca, xa),
                                 multiplier=n_mlstm * G * (NC - 1)))
            if n_slstm and Sfull > 1:
                from repro.models.xlstm import slstm_token_body
                r = tuple(_sds((H, hd, hd), jnp.float32) for _ in range(4))
                carry = tuple(_sds((B, D), jnp.float32) for _ in range(4))
                xs = tuple(_sds((B, D), jnp.float32) for _ in range(4))

                def tok(r_mats, c, x):
                    return slstm_token_body(r_mats, (H, hd), c, x)

                fn = (_grad_probe(tok, remat=True) if kind == "train"
                      else tok)
                ra = tuple(("heads", "head_dim", None) for _ in range(4))
                ba = tuple(("batch", "inner") for _ in range(4))
                out.append(Probe("slstm_token", fn, (r, carry, xs),
                                 (ra, ba, ba),
                                 multiplier=n_slstm * G * (Sfull - 1)))
        else:
            lspecs = _slice_specs(pshapes["layers"])
            laxes = _slice_axes(paxes["layers"])

            def layer_fwd(lp, x, sin, cos):
                return tfm._dense_layer_full(cfg, lp, x, sin, cos, None)[0]

            G = tfm.remat_group_size(cfg)
            if kind == "train" and G > 1:
                # scan-of-scans remat: full program counts one group (which
                # itself counts one layer); corrections per DESIGN.md §7:
                #   total = full + (NG-1)*P_group + NG*(G-1)*P_layer
                NG = cfg.num_layers // G
                gspecs = jax.tree.map(
                    lambda s: _sds((G,) + s.shape[1:], s.dtype),
                    pshapes["layers"])

                def group_fwd(gp, x, sin, cos):
                    return tfm.dense_group_fwd(cfg, gp, x, sin, cos)

                out.append(Probe("group", _grad_probe(group_fwd),
                                 (gspecs, x_spec, sin_spec, sin_spec),
                                 (paxes["layers"], x_axes, (None, None),
                                  (None, None)),
                                 multiplier=NG - 1))
                out.append(Probe("layer", _grad_probe(layer_fwd, remat=True),
                                 (lspecs, x_spec, sin_spec, sin_spec),
                                 (laxes, x_axes, (None, None), (None, None)),
                                 multiplier=NG * (G - 1)))
            else:
                fn = _grad_probe(layer_fwd) if kind == "train" else layer_fwd
                out.append(Probe("layer", fn,
                                 (lspecs, x_spec, sin_spec, sin_spec),
                                 (laxes, x_axes, (None, None), (None, None)),
                                 multiplier=cfg.num_layers - 1))

        # attention inner-scan probes (chunked flash path, DESIGN.md §7)
        out.extend(_attention_chunk_probes(cfg, shape, B, Sfull, dt))
        if kind == "train":
            out.extend(_ce_chunk_probes(cfg, B, S, dt))
    else:  # decode
        sin_spec = _sds((1, cfg.head_dim // 2), jnp.float32)
        x_spec = _sds((B, 1, D), dt)
        x_axes = ("batch", None, None)
        pos_spec = _sds((), jnp.int32)
        # build the cache abstractly (jnp.zeros under eval_shape)
        holder = {}

        def mkcache():
            c, ax = tfm.init_cache(cfg, B, S)
            holder["ax"] = ax
            return c

        cache_shapes = jax.eval_shape(mkcache)
        cache_axes = holder["ax"]

        if cfg.block_pattern:
            pat = tuple(cfg.block_pattern)
            G = cfg.num_layers // len(pat)
            gspecs = _slice_specs(pshapes["groups"])
            gaxes = _slice_axes(paxes["groups"])
            cspecs = _slice_specs(cache_shapes["groups"])
            caxes = _slice_axes(cache_axes["groups"])

            def group_dec(gp, gc, x, sin, cos, pos):
                # mirror of lm_decode_step's gbody for one group slice
                body = _decode_group_body(cfg, pat)
                return body(gp, gc, x, sin, cos, pos)

            out.append(Probe("group_dec", group_dec,
                             (gspecs, cspecs, x_spec, sin_spec, sin_spec, pos_spec),
                             (gaxes, caxes, x_axes, (None, None), (None, None), ()),
                             multiplier=G - 1))
        else:
            lspecs = _slice_specs(pshapes["layers"])
            laxes = _slice_axes(paxes["layers"])
            kc = _sds(tuple(cache_shapes["k"].shape[1:]), cache_shapes["k"].dtype)
            vc = _sds(tuple(cache_shapes["v"].shape[1:]), cache_shapes["v"].dtype)
            kax = _slice_axes(cache_axes["k"])
            vax = _slice_axes(cache_axes["v"])

            def layer_dec(lp, kcache, vcache, x, sin, cos, pos):
                y, kc2, vc2 = tfm._attn_decode(cfg, lp, x, kcache, vcache,
                                               sin, cos, pos, None)
                y, _ = tfm._mlp_sub(cfg, lp, y, None)
                return y, kc2, vc2

            out.append(Probe("layer_dec", layer_dec,
                             (lspecs, kc, vc, x_spec, sin_spec, sin_spec, pos_spec),
                             (laxes, kax, vax, x_axes, (None, None), (None, None), ()),
                             multiplier=cfg.num_layers - 1))
    return out


def _attention_chunk_probes(cfg, shape: ShapeSpec, B: int, S: int, dt,
                            tp: int = 16) -> List[Probe]:
    """Scan-body probes for the flash-in-XLA attention paths.

    The layer/group probe counts the attention scans' bodies once; the true
    program runs them nq (and nq*nk) times per attention layer. Multipliers:
        causal: qbody x n_att*(nq-1), kvbody x n_att*nq*(nk-1)
        window: qwin  x n_att*(nq-1)
    """
    import math as _math
    from repro.models import layers as nn

    out: List[Probe] = []
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    W = cfg.window_size
    train = shape.kind == "train"

    # number of attention layers
    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        G = cfg.num_layers // len(pat)
        n_att = sum(1 for k in pat if k == "attention") * G
        n_att += sum(1 for k in pat[:cfg.num_layers - G * len(pat)]
                     if k == "attention")
    elif cfg.family in ("dense", "moe", "vlm"):
        n_att = cfg.num_layers
    elif cfg.family == "audio":
        n_att = cfg.num_layers          # decoder self-attn (chunked one)
    else:
        n_att = 0
    if n_att == 0:
        return out

    if W and S > W:                      # sliding-window path
        qc = min(nn._CHUNK_Q, S)
        Sq = S + ((-S) % qc)
        nq = Sq // qc
        if nq <= 1:
            return out
        qt = _sds((B, H, Sq, hd), dt)
        ktp = _sds((B, KV, Sq + W, hd), dt)
        idx = _sds((), jnp.int32)

        def qwin(qt_, ktp_, vtp_, i):
            return nn.window_qbody_probe(qt_, ktp_, vtp_, i, W)

        fn = _grad_probe(qwin, remat=True) if train else qwin
        if cfg.num_heads % tp == 0:
            ax = ("batch", "heads", None, None)
            kax = ("batch", "kv_heads" if KV % tp == 0 else None, None, None)
        else:
            ax = ("batch_dm", None, None, None)
            kax = ("batch_dm", None, None, None)
        out.append(Probe("attn_qwin", fn, (qt, ktp, ktp, idx),
                         (ax, kax, kax, ()), multiplier=n_att * (nq - 1)))
        return out

    if S <= nn.CHUNKED_THRESHOLD:
        return out                       # exact path, no inner scans

    qc = min(nn._CHUNK_Q, S)
    kc = min(nn._CHUNK_K, S)
    Sq = S + ((-S) % qc)
    Sk = S + ((-S) % kc)
    nq, nk = Sq // qc, Sk // kc

    qblk = _sds((B, H, qc, hd), dt)
    kb = _sds((nk, B, KV, kc, hd), dt)
    kpos = _sds((nk, B, kc), jnp.int32)
    qpos = _sds((B, qc), jnp.int32)
    if H % tp == 0:
        bname = "batch"
        qax = ("batch", "heads", None, None)
        kvn = "kv_heads" if KV % tp == 0 else None
        kbax = (None, "batch", kvn, None, None)
    else:
        bname = "batch_dm"
        qax = ("batch_dm", None, None, None)
        kbax = (None, "batch_dm", None, None, None)

    if nq > 1:
        fn = (_grad_probe(nn.flash_qbody_probe, remat=True) if train
              else nn.flash_qbody_probe)
        out.append(Probe("attn_qbody", fn, (qblk, kb, kb, kpos, qpos),
                         (qax, kbax, kbax, (None, bname, None),
                          (bname, None)),
                         multiplier=n_att * (nq - 1)))
    if nk > 1:
        m = _sds((B, H, qc), jnp.float32)
        acc = _sds((B, H, qc, hd), jnp.float32)
        kblk = _sds((B, KV, kc, hd), dt)
        kp = _sds((B, kc), jnp.int32)
        fn = (_grad_probe(nn.flash_kvbody_probe, remat=True) if train
              else nn.flash_kvbody_probe)
        kax = kbax[1:]
        hax = qax[:3]
        out.append(Probe("attn_kvbody", fn,
                         (m, m, acc, kblk, kblk, kp, qblk, qpos),
                         (hax, hax, qax, kax, kax,
                          (bname, None), qax, (bname, None)),
                         multiplier=n_att * nq * (nk - 1)))
    return out


def _ce_chunk_probes(cfg: ModelConfig, B: int, S: int, dt) -> List[Probe]:
    """Streamed head+CE scan-body probe (train loss path)."""
    if S <= nn.CE_CHUNK:
        return []
    c = min(nn.CE_CHUNK, S)
    nc = (S + c - 1) // c
    if nc <= 1:
        return []
    D, V = cfg.d_model, cfg.vocab_size
    h = _sds((B, c, D), dt)
    tgt = _sds((B, c), jnp.int32)
    valid = _sds((B, c), jnp.bool_)
    carry = (_sds((), jnp.float32), _sds((), jnp.float32))
    if cfg.tie_embeddings:
        w = _sds((V, D), jnp.dtype(cfg.param_dtype))
        wax = ("vocab", "embed")
    else:
        w = _sds((D, V), jnp.dtype(cfg.param_dtype))
        wax = ("embed", "vocab")

    def ce(carry_, h_, tgt_, valid_, w_):
        return nn.ce_chunk_body(carry_, (h_, tgt_, valid_), w_,
                                cfg.tie_embeddings)[0]

    return [Probe("ce_chunk", _grad_probe(ce, remat=True),
                  (carry, h, tgt, valid, w),
                  (((), ()), ("batch", None, None), ("batch", None),
                   ("batch", None), wax),
                  multiplier=nc - 1)]


def _decode_group_body(cfg, pat):
    """Standalone one-group decode step used as probe (mirrors lm_decode_step)."""
    from repro.models import recurrent as rec_lib
    from repro.models import xlstm as xlstm_lib

    def body(gp, gc, x, sin, cos, pos):
        y = x
        for i, kind in enumerate(pat):
            name = f"b{i}_{kind}"
            lp, c = gp[name], gc[name]
            if kind == "attention":
                y, _, _ = tfm._attn_decode(
                    cfg, {"ln": lp["ln"], "core": lp["core"]},
                    y, c["k"], c["v"], sin, cos, pos, None,
                    window=cfg.window_size)
                if "mlp" in lp:
                    y, _ = tfm._mlp_sub(cfg, lp, y, None)
            elif kind == "recurrent":
                h = nn.rms_norm(y, lp["ln"], cfg.norm_eps)
                o, _ = rec_lib.recurrent_block(
                    cfg, lp["core"], h, conv_state=c["conv"],
                    h_state=c["h"], decode=True)
                y = y + o
                if "mlp" in lp:
                    y, _ = tfm._mlp_sub(cfg, lp, y, None)
            elif kind == "mlstm":
                h = nn.rms_norm(y, lp["ln"], cfg.norm_eps)
                o, _ = xlstm_lib.mlstm_block(
                    cfg, lp["core"], h,
                    state=(c["conv"], (c["C"], c["n"], c["m"])), decode=True)
                y = y + o
            elif kind == "slstm":
                h = nn.rms_norm(y, lp["ln"], cfg.norm_eps)
                o, _ = xlstm_lib.slstm_block(
                    cfg, lp["core"], h,
                    state=(c["conv"], (c["c"], c["n2"], c["h"], c["m"])),
                    decode=True)
                y = y + o
        return y
    return body


# -- encoder-decoder (audio) ---------------------------------------------------

def _build_encdec(cfg: ModelConfig) -> Model:
    def init(key):
        return encdec_lib.init_encdec(key, cfg)

    def loss(params, batch, ctx):
        return encdec_lib.encdec_loss(cfg, params, batch, ctx)

    def prefill(params, batch, max_len, ctx):
        return encdec_lib.encdec_prefill(cfg, params, batch["frontend_embeds"],
                                         batch["tokens"], max_len, ctx)

    def decode(params, cache, tokens, pos, ctx):
        return encdec_lib.encdec_decode_step(cfg, params, cache, tokens, pos, ctx)

    def init_cache(batch, max_len, cache_dtype):
        return encdec_lib.init_encdec_cache(cfg, batch, max_len, cache_dtype)

    def probes(shape: ShapeSpec) -> List[Probe]:
        return _encdec_probes(cfg, shape)

    return Model(cfg, init, loss, prefill, decode, init_cache, probes)


def _encdec_probes(cfg: ModelConfig, shape: ShapeSpec) -> List[Probe]:
    B, S = shape.global_batch, shape.seq_len
    Se = cfg.frontend_seq
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    model = build_model(cfg)
    pshapes, paxes = model.abstract_params()
    kind = shape.kind
    if kind in ("train", "prefill"):
        pshapes = jax.tree.map(lambda t: _sds(t.shape, jnp.bfloat16), pshapes)
    out: List[Probe] = []

    enc_specs = _slice_specs(pshapes["encoder"]["layers"])
    enc_axes = _slice_axes(paxes["encoder"]["layers"])
    dec_specs = _slice_specs(pshapes["decoder"]["layers"])
    dec_axes = _slice_axes(paxes["decoder"]["layers"])
    sin_e = _sds((Se, cfg.head_dim // 2), jnp.float32)

    def enc_layer(lp, x, sin, cos):
        h = nn.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = nn.qkv_project(cfg, lp["attn"], h)
        q = nn.apply_rope(q, sin, cos)
        k = nn.apply_rope(k, sin, cos)
        o = nn.causal_attention(q, k, v, causal=False)
        y = x + nn.out_project(cfg, lp["attn"], o)
        h2 = nn.rms_norm(y, lp["ln2"], cfg.norm_eps)
        return y + nn.mlp(cfg, lp["mlp"], h2)

    if kind in ("train", "prefill"):
        xe = _sds((B, Se, D), dt)
        xd = _sds((B, S, D), dt)
        sin_d = _sds((S, cfg.head_dim // 2), jnp.float32)
        eo = _sds((B, Se, D), dt)

        def dec_layer(lp, x, enc_out, sin, cos):
            h = nn.rms_norm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = nn.qkv_project(cfg, lp["attn"], h)
            q = nn.apply_rope(q, sin, cos)
            k = nn.apply_rope(k, sin, cos)
            o = tfm._attention_dispatch(cfg, q, k, v)
            y = x + nn.out_project(cfg, lp["attn"], o)
            hx = nn.rms_norm(y, lp["lnx"], cfg.norm_eps)
            qx, _, _ = nn.qkv_project(cfg, lp["xattn"], hx)
            _, kx, vx = nn.qkv_project(cfg, lp["xattn"], enc_out)
            ox = nn.causal_attention(qx, kx, vx, causal=False)
            y = y + nn.out_project(cfg, lp["xattn"], ox)
            h2 = nn.rms_norm(y, lp["ln2"], cfg.norm_eps)
            return y + nn.mlp(cfg, lp["mlp"], h2)

        ef = _grad_probe(enc_layer) if kind == "train" else enc_layer
        df = _grad_probe(dec_layer) if kind == "train" else dec_layer
        out.append(Probe("enc_layer", ef, (enc_specs, xe, sin_e, sin_e),
                         (enc_axes, ("batch", "seq", None), (None, None), (None, None)),
                         multiplier=cfg.encoder_layers - 1))
        out.append(Probe("dec_layer", df, (dec_specs, xd, eo, sin_d, sin_d),
                         (dec_axes, ("batch", "seq", None), ("batch", "seq", None),
                          (None, None), (None, None)),
                         multiplier=cfg.num_layers - 1))
        out.extend(_attention_chunk_probes(cfg, shape, B, S, dt))
    else:
        x = _sds((B, 1, D), dt)
        sin1 = _sds((1, cfg.head_dim // 2), jnp.float32)
        pos = _sds((), jnp.int32)
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        kc = _sds((B, S, KV, hd), jnp.bfloat16)
        xk = _sds((B, Se, KV, hd), jnp.bfloat16)
        cax = ("batch", None, "kv_heads", "head_dim")

        def dec_step(lp, kcache, vcache, xkc, xvc, xx, sin, cos, p):
            h = nn.rms_norm(xx, lp["ln1"], cfg.norm_eps)
            q, k, v = nn.qkv_project(cfg, lp["attn"], h)
            q = nn.apply_rope(q, sin, cos)
            k = nn.apply_rope(k, sin, cos)
            kcache, vcache = nn.cache_update(kcache, vcache, k, v, p)
            o = nn.decode_attention(q, kcache, vcache, p)
            y = xx + nn.out_project(cfg, lp["attn"], o)
            hx = nn.rms_norm(y, lp["lnx"], cfg.norm_eps)
            qx, _, _ = nn.qkv_project(cfg, lp["xattn"], hx)
            ox = nn.decode_attention(qx, xkc, xvc, jnp.asarray(Se - 1))
            y = y + nn.out_project(cfg, lp["xattn"], ox)
            h2 = nn.rms_norm(y, lp["ln2"], cfg.norm_eps)
            return y + nn.mlp(cfg, lp["mlp"], h2), kcache, vcache

        out.append(Probe("dec_step", dec_step,
                         (dec_specs, kc, kc, xk, xk, x, sin1, sin1, pos),
                         (dec_axes, cax, cax, cax, cax,
                          ("batch", None, None), (None, None), (None, None), ()),
                         multiplier=cfg.num_layers - 1))
    return out
