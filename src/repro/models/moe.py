"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Design (TPU-native, HLO-FLOPs-honest):
  * router: dense (D, E) matmul + top-k.
  * dispatch: tokens are scattered into per-expert buffers (E, C, D) where
    C = capacity = ceil(k * T / E) * capacity_factor. Scatter/gather are
    memory ops, NOT one-hot matmuls, so HLO FLOPs reflect only the *active*
    expert compute (2*k*T*D*F-ish) — keeping MODEL_FLOPS/HLO_FLOPs meaningful.
  * expert compute: batched einsum over the expert axis; experts shard over
    the "model" mesh axis (expert parallelism). GSPMD inserts the
    dispatch/combine collectives (all-to-all / all-gather depending on the
    token sharding) — these show up in the collective roofline term.
  * determinism: top-k on identical inputs is bitwise deterministic, so SEDAR
    replicas stay in lockstep (DESIGN.md §4); no routing jitter under SEDAR.

Dropped tokens (over capacity) fall back to the residual path (standard
"token dropping" semantics, loss-free at the framework level).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init


def init_moe(key, cfg, layers: Optional[int] = None):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    L = (layers,) if layers else ()
    lax_pref = ("layers",) if layers else ()
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "router": normal_init(ks[0], L + (D, E), pdt, 1.0 / math.sqrt(D)),
        "w_gate": normal_init(ks[1], L + (E, D, F), pdt, 1.0 / math.sqrt(D)),
        "w_up":   normal_init(ks[2], L + (E, D, F), pdt, 1.0 / math.sqrt(D)),
        "w_down": normal_init(ks[3], L + (E, F, D), pdt, 1.0 / math.sqrt(F)),
    }
    ax = {
        "router": lax_pref + ("embed", None),
        "w_gate": lax_pref + ("experts", "embed", "mlp"),
        "w_up":   lax_pref + ("experts", "embed", "mlp"),
        "w_down": lax_pref + ("experts", "mlp", "embed"),
    }
    return p, ax


def moe_mlp_ep(cfg, p, x, *, capacity_factor: float = 1.25, ctx=None):
    """Expert-parallel MoE via shard_map + all_to_all (the production path).

    Tokens are sharded over every mesh axis (data x model); each device
    routes ITS tokens locally (local cumsum positions, local capacity, local
    scatter — kilobyte-scale buffers), then one all_to_all over the model
    axis moves token slices to their expert's owner, the expert FFN runs on
    local weights, and the reverse all_to_all brings results home. GSPMD
    cannot infer this from a global scatter (it replicates the dispatch
    buffers — tens of GB at 1M tokens); shard_map makes the exchange
    explicit. Used whenever a mesh ctx is present and E % TP == 0."""
    import numpy as _np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    dt = x.dtype
    rules = ctx.resolver.rules
    mesh = ctx.mesh
    token_axes = tuple(rules.data_axes) + tuple(rules.model_axes)
    n_tok_shards = rules.axis_size(mesh, token_axes)
    tp = rules.axis_size(mesh, rules.model_axes)
    model_axis = rules.model_axes[0]
    Tl = T // n_tok_shards
    Cl = max(int(math.ceil(k * Tl / E * capacity_factor)), 4)
    E_l = E // tp

    def body(xt, router, wg, wu, wd):
        # xt: (Tl, D) local tokens; router: (D, E); w*: (E_l, D, F) local
        logits = jnp.einsum("td,de->te", xt, router.astype(dt)
                            ).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_idx = jax.lax.top_k(probs, k)
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32),
                              axis=1), axis=0)
        aux = E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, token_axes)

        flat_e = gate_idx.reshape(Tl * k)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - onehot,
                                  flat_e[:, None], axis=1)[:, 0]
        keep = pos < Cl
        e_idx = jnp.where(keep, flat_e, 0)
        c_idx = jnp.where(keep, pos, Cl - 1)
        src = jnp.repeat(xt, k, axis=0) if k > 1 else xt
        contrib = jnp.where(keep[:, None], src, 0).astype(dt)
        buf = jnp.zeros((E, Cl, D), dt).at[e_idx, c_idx].add(
            contrib, mode="drop")                     # local dispatch

        # token -> expert exchange: each peer gets its experts' queues
        # (tiled all_to_all: (E, Cl, D) -> (E/tp, tp*Cl, D); its transpose is
        # the symmetric reverse exchange, which keeps the VJP well-formed)
        recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                                  concat_axis=1, tiled=True)  # (E_l, tp*Cl, D)

        hg = jnp.einsum("ecd,edf->ecf", recv, wg.astype(dt))
        hu = jnp.einsum("ecd,edf->ecf", recv, wu.astype(dt))
        h = jax.nn.silu(hg.astype(jnp.float32)).astype(dt) * hu
        outb = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))  # (E_l, tp*Cl, D)

        # reverse exchange: results back to the token owners
        back = jax.lax.all_to_all(outb, model_axis, split_axis=1,
                                  concat_axis=0, tiled=True)  # (E, Cl, D)

        gathered = back[e_idx, c_idx]
        gathered = jnp.where(keep[:, None], gathered, 0)
        w = gate_w.reshape(Tl * k).astype(jnp.float32)
        out = (gathered.astype(jnp.float32) * w[:, None]) \
            .reshape(Tl, k, D).sum(axis=1).astype(dt)
        drop = 1.0 - jnp.mean(keep.astype(jnp.float32))
        return out, aux, jax.lax.pmean(drop, token_axes)

    tok_spec = P(token_axes if len(token_axes) > 1 else token_axes[0], None)
    try:
        sm = shard_map(body, mesh=mesh,
                       in_specs=(tok_spec, P(), P(model_axis, None, None),
                                 P(model_axis, None, None),
                                 P(model_axis, None, None)),
                       out_specs=(tok_spec, P(), P()), check_vma=False)
    except TypeError:
        sm = shard_map(body, mesh=mesh,
                       in_specs=(tok_spec, P(), P(model_axis, None, None),
                                 P(model_axis, None, None),
                                 P(model_axis, None, None)),
                       out_specs=(tok_spec, P(), P()), check_rep=False)
    out, aux, drop = sm(x.reshape(T, D), p["router"], p["w_gate"],
                        p["w_up"], p["w_down"])
    return out.reshape(B, S, D), {"moe_aux": aux, "moe_drop_frac": drop}


def moe_mlp(cfg, p, x, *, capacity_factor: float = 1.25, ctx=None):
    """x: (B, S, D) -> (B, S, D), plus aux losses dict.

    Group-local dispatch: tokens are viewed as (G, T/G, ...) with G = the
    data-parallel degree, the leading dim pinned to the data axis. Routing
    positions (cumsum) and the dispatch scatter are then LOCAL per data
    shard — per-group capacity, the standard EP formulation — and the only
    cross-device movement is the intended token->expert exchange over the
    model axis (all-to-all in the compiled HLO). Without the grouping GSPMD
    must treat the scatter as global and falls back to replicating the
    (E, C, D) buffers, which at 1M tokens is tens of GB per device.
    """
    def act(t, *logical):
        return ctx.act(t, *logical) if ctx is not None else t

    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    dt = x.dtype

    # production path: explicit expert parallelism when a mesh is present
    if ctx is not None:
        r = ctx.resolver.rules
        tp = r.axis_size(ctx.mesh, r.model_axes)
        nsh = r.axis_size(ctx.mesh, tuple(r.data_axes) + tuple(r.model_axes))
        if E % tp == 0 and T % nsh == 0 and tp > 1:
            return moe_mlp_ep(cfg, p, x, capacity_factor=capacity_factor,
                              ctx=ctx)

    # dispatch group count = data-parallel degree (1 when mesh-free)
    G = 1
    if ctx is not None:
        r = ctx.resolver.rules
        G = r.axis_size(ctx.mesh, r.data_axes)
        if T % G != 0:
            G = 1
    Tg = T // G

    xt = act(x.reshape(T, D), "batch", None)

    # ---- route ---------------------------------------------------------------
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                  # (T, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)   # renormalize

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=1), axis=0)
    aux_loss = E * jnp.sum(me * ce)

    # ---- group-local dispatch ---------------------------------------------------
    Cg = int(math.ceil(k * Tg / E * capacity_factor))
    Cg = max(Cg, 4)
    flat_e = gate_idx.reshape(G, Tg * k)                         # (G, Tkg)
    flat_e = act(flat_e, "batch", None)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (G, Tkg, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot               # per-group cumsum
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                              axis=2)[..., 0]                    # (G, Tkg)
    keep = pos < Cg

    src = (jnp.repeat(xt, k, axis=0) if k > 1 else xt).reshape(G, Tg * k, D)
    src = act(src, "batch", None, None)
    gi = jnp.broadcast_to(jnp.arange(G)[:, None], flat_e.shape)  # (G, Tkg)
    e_idx = jnp.where(keep, flat_e, 0)
    c_idx = jnp.where(keep, pos, Cg - 1)
    contrib = jnp.where(keep[..., None], src, 0).astype(dt)

    buf = jnp.zeros((G, E, Cg, D), dt)
    buf = buf.at[gi, e_idx, c_idx].add(contrib, mode="drop")
    buf = act(buf, "batch", "experts", None, None)   # G->data, E->model (EP)

    # ---- expert compute --------------------------------------------------------
    h_g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    h_u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(dt) * h_u
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    out_buf = act(out_buf, "batch", "experts", None, None)

    # ---- combine ----------------------------------------------------------------
    gathered = out_buf[gi, e_idx, c_idx]                         # (G, Tkg, D)
    gathered = jnp.where(keep[..., None], gathered, 0)
    w = gate_w.reshape(G, Tg * k).astype(jnp.float32)
    out = (gathered.astype(jnp.float32) * w[..., None]) \
        .reshape(G, Tg, k, D).sum(axis=2)
    out = out.reshape(B, S, D).astype(dt)
    return out, {"moe_aux": aux_loss,
                 "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
