"""Decoder LM assembly: scan-over-layers, all families, train + prefill + decode.

Families:
  dense / moe / vlm : homogeneous layer stack, lax.scan over L stacked params.
  hybrid (griffin)  : pattern groups (rec, rec, attn) scanned over G + tail.
  ssm (xlstm)       : pattern groups (mlstm, slstm) scanned over G.

Scan-over-layers keeps compile time depth-independent (critical for the 88-L
dry-runs on the CPU container) and is the production choice anyway.

Activation sharding hints are applied through an optional ``ctx`` (ShardCtx);
with ctx=None the code is mesh-free (CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as nn
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models import xlstm as xlstm_lib


# ---------------------------------------------------------------------------
# Sharding context for activations
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardCtx:
    mesh: Any
    resolver: Any   # repro.sharding.Resolver

    def act(self, x, *logical):
        from jax.sharding import NamedSharding
        spec = self.resolver.spec(logical, x.shape, name="act")
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def tp_size(self) -> int:
        r = self.resolver.rules
        return r.axis_size(self.mesh, r.model_axes)


def _act(ctx, x, *logical):
    return ctx.act(x, *logical) if ctx is not None else x


# ---------------------------------------------------------------------------
# Layer init (per family)
# ---------------------------------------------------------------------------

def _init_dense_layer_stack(key, cfg, L):
    ks = jax.random.split(key, 4)
    attn_p, attn_ax = nn.init_attention(ks[0], cfg, layers=L)
    if cfg.family == "moe" and cfg.num_experts:
        mlp_p, mlp_ax = moe_lib.init_moe(ks[1], cfg, layers=L)
    else:
        mlp_p, mlp_ax = nn.init_mlp(ks[1], cfg, layers=L)
    pdt = jnp.dtype(cfg.param_dtype)
    p = {"attn": attn_p, "mlp": mlp_p,
         "ln1": jnp.zeros((L, cfg.d_model), pdt),
         "ln2": jnp.zeros((L, cfg.d_model), pdt)}
    ax = {"attn": attn_ax, "mlp": mlp_ax,
          "ln1": ("layers", "embed"), "ln2": ("layers", "embed")}
    return p, ax


def _init_hybrid_group_stack(key, cfg, pattern, G):
    """One stacked group of blocks following ``pattern`` (e.g. rec,rec,attn)."""
    p, ax = {}, {}
    pdt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2 * len(pattern))
    for i, kind in enumerate(pattern):
        name = f"b{i}_{kind}"
        if kind == "attention":
            bp, bax = nn.init_attention(ks[2 * i], cfg, layers=G)
        elif kind == "recurrent":
            bp, bax = rec_lib.init_recurrent_block(ks[2 * i], cfg, layers=G)
        elif kind == "mlstm":
            bp, bax = xlstm_lib.init_mlstm_block(ks[2 * i], cfg, layers=G)
        elif kind == "slstm":
            bp, bax = xlstm_lib.init_slstm_block(ks[2 * i], cfg, layers=G)
        else:
            raise ValueError(kind)
        entry = {"core": bp, "ln": jnp.zeros((G, cfg.d_model), pdt)}
        entry_ax = {"core": bax, "ln": ("layers", "embed")}
        if kind in ("attention", "recurrent") and cfg.d_ff:
            mp, max_ = nn.init_mlp(ks[2 * i + 1], cfg, layers=G)
            entry["mlp"] = mp
            entry["ln2"] = jnp.zeros((G, cfg.d_model), pdt)
            entry_ax["mlp"] = max_
            entry_ax["ln2"] = ("layers", "embed")
        p[name] = entry
        ax[name] = entry_ax
    return p, ax


def init_lm(key, cfg):
    """Returns (params, logical_axes)."""
    ks = jax.random.split(key, 4)
    emb_p, emb_ax = nn.init_embedding(ks[0], cfg)
    pdt = jnp.dtype(cfg.param_dtype)
    params: Dict[str, Any] = {"embed": emb_p,
                              "final_ln": jnp.zeros((cfg.d_model,), pdt)}
    axes: Dict[str, Any] = {"embed": emb_ax, "final_ln": ("embed",)}

    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        G = cfg.num_layers // len(pat)
        tail_len = cfg.num_layers - G * len(pat)
        gp, gax = _init_hybrid_group_stack(ks[1], cfg, pat, G)
        params["groups"] = gp
        axes["groups"] = gax
        if tail_len:
            tp, tax = _init_hybrid_group_stack(ks[2], cfg, pat[:tail_len], 1)
            params["tail"] = tp
            axes["tail"] = tax
    else:
        lp, lax_ = _init_dense_layer_stack(ks[1], cfg, cfg.num_layers)
        params["layers"] = lp
        axes["layers"] = lax_
    return params, axes


# ---------------------------------------------------------------------------
# Block applications (full-sequence mode)
# ---------------------------------------------------------------------------

def _attn_full(cfg, lp, x, sin, cos, ctx, window: int = 0):
    """Pre-norm attention sub-block, full sequence."""
    h = nn.rms_norm(x, lp["ln1"] if "ln1" in lp else lp["ln"], cfg.norm_eps)
    # sequence-parallel boundary: x stays seq-sharded, the norm runs locally
    # (per-token), and the all-gather moves the bf16 normed activations
    h = _act(ctx, h, "batch", None, None)
    q, k, v = nn.qkv_project(cfg, lp["attn"] if "attn" in lp else lp["core"], h)
    q = nn.apply_rope(q, sin, cos)
    k = nn.apply_rope(k, sin, cos)
    # Inside attention: tensor-parallel over heads; when heads % TP != 0 the
    # batch dim takes data*model instead (fully-local attention). The q and
    # k/v layouts are COUPLED: if q shards heads, k/v either shard kv_heads
    # (divisible) or replicate over the model axis (GQA kv < TP: each kv head
    # lives on H/KV devices — the standard replication trick); k/v must never
    # take a batch layout different from q's. head_dim is deliberately NOT a
    # candidate for activations: it is a contraction dim, and sharding it
    # turns every QK^T/PV einsum into an S^2-sized all-reduce. The seq dim
    # must not pick up the model axis here either (attention chunking
    # reshapes seq -> replicate-repartition storms).
    tp = ctx.tp_size() if ctx is not None else 1
    heads_ok = q.shape[2] % tp == 0
    kv_ok = k.shape[2] % tp == 0
    if heads_ok:
        q = _act(ctx, q, "batch", None, "heads", None)
        kv_name = "kv_heads" if kv_ok else None
        k = _act(ctx, k, "batch", None, kv_name, None)
        v = _act(ctx, v, "batch", None, kv_name, None)
    else:
        q = _act(ctx, q, "batch_dm", None, None, None)
        k = _act(ctx, k, "batch_dm", None, None, None)
        v = _act(ctx, v, "batch_dm", None, None, None)
    o = _attention_dispatch(cfg, q, k, v, window)
    o = nn.out_project(cfg, lp["attn"] if "attn" in lp else lp["core"], o)
    return x + _act(ctx, o, "batch", "seq", None)


def _attention_dispatch(cfg, q, k, v, window: int = 0):
    """Pick the attention implementation by sequence length / config.

    S <= CHUNKED_THRESHOLD: exact einsum (O(S^2) logits, fine at this size).
    Larger S: flash-in-XLA chunked scans (O(chunk) memory, GSPMD-shardable).
    attention_impl="pallas": the Pallas flash kernel (TPU production path)."""
    S = q.shape[1]
    if cfg.attention_impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, window=window)
    if window and S > window:
        return nn.chunked_window_attention(q, k, v, window)
    if S > nn.CHUNKED_THRESHOLD:
        return nn.chunked_causal_attention(q, k, v)
    return nn.causal_attention(q, k, v)


def _mlp_sub(cfg, lp, x, ctx, ln_key="ln2", mlp_key="mlp"):
    h = nn.rms_norm(x, lp[ln_key], cfg.norm_eps)
    h = _act(ctx, h, "batch", None, None)   # SP boundary (see _attn_full)
    if cfg.family == "moe" and mlp_key == "mlp" and cfg.num_experts and "router" in lp[mlp_key]:
        o, aux = moe_lib.moe_mlp(cfg, lp[mlp_key], h, ctx=ctx)
    else:
        o, aux = nn.mlp(cfg, lp[mlp_key], h), {}
    return x + _act(ctx, o, "batch", "seq", None), aux


def _dense_layer_full(cfg, lp, x, sin, cos, ctx):
    x = _attn_full(cfg, lp, x, sin, cos, ctx)
    x, aux = _mlp_sub(cfg, lp, x, ctx)
    return x, aux


def _hybrid_group_full(cfg, gp, x, sin, cos, ctx, pattern):
    """Apply one (stack-sliced) pattern group, full sequence. Returns (x, aux)."""
    auxes = {}
    for i, kind in enumerate(pattern):
        lp = gp[f"b{i}_{kind}"]
        if kind == "attention":
            x = _attn_full(cfg, {"ln1": lp["ln"], "attn": lp["core"]},
                           x, sin, cos, ctx, window=cfg.window_size)
            if "mlp" in lp:
                x, _ = _mlp_sub(cfg, lp, x, ctx)
        elif kind == "recurrent":
            h = nn.rms_norm(x, lp["ln"], cfg.norm_eps)
            h = _act(ctx, h, "batch", None, None)
            o, _ = rec_lib.recurrent_block(cfg, lp["core"], h)
            x = x + _act(ctx, o, "batch", "seq", None)
            if "mlp" in lp:
                x, _ = _mlp_sub(cfg, lp, x, ctx)
        elif kind == "mlstm":
            h = nn.rms_norm(x, lp["ln"], cfg.norm_eps)
            h = _act(ctx, h, "batch", None, None)
            o, _ = xlstm_lib.mlstm_block(cfg, lp["core"], h)
            x = x + _act(ctx, o, "batch", "seq", None)
        elif kind == "slstm":
            h = nn.rms_norm(x, lp["ln"], cfg.norm_eps)
            h = _act(ctx, h, "batch", None, None)
            o, _ = xlstm_lib.slstm_block(cfg, lp["core"], h)
            x = x + _act(ctx, o, "batch", "seq", None)
    return x, auxes


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        policy = jax.checkpoint_policies.nothing_saveable
    else:
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Full forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def lm_hidden(cfg, params, tokens, ctx=None, frontend_embeds=None,
              collect_kv: bool = False):
    """tokens: (B, S_text) int32. frontend_embeds: (B, P, D) or None.

    Returns (hidden (B,S,D), kv_stack or None, aux dict). S = P + S_text.
    kv_stack (dense families only): (k, v) each (L, B, S, KV, hd)."""
    x = nn.embed_tokens(cfg, params["embed"], tokens)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    B, S, D = x.shape
    x = _act(ctx, x, "batch", "seq", None)
    sin, cos = nn.rope_tables(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
    aux_out: Dict[str, Any] = {}

    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        G = cfg.num_layers // len(pat)

        def gbody(carry, gp):
            y, _ = _hybrid_group_full(cfg, gp, carry, sin, cos, ctx, pat)
            return y, None

        x, _ = jax.lax.scan(_remat(cfg, gbody), x, params["groups"])
        if "tail" in params:
            tail_pat = pat[: len(_pattern_tail(cfg))]

            def tbody(carry, gp):
                y, _ = _hybrid_group_full(cfg, gp, carry, sin, cos, ctx, tail_pat)
                return y, None

            x, _ = jax.lax.scan(_remat(cfg, tbody), x, params["tail"])
        kv = None
    else:
        is_moe = cfg.family == "moe" and cfg.num_experts > 0

        def body(carry, lp):
            y, aux = _dense_layer_full(cfg, lp, carry, sin, cos, ctx)
            if collect_kv:
                # re-derive this layer's K/V from the *input* activations to
                # seed the decode cache (prefill path only)
                hq = nn.rms_norm(carry, lp["ln1"], cfg.norm_eps)
                _, k, v = nn.qkv_project(cfg, lp["attn"], hq)
                k = nn.apply_rope(k, sin, cos)
                out = (k, v)
            elif is_moe:
                out = aux
            else:
                out = None
            return y, out

        G = remat_group_size(cfg)
        if collect_kv or G == 1:
            x, ys = jax.lax.scan(_remat(cfg, body), x, params["layers"])
        else:
            # scan-of-scans remat: checkpoint GROUPS of G layers so the
            # saved residual-stream carries shrink L -> L/G (the standard
            # sqrt-style activation-checkpointing trade; bwd recomputes one
            # group forward). Only the bwd path cares, so prefill keeps the
            # flat scan.
            NG = cfg.num_layers // G
            grouped = jax.tree.map(
                lambda a: a.reshape((NG, G) + a.shape[1:]), params["layers"])

            # two-level remat: the inner per-layer body is checkpointed as
            # well, otherwise the group's bwd recompute stashes G layers of
            # f32 residuals (norm/silu upcasts) at once — the difference
            # between ~240 GB and ~10 GB per device at 123B/1M-token scale
            inner = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

            def group_body(carry, gp):
                return jax.lax.scan(inner, carry, gp)

            x, ys_g = jax.lax.scan(_remat(cfg, group_body), x, grouped)
            ys = (jax.tree.map(lambda a: a.reshape((cfg.num_layers,)
                                                   + a.shape[2:]), ys_g)
                  if ys_g is not None and is_moe else None)
        kv = ys if collect_kv else None
        if is_moe and not collect_kv and ys is not None:
            aux_out = {k: jnp.mean(v) for k, v in ys.items()}
    x = nn.rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, kv, aux_out


def remat_group_size(cfg) -> int:
    """Largest divisor of num_layers <= 8 (1 disables grouping)."""
    if cfg.remat == "none" or cfg.block_pattern:
        return 1
    for g in range(min(8, cfg.num_layers), 0, -1):
        if cfg.num_layers % g == 0:
            return g
    return 1


def dense_group_fwd(cfg, gp, x, sin, cos):
    """One remat group of G stacked dense layers (dry-run cost probe; the
    same inner-scan + inner-checkpoint structure as lm_hidden's group_body)."""
    def body(carry, lp):
        y, _ = _dense_layer_full(cfg, lp, carry, sin, cos, None)
        return y, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    y, _ = jax.lax.scan(body, x, gp)
    return y


def _pattern_tail(cfg):
    pat = tuple(cfg.block_pattern)
    return pat[: cfg.num_layers - (cfg.num_layers // len(pat)) * len(pat)]


# ---------------------------------------------------------------------------
# Decode (single-token serve step) + cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    """Abstract-safe cache init. Returns (cache, logical_axes)."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        G = cfg.num_layers // len(pat)
        tail = _pattern_tail(cfg)

        def group_cache(n, pattern):
            c, a = {}, {}
            for i, kind in enumerate(pattern):
                name = f"b{i}_{kind}"
                if kind == "attention":
                    W = cfg.window_size or max_len
                    T = min(W, max_len) if cfg.window_size else max_len
                    c[name] = {
                        "k": jnp.zeros((n, batch, T, KV, hd), cache_dtype),
                        "v": jnp.zeros((n, batch, T, KV, hd), cache_dtype)}
                    a[name] = {
                        "k": ("layers", "batch", None, "kv_heads", "head_dim"),
                        "v": ("layers", "batch", None, "kv_heads", "head_dim")}
                elif kind == "recurrent":
                    c[name] = {
                        "conv": jnp.zeros((n, batch, cfg.conv_width - 1, cfg.d_rnn), jnp.float32),
                        "h": jnp.zeros((n, batch, cfg.d_rnn), jnp.float32)}
                    a[name] = {"conv": ("layers", "batch", None, "rnn"),
                               "h": ("layers", "batch", "rnn")}
                elif kind == "mlstm":
                    H = cfg.num_heads
                    c[name] = {
                        "conv": jnp.zeros((n, batch, cfg.conv_width - 1, cfg.d_model), jnp.float32),
                        "C": jnp.zeros((n, batch, H, hd, hd), jnp.float32),
                        "n": jnp.zeros((n, batch, H, hd), jnp.float32),
                        "m": jnp.full((n, batch, H), -1e30, jnp.float32)}
                    a[name] = {"conv": ("layers", "batch", None, "inner"),
                               "C": ("layers", "batch", "heads", "head_dim", None),
                               "n": ("layers", "batch", "heads", "head_dim"),
                               "m": ("layers", "batch", "heads")}
                elif kind == "slstm":
                    D = cfg.d_model
                    c[name] = {
                        "conv": jnp.zeros((n, batch, cfg.conv_width - 1, D), jnp.float32),
                        "c": jnp.zeros((n, batch, D), jnp.float32),
                        "n2": jnp.zeros((n, batch, D), jnp.float32),
                        "h": jnp.zeros((n, batch, D), jnp.float32),
                        "m": jnp.full((n, batch, D), -1e30, jnp.float32)}
                    a[name] = {"conv": ("layers", "batch", None, "inner"),
                               "c": ("layers", "batch", "inner"),
                               "n2": ("layers", "batch", "inner"),
                               "h": ("layers", "batch", "inner"),
                               "m": ("layers", "batch", "inner")}
            return c, a

        cache, axes = {}, {}
        cache["groups"], axes["groups"] = group_cache(G, pat)
        if tail:
            cache["tail"], axes["tail"] = group_cache(1, tail)
        return cache, axes

    L = cfg.num_layers
    cache = {"k": jnp.zeros((L, batch, max_len, KV, hd), cache_dtype),
             "v": jnp.zeros((L, batch, max_len, KV, hd), cache_dtype)}
    axes = {"k": ("layers", "batch", None, "kv_heads", "head_dim"),
            "v": ("layers", "batch", None, "kv_heads", "head_dim")}
    return cache, axes


def _attn_decode(cfg, lp, x, kc, vc, sin, cos, pos, ctx, window: int = 0):
    """One attention block, single token. kc/vc: (B,T,KV,hd). Returns
    (y, kc_new, vc_new)."""
    h = nn.rms_norm(x, lp["ln1"] if "ln1" in lp else lp["ln"], cfg.norm_eps)
    ap = lp["attn"] if "attn" in lp else lp["core"]
    q, k, v = nn.qkv_project(cfg, ap, h)
    q = nn.apply_rope(q, sin, cos)
    k = nn.apply_rope(k, sin, cos)
    # Decode layout must FOLLOW the cache layout (gathering a 32k-token KV
    # cache per step would dwarf the step itself). With GQA kv < TP the cache
    # shards head_dim over the model axis, so q/k/v take head_dim sharding
    # and the QK^T partial products all-reduce only (B,1,T)-sized logits.
    tp = ctx.tp_size() if ctx is not None else 1
    if k.shape[2] % tp == 0:
        q = _act(ctx, q, "batch", None, "heads", None)
        k = _act(ctx, k, "batch", None, "kv_heads", None)
        v = _act(ctx, v, "batch", None, "kv_heads", None)
    else:
        q = _act(ctx, q, "batch", None, None, "head_dim")
        k = _act(ctx, k, "batch", None, None, "head_dim")
        v = _act(ctx, v, "batch", None, None, "head_dim")
    kc, vc = nn.cache_update(kc, vc, k, v, pos, window=window)
    o = nn.decode_attention(q, kc, vc, pos, window=window)
    o = _act(ctx, o, "batch", None, None, None)
    o = nn.out_project(cfg, ap, o)
    return x + _act(ctx, o, "batch", None, None), kc, vc


def lm_decode_step(cfg, params, cache, tokens, pos, ctx=None):
    """One serve step. tokens: (B,) int32; pos: scalar int32 (0-based absolute
    position of this token). Returns (logits (B,V), new_cache)."""
    x = nn.embed_tokens(cfg, params["embed"], tokens[:, None])   # (B,1,D)
    x = _act(ctx, x, "batch", None, None)
    sin, cos = nn.rope_tables(pos[None] if jnp.ndim(pos) == 0 else pos,
                              cfg.head_dim, cfg.rope_theta)

    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)

        def make_gbody(pattern):
            def gbody(carry, sl):
                gp, gc = sl
                y = carry
                gc_new = {}
                for i, kind in enumerate(pattern):
                    name = f"b{i}_{kind}"
                    lp, c = gp[name], gc[name]
                    if kind == "attention":
                        y, kc, vc = _attn_decode(
                            cfg, {"ln": lp["ln"], "core": lp["core"]},
                            y, c["k"], c["v"], sin, cos, pos,
                            ctx, window=cfg.window_size)
                        gc_new[name] = {"k": kc, "v": vc}
                        if "mlp" in lp:
                            y, _ = _mlp_sub(cfg, lp, y, ctx)
                    elif kind == "recurrent":
                        h = nn.rms_norm(y, lp["ln"], cfg.norm_eps)
                        o, (cs, hs) = rec_lib.recurrent_block(
                            cfg, lp["core"], h,
                            conv_state=c["conv"], h_state=c["h"], decode=True)
                        y = y + o
                        gc_new[name] = {"conv": cs, "h": hs}
                        if "mlp" in lp:
                            y, _ = _mlp_sub(cfg, lp, y, ctx)
                    elif kind == "mlstm":
                        h = nn.rms_norm(y, lp["ln"], cfg.norm_eps)
                        o, (cs, cell) = xlstm_lib.mlstm_block(
                            cfg, lp["core"], h,
                            state=(c["conv"], (c["C"], c["n"], c["m"])),
                            decode=True)
                        y = y + o
                        gc_new[name] = {"conv": cs, "C": cell[0],
                                        "n": cell[1], "m": cell[2]}
                    elif kind == "slstm":
                        h = nn.rms_norm(y, lp["ln"], cfg.norm_eps)
                        o, (cs, cell) = xlstm_lib.slstm_block(
                            cfg, lp["core"], h,
                            state=(c["conv"], (c["c"], c["n2"], c["h"], c["m"])),
                            decode=True)
                        y = y + o
                        gc_new[name] = {"conv": cs, "c": cell[0], "n2": cell[1],
                                        "h": cell[2], "m": cell[3]}
                return y, gc_new
            return gbody

        x, groups_new = jax.lax.scan(make_gbody(pat), x,
                                     (params["groups"], cache["groups"]))
        cache_new = {"groups": groups_new}
        if "tail" in params:
            x, tail_new = jax.lax.scan(make_gbody(_pattern_tail(cfg)), x,
                                       (params["tail"], cache["tail"]))
            cache_new["tail"] = tail_new
    else:
        # The KV cache is a loop CARRY updated in place with
        # dynamic_update_index (single buffer), NOT a scan xs->ys pair —
        # the xs/ys form double-buffers the multi-GB cache (§Perf C8).
        L = cfg.num_layers

        def body(carry, sl):
            y, kcache, vcache = carry
            lp, li = sl
            kc = jax.lax.dynamic_index_in_dim(kcache, li, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vcache, li, 0, keepdims=False)
            y, kc2, vc2 = _attn_decode(cfg, lp, y, kc, vc, sin, cos, pos, ctx)
            y, _ = _mlp_sub(cfg, lp, y, ctx)
            kcache = jax.lax.dynamic_update_index_in_dim(
                kcache, kc2.astype(kcache.dtype), li, 0)
            vcache = jax.lax.dynamic_update_index_in_dim(
                vcache, vc2.astype(vcache.dtype), li, 0)
            return (y, kcache, vcache), None

        (x, k_new, v_new), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(L)))
        cache_new = {"k": k_new, "v": v_new}

    x = nn.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = nn.logits_from_hidden(cfg, params["embed"], x)[:, 0, :]
    logits = _act(ctx, logits, "batch", "vocab")
    return logits, cache_new


def _hybrid_group_prefill(cfg, gp, x, sin, cos, ctx, pattern, cache_dtype):
    """One pattern group over the full prompt, returning decode states."""
    states = {}
    W = cfg.window_size
    for i, kind in enumerate(pattern):
        name = f"b{i}_{kind}"
        lp = gp[name]
        if kind == "attention":
            h = nn.rms_norm(x, lp["ln"], cfg.norm_eps)
            q, k, v = nn.qkv_project(cfg, lp["core"], h)
            q = nn.apply_rope(q, sin, cos)
            k = nn.apply_rope(k, sin, cos)
            o = _attention_dispatch(cfg, q, k, v, W)
            x = x + nn.out_project(cfg, lp["core"], o)
            if "mlp" in lp:
                x, _ = _mlp_sub(cfg, lp, x, ctx)
            S = k.shape[1]
            T = min(W or S, S)
            # ring alignment holds when S % W == 0 (all assigned shapes)
            states[name] = {"k": k[:, -T:].astype(cache_dtype),
                            "v": v[:, -T:].astype(cache_dtype)}
        elif kind == "recurrent":
            h = nn.rms_norm(x, lp["ln"], cfg.norm_eps)
            o, (cs, hs) = rec_lib.recurrent_block(cfg, lp["core"], h)
            x = x + o
            states[name] = {"conv": cs.astype(jnp.float32), "h": hs}
            if "mlp" in lp:
                x, _ = _mlp_sub(cfg, lp, x, ctx)
        elif kind == "mlstm":
            h = nn.rms_norm(x, lp["ln"], cfg.norm_eps)
            o, (cs, cell) = xlstm_lib.mlstm_block(cfg, lp["core"], h)
            x = x + o
            states[name] = {"conv": cs.astype(jnp.float32), "C": cell[0],
                            "n": cell[1], "m": cell[2]}
        elif kind == "slstm":
            h = nn.rms_norm(x, lp["ln"], cfg.norm_eps)
            o, (cs, cell) = xlstm_lib.slstm_block(cfg, lp["core"], h)
            x = x + o
            states[name] = {"conv": cs.astype(jnp.float32), "c": cell[0],
                            "n2": cell[1], "h": cell[2], "m": cell[3]}
    return x, states


def lm_prefill(cfg, params, tokens, max_len: int, ctx=None,
               frontend_embeds=None, cache_dtype=jnp.bfloat16,
               lengths=None):
    """Prefill: run the trunk over the prompt and build the decode cache.
    Returns (last_logits (B,V), cache).

    `lengths` (B,) enables RIGHT-PADDED prompts (runtime/prefill.py bucket
    padding): the last-hidden gather happens at each row's true final
    position instead of S-1. Only the dense/window-free family supports it —
    causal attention means real positions never attend pad columns, and the
    decode-time mask (`slots <= pos` with pos starting at the true length)
    keeps the pad garbage written beyond `lengths` in the KV cache forever
    unobservable: decode overwrites slot `pos` BEFORE attending it. Stateful
    families (recurrent/ssm/xlstm scans fold every position into their
    state) and ring-buffer window caches cannot skip padding, so `lengths`
    raises there rather than silently corrupting."""
    B = tokens.shape[0]
    if cfg.block_pattern:
        if lengths is not None:
            raise NotImplementedError(
                "length-gathered (right-padded) prefill needs positions to "
                "be skippable; recurrent/ssm/window states fold every "
                "position in — pad-to-bucket is dense-family only")
        x = nn.embed_tokens(cfg, params["embed"], tokens)
        x = _act(ctx, x, "batch", "seq", None)
        S = x.shape[1]
        sin, cos = nn.rope_tables(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
        pat = tuple(cfg.block_pattern)

        def make_gbody(pattern):
            def gbody(carry, gp):
                return _hybrid_group_prefill(cfg, gp, carry, sin, cos, ctx,
                                             pattern, cache_dtype)
            return gbody

        x, groups_state = jax.lax.scan(make_gbody(pat), x, params["groups"])
        cache = {"groups": groups_state}
        if "tail" in params:
            x, tail_state = jax.lax.scan(make_gbody(_pattern_tail(cfg)), x,
                                         params["tail"])
            cache["tail"] = tail_state
        x = nn.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = nn.logits_from_hidden(cfg, params["embed"], x[:, -1:, :])[:, 0, :]
        return logits, cache

    if lengths is not None and cfg.window_size:
        raise NotImplementedError(
            "length-gathered prefill is incompatible with ring-buffer "
            "window caches: pad entries would wrap onto real slots")
    h, kv, _ = lm_hidden(cfg, params, tokens, ctx, frontend_embeds,
                         collect_kv=True)
    cache, _ = init_cache(cfg, B, max_len, cache_dtype)
    k, v = kv   # (L, B, S, KV, hd)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache_dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache_dtype), (0, 0, 0, 0, 0))
    if lengths is None:
        h_last = h[:, -1:, :]
    else:
        P = frontend_embeds.shape[1] if frontend_embeds is not None else 0
        idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1 + P, 0,
                       h.shape[1] - 1)
        h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    logits = nn.logits_from_hidden(cfg, params["embed"], h_last)[:, 0, :]
    return logits, cache


def lm_loss(cfg, params, batch, ctx=None):
    """batch: {"tokens": (B,S), "targets": (B,S), ["frontend_embeds"]}.

    Loss over text positions only (frontend positions excluded). Long
    sequences stream the head+CE over seq chunks so (B,S,V) logits never
    materialize."""
    fe = batch.get("frontend_embeds")
    h, _, aux = lm_hidden(cfg, params, batch["tokens"], ctx, fe)
    if fe is not None:
        h = h[:, fe.shape[1]:, :]     # text positions only
    if h.shape[1] > nn.CE_CHUNK:
        # gather the (bf16) hidden over seq ONCE before the CE scan — the
        # scan slices seq, and slicing a seq-sharded tensor reshards per step
        h = _act(ctx, h, "batch", None, None)
        loss = nn.chunked_cross_entropy(cfg, params["embed"], h,
                                        batch["targets"])
    else:
        logits = nn.logits_from_hidden(cfg, params["embed"], h)
        logits = _act(ctx, logits, "batch", "seq", "vocab")
        loss = nn.cross_entropy_loss(logits, batch["targets"])
    metrics = {"loss": loss}
    for k, v in aux.items():
        metrics[k] = v
    if "moe_aux" in aux:
        loss = loss + 0.01 * aux["moe_aux"]
    return loss, metrics
