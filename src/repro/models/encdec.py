"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech frontend is a STUB per the task spec: the encoder consumes
precomputed frame embeddings (B, S_enc, D) supplied by input_specs(). The
decoder is a standard causal transformer with cross-attention; decode uses a
self-attention KV cache plus a cross-attention cache computed once from the
encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as nn
from repro.models.transformer import ShardCtx, _act, _remat


def init_encdec(key, cfg):
    ks = jax.random.split(key, 8)
    pdt = jnp.dtype(cfg.param_dtype)
    emb_p, emb_ax = nn.init_embedding(ks[0], cfg)

    def stack(key, L, cross: bool):
        k1, k2, k3 = jax.random.split(key, 3)
        attn_p, attn_ax = nn.init_attention(k1, cfg, layers=L)
        mlp_p, mlp_ax = nn.init_mlp(k2, cfg, layers=L)
        p = {"attn": attn_p, "mlp": mlp_p,
             "ln1": jnp.zeros((L, cfg.d_model), pdt),
             "ln2": jnp.zeros((L, cfg.d_model), pdt)}
        ax = {"attn": attn_ax, "mlp": mlp_ax,
              "ln1": ("layers", "embed"), "ln2": ("layers", "embed")}
        if cross:
            xp, xax = nn.init_attention(k3, cfg, layers=L)
            p["xattn"] = xp
            p["lnx"] = jnp.zeros((L, cfg.d_model), pdt)
            ax["xattn"] = xax
            ax["lnx"] = ("layers", "embed")
        return p, ax

    enc_p, enc_ax = stack(ks[1], cfg.encoder_layers, cross=False)
    dec_p, dec_ax = stack(ks[2], cfg.num_layers, cross=True)
    params = {
        "embed": emb_p,
        "encoder": {"layers": enc_p, "final_ln": jnp.zeros((cfg.d_model,), pdt)},
        "decoder": {"layers": dec_p, "final_ln": jnp.zeros((cfg.d_model,), pdt)},
    }
    axes = {
        "embed": emb_ax,
        "encoder": {"layers": enc_ax, "final_ln": ("embed",)},
        "decoder": {"layers": dec_ax, "final_ln": ("embed",)},
    }
    return params, axes


def encode(cfg, params, frames, ctx=None):
    """frames: (B, S_enc, D) precomputed frontend embeddings -> (B, S_enc, D)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = _act(ctx, x, "batch", "seq", None)
    S = x.shape[1]
    sin, cos = nn.rope_tables(jnp.arange(S), cfg.head_dim, cfg.rope_theta)

    def body(carry, lp):
        h = nn.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        q, k, v = nn.qkv_project(cfg, lp["attn"], h)
        q = nn.apply_rope(q, sin, cos)
        k = nn.apply_rope(k, sin, cos)
        o = nn.causal_attention(q, k, v, causal=False)   # bidirectional
        y = carry + nn.out_project(cfg, lp["attn"], o)
        h2 = nn.rms_norm(y, lp["ln2"], cfg.norm_eps)
        y = y + _act(ctx, nn.mlp(cfg, lp["mlp"], h2), "batch", "seq", None)
        return y, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["encoder"]["layers"])
    return nn.rms_norm(x, params["encoder"]["final_ln"], cfg.norm_eps)


def _decoder_hidden(cfg, params, tokens, enc_out, ctx=None, collect_kv=False):
    x = nn.embed_tokens(cfg, params["embed"], tokens)
    x = _act(ctx, x, "batch", "seq", None)
    S = x.shape[1]
    sin, cos = nn.rope_tables(jnp.arange(S), cfg.head_dim, cfg.rope_theta)

    def body(carry, lp):
        # self attention (causal; chunked for long decoder sequences)
        from repro.models.transformer import _attention_dispatch
        h = nn.rms_norm(carry, lp["ln1"], cfg.norm_eps)
        q, k, v = nn.qkv_project(cfg, lp["attn"], h)
        q = nn.apply_rope(q, sin, cos)
        k = nn.apply_rope(k, sin, cos)
        o = _attention_dispatch(cfg, q, k, v)
        y = carry + nn.out_project(cfg, lp["attn"], o)
        # cross attention
        hx = nn.rms_norm(y, lp["lnx"], cfg.norm_eps)
        qx, _, _ = nn.qkv_project(cfg, lp["xattn"], hx)
        _, kx, vx = nn.qkv_project(cfg, lp["xattn"], enc_out)
        ox = nn.causal_attention(qx, kx, vx, causal=False)
        y = y + nn.out_project(cfg, lp["xattn"], ox)
        # mlp
        h2 = nn.rms_norm(y, lp["ln2"], cfg.norm_eps)
        y = y + _act(ctx, nn.mlp(cfg, lp["mlp"], h2), "batch", "seq", None)
        out = (k, v) if collect_kv else None
        return y, out

    x, kv = jax.lax.scan(_remat(cfg, body), x, params["decoder"]["layers"])
    x = nn.rms_norm(x, params["decoder"]["final_ln"], cfg.norm_eps)
    return x, (kv if collect_kv else None)


def encdec_loss(cfg, params, batch, ctx=None):
    """batch: {"frontend_embeds": (B,S_enc,D), "tokens": (B,S), "targets"}."""
    enc_out = encode(cfg, params, batch["frontend_embeds"], ctx)
    h, _ = _decoder_hidden(cfg, params, batch["tokens"], enc_out, ctx)
    if h.shape[1] > nn.CE_CHUNK:
        loss = nn.chunked_cross_entropy(cfg, params["embed"], h,
                                        batch["targets"])
    else:
        logits = nn.logits_from_hidden(cfg, params["embed"], h)
        logits = _act(ctx, logits, "batch", "seq", "vocab")
        loss = nn.cross_entropy_loss(logits, batch["targets"])
    return loss, {"loss": loss}


def init_encdec_cache(cfg, batch: int, max_len: int, cache_dtype=jnp.bfloat16):
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    Se = cfg.frontend_seq
    cache = {
        "k": jnp.zeros((L, batch, max_len, KV, hd), cache_dtype),
        "v": jnp.zeros((L, batch, max_len, KV, hd), cache_dtype),
        "xk": jnp.zeros((L, batch, Se, KV, hd), cache_dtype),
        "xv": jnp.zeros((L, batch, Se, KV, hd), cache_dtype),
    }
    ax = ("layers", "batch", None, "kv_heads", "head_dim")
    return cache, {"k": ax, "v": ax, "xk": ax, "xv": ax}


def encdec_prefill(cfg, params, frames, tokens, max_len: int, ctx=None,
                   cache_dtype=jnp.bfloat16):
    """Encode + decoder prefill. Returns (last_logits, cache)."""
    enc_out = encode(cfg, params, frames, ctx)
    B = tokens.shape[0]
    cache, _ = init_encdec_cache(cfg, B, max_len, cache_dtype)

    # cross-attention cache: (L, B, Se, KV, hd), computed once
    def xbody(_, lp):
        _, kx, vx = nn.qkv_project(cfg, lp["xattn"], enc_out)
        return None, (kx, vx)

    _, (xk, xv) = jax.lax.scan(xbody, None, params["decoder"]["layers"])
    cache["xk"] = xk.astype(cache_dtype)
    cache["xv"] = xv.astype(cache_dtype)

    h, kv = _decoder_hidden(cfg, params, tokens, enc_out, ctx, collect_kv=True)
    k, v = kv
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache_dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache_dtype), (0, 0, 0, 0, 0))
    logits = nn.logits_from_hidden(cfg, params["embed"], h[:, -1:, :])[:, 0, :]
    return logits, cache


def encdec_decode_step(cfg, params, cache, tokens, pos, ctx=None):
    """One decoder step. tokens: (B,); pos: scalar int32."""
    x = nn.embed_tokens(cfg, params["embed"], tokens[:, None])
    sin, cos = nn.rope_tables(pos[None] if jnp.ndim(pos) == 0 else pos,
                              cfg.head_dim, cfg.rope_theta)

    def body(carry, sl):
        y, kcache, vcache = carry
        lp, xk, xv, li = sl
        kc = jax.lax.dynamic_index_in_dim(kcache, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vcache, li, 0, keepdims=False)
        h = nn.rms_norm(y, lp["ln1"], cfg.norm_eps)
        q, k, v = nn.qkv_project(cfg, lp["attn"], h)
        q = nn.apply_rope(q, sin, cos)
        k = nn.apply_rope(k, sin, cos)
        kc, vc = nn.cache_update(kc, vc, k, v, pos)
        o = nn.decode_attention(q, kc, vc, pos)
        y = y + nn.out_project(cfg, lp["attn"], o)
        hx = nn.rms_norm(y, lp["lnx"], cfg.norm_eps)
        qx, _, _ = nn.qkv_project(cfg, lp["xattn"], hx)
        ox = nn.decode_attention(qx, xk, xv, jnp.asarray(xk.shape[1] - 1))
        y = y + nn.out_project(cfg, lp["xattn"], ox)
        h2 = nn.rms_norm(y, lp["ln2"], cfg.norm_eps)
        y = y + nn.mlp(cfg, lp["mlp"], h2)
        kcache = jax.lax.dynamic_update_index_in_dim(
            kcache, kc.astype(kcache.dtype), li, 0)
        vcache = jax.lax.dynamic_update_index_in_dim(
            vcache, vc.astype(vcache.dtype), li, 0)
        return (y, kcache, vcache), None

    (x, k_new, v_new), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["decoder"]["layers"], cache["xk"], cache["xv"],
         jnp.arange(cfg.num_layers)))
    x = nn.rms_norm(x, params["decoder"]["final_ln"], cfg.norm_eps)
    logits = nn.logits_from_hidden(cfg, params["embed"], x)[:, 0, :]
    cache_new = dict(cache)
    cache_new["k"] = k_new
    cache_new["v"] = v_new
    return logits, cache_new
