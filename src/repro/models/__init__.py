from repro.models.model import Model, Probe, build_model, count_params_analytic

__all__ = ["Model", "Probe", "build_model", "count_params_analytic"]
