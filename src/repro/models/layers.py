"""Core layer library (pure functional JAX).

Conventions:
  * params are nested dicts of jnp arrays; a "stacked" layer dict has a
    leading ``num_layers`` axis on every leaf (consumed by lax.scan).
  * every init_* returns (params, logical_axes) where logical_axes mirrors
    params with tuples of logical axis names (see repro.sharding).
  * compute dtype = cfg.dtype (bf16 on TPU); master params = cfg.param_dtype.
  * attention is exact (einsum, f32 softmax); the Pallas flash kernel in
    repro.kernels is an alternative impl selected by cfg.attention_impl.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, scale: float):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, d_in: int, shape_out: Tuple[int, ...], dtype,
                scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return normal_init(key, (d_in, *shape_out), dtype, scale)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_tables(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> (sin, cos) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (B, S, H, hd); sin/cos: (S, hd//2) or broadcastable (B, S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # (S, half) -> broadcast over batch and heads
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:              # (B, S, half)
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg, layers: Optional[int] = None):
    """GQA attention params; stacked over ``layers`` when given."""
    ks = jax.random.split(key, 8)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = (layers,) if layers else ()
    pdt = _pdt(cfg)

    def mk(k, shape, fan_in):
        return normal_init(k, L + shape, pdt, 1.0 / math.sqrt(fan_in))

    p = {
        "wq": mk(ks[0], (D, H, hd), D),
        "wk": mk(ks[1], (D, KV, hd), D),
        "wv": mk(ks[2], (D, KV, hd), D),
        "wo": mk(ks[3], (H, hd, D), H * hd),
    }
    lax_pref = ("layers",) if layers else ()
    ax = {
        "wq": lax_pref + ("embed", "heads", "head_dim"),
        "wk": lax_pref + ("embed", "kv_heads", "head_dim"),
        "wv": lax_pref + ("embed", "kv_heads", "head_dim"),
        "wo": lax_pref + ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(L + (H, hd), pdt)
        p["bk"] = jnp.zeros(L + (KV, hd), pdt)
        p["bv"] = jnp.zeros(L + (KV, hd), pdt)
        ax["bq"] = lax_pref + ("heads", "head_dim")
        ax["bk"] = lax_pref + ("kv_heads", "head_dim")
        ax["bv"] = lax_pref + ("kv_heads", "head_dim")
    return p, ax


def qkv_project(cfg, p, x):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd) in compute dtype."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def out_project(cfg, p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q: (B,S,H,hd) k: (B,T,KV,hd) -> logits (B,KV,G,S,T) in f32."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def _gqa_out(w, v, out_dtype):
    """w: (B,KV,G,S,T) f32; v: (B,T,KV,hd) -> (B,S,H,hd)."""
    B, KV, G, S, T = w.shape
    o = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return o.reshape(B, S, KV * G, v.shape[-1]).astype(out_dtype)


def causal_attention(q, k, v, *, causal: bool = True,
                     positions_q=None, positions_k=None):
    """Exact attention with f32 softmax. q:(B,S,H,hd) k,v:(B,T,KV,hd)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = _gqa_scores(q, k, scale)          # (B,KV,G,S,T)
    if causal:
        S, T = logits.shape[-2], logits.shape[-1]
        pq = positions_q if positions_q is not None else jnp.arange(S)
        pk = positions_k if positions_k is not None else jnp.arange(T)
        mask = pq[:, None] >= pk[None, :]
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(w, v, q.dtype)


def sliding_window_attention(q, k, v, window: int):
    """Blocked local (sliding-window, causal) attention.

    Memory is O(S * 2w) instead of O(S^2): the sequence is cut into blocks of
    ``window`` and each block attends to itself + the previous block with the
    exact band mask. Requires S % window == 0 (all assigned shapes satisfy
    this; input_specs pads otherwise).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if S <= window:
        return causal_attention(q, k, v)
    assert S % window == 0, (S, window)
    nb = S // window
    scale = 1.0 / math.sqrt(hd)
    G = H // KV

    qb = q.reshape(B, nb, window, KV, G, hd)
    kb = k.reshape(B, nb, window, KV, hd)
    vb = v.reshape(B, nb, window, KV, hd)
    # previous block of k/v (block 0's "previous" is zeros, fully masked)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kcat = jnp.concatenate([k_prev, kb], axis=2)   # (B, nb, 2w, KV, hd)
    vcat = jnp.concatenate([v_prev, vb], axis=2)

    logits = jnp.einsum("bnskgh,bntkh->bnkgst", qb.astype(jnp.float32),
                        kcat.astype(jnp.float32)) * scale  # (B,nb,KV,G,w,2w)
    qpos = jnp.arange(window)[:, None] + window          # query pos within [w, 2w)
    kpos = jnp.arange(2 * window)[None, :]               # key pos within [0, 2w)
    band = (qpos >= kpos) & (qpos - kpos < window)       # causal & within window
    first = (jnp.arange(nb) == 0)[:, None, None]         # block 0 has no prev block
    mask = band[None, :, :] & ~(first & (kpos < window)[None, :, :])  # (nb, w, 2w)
    logits = jnp.where(mask[None, :, None, None, :, :], logits, -1e30)
    w_ = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bnkgst,bntkh->bnskgh", w_, vcat.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


_CHUNK_Q = 512           # default q-chunk for the XLA flash path
_CHUNK_K = 1024
CHUNKED_THRESHOLD = 2048  # use chunked attention when S exceeds this


def _flash_kv_body(carry, xs, scale):
    """Inner (k-block) step of XLA-expressed flash attention — also a
    dry-run cost probe. carry=(m,l,acc); xs=(k_blk,v_blk,s_blk,q_blk,qpos)."""
    m, l, acc = carry
    kb, vb, kpos, qb, qpos = xs
    s = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32),
                   kb.astype(jnp.float32)) * scale
    mask = qpos[..., :, None] >= kpos[..., None, :]
    s = jnp.where(mask[:, None], s, -1e30)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
    return (m_new, l_new, acc_new), None


def chunked_causal_attention(q, k, v, *, q_chunk: int = _CHUNK_Q,
                             k_chunk: int = _CHUNK_K):
    """Flash attention expressed in XLA scans (GSPMD-shardable): outer scan
    over q chunks, inner scan over k chunks, online-softmax carry. Memory is
    O(q_chunk * k_chunk) per step instead of O(S^2).

    q: (B,S,H,hd); k/v: (B,S,KV,hd). Exact vs mha oracle. NB: the inner scan
    visits every k block (no causal block skipping in XLA) — the compiled
    FLOPs overcount causal attention ~2x; the roofline report corrects for
    this analytically and the Pallas kernel path skips for real on TPU."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, S)
    kc = min(k_chunk, S)
    pS = (-S) % qc
    pK = (-S) % kc

    # head-major layout, GQA expanded per q head group index
    qt = q.transpose(0, 2, 1, 3)                               # (B,H,S,hd)
    kt = k.transpose(0, 2, 1, 3)                               # (B,KV,S,hd)
    vt = v.transpose(0, 2, 1, 3)
    if pS:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pS), (0, 0)))
    if pK:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pK), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pK), (0, 0)))
    Sq, Sk = qt.shape[2], kt.shape[2]
    nq, nk = Sq // qc, Sk // kc

    kb = kt.reshape(B, KV, nk, kc, hd).transpose(2, 0, 1, 3, 4)  # (nk,B,KV,kc,hd)
    vb = vt.reshape(B, KV, nk, kc, hd).transpose(2, 0, 1, 3, 4)
    kpos = (jnp.arange(Sk).reshape(nk, 1, kc)
            + jnp.zeros((nk, B, kc), jnp.int32))                  # (nk,B,kc)
    kpos = jnp.where(kpos < S, kpos, jnp.int32(2**30))            # pad = +inf pos

    def q_body(_, qxs):
        qblk, qpos = qxs                                          # (B,H,qc,hd)
        qg = qblk.reshape(B, KV, G, qc, hd).reshape(B, KV * G, qc, hd)
        m0 = jnp.full((B, H, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, hd), jnp.float32)

        def kv_body(carry, kxs):
            kblk, vblk, kp = kxs
            kg = jnp.repeat(kblk, G, axis=1)                      # (B,H,kc,hd)
            vg = jnp.repeat(vblk, G, axis=1)
            return _flash_kv_body(carry, (kg, vg, kp, qg, qpos), scale)

        # flash bwd semantics: recompute p in backward instead of saving the
        # (qc, kc) probability tiles per step (otherwise the scan stashes the
        # full S^2 matrix as residuals and the memory win evaporates)
        kv_body = jax.checkpoint(
            kv_body, policy=jax.checkpoint_policies.nothing_saveable)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kb, vb, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    qblocks = qt.reshape(B, H, nq, qc, hd).transpose(2, 0, 1, 3, 4)
    qpos = (jnp.arange(Sq).reshape(nq, 1, qc)
            + jnp.zeros((nq, B, qc), jnp.int32))
    q_body = jax.checkpoint(
        q_body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(q_body, None, (qblocks, qpos))          # (nq,B,H,qc,hd)
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, hd)
    return out[:, :, :S, :].transpose(0, 2, 1, 3)


def chunked_window_attention(q, k, v, window: int, *, q_chunk: int = _CHUNK_Q):
    """Exact sliding-window attention, linear in S: each q chunk attends to a
    statically-sized k slice [chunk_start - window, chunk_end). No masked-out
    block overcount (the slice is exactly the live range)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, S)
    pS = (-S) % qc
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if pS:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pS), (0, 0)))
    Sq = qt.shape[2]
    nq = Sq // qc
    span = window + qc                       # k live range per q chunk
    # left-pad k/v by `window` so the slice start is simply i*qc
    ktp = jnp.pad(kt, ((0, 0), (0, 0), (window, pS), (0, 0)))
    vtp = jnp.pad(vt, ((0, 0), (0, 0), (window, pS), (0, 0)))

    def q_body(_, xs):
        i = xs
        qblk = jax.lax.dynamic_slice_in_dim(qt, i * qc, qc, axis=2)
        kblk = jax.lax.dynamic_slice_in_dim(ktp, i * qc, span, axis=2)
        vblk = jax.lax.dynamic_slice_in_dim(vtp, i * qc, span, axis=2)
        qpos = i * qc + jnp.arange(qc)
        kpos = i * qc - window + jnp.arange(span)
        qg = qblk.reshape(B, KV, G, qc, hd)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        mask = ((qpos[:, None] >= kpos[None, :])
                & (qpos[:, None] - kpos[None, :] < window)
                & (kpos[None, :] >= 0) & (qpos[:, None] < S))
        s = jnp.where(mask[None, None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqc,bkcd->bkgqd", w, vblk.astype(jnp.float32))
        return None, o.reshape(B, H, qc, hd).astype(q.dtype)

    q_body = jax.checkpoint(
        q_body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(q_body, None, jnp.arange(nq))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, hd)
    return out[:, :, :S, :].transpose(0, 2, 1, 3)


# -- dry-run cost probes for the chunked-attention scan bodies ----------------
# (cost-equivalent mirrors of the scan bodies above: same einsum/mask shapes,
#  so compiled FLOPs/bytes match the in-loop bodies exactly)

def flash_kvbody_probe(m, l, acc, kblk, vblk, kp, qblk, qpos):
    """One inner (k-block) step incl. the GQA repeat. kblk: (B,KV,kc,hd);
    qblk: (B,H,qc,hd)."""
    G = qblk.shape[1] // kblk.shape[1]
    kg = jnp.repeat(kblk, G, axis=1)
    vg = jnp.repeat(vblk, G, axis=1)
    scale = 1.0 / math.sqrt(qblk.shape[-1])
    (m2, l2, a2), _ = _flash_kv_body((m, l, acc), (kg, vg, kp, qblk, qpos), scale)
    return m2, l2, a2


def flash_qbody_probe(qblk, kb, vb, kpos, qpos):
    """One outer (q-chunk) step: inner scan over all k blocks (counted once
    by HLO cost analysis, exactly like the real program's nesting).
    kb: (nk,B,KV,kc,hd)."""
    B, H, qc, hd = qblk.shape
    KV = kb.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    m0 = jnp.full((B, H, qc), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, qc), jnp.float32)
    a0 = jnp.zeros((B, H, qc, hd), jnp.float32)

    def kv_body(carry, kxs):
        kblk, vblk, kp = kxs
        kg = jnp.repeat(kblk, G, axis=1)
        vg = jnp.repeat(vblk, G, axis=1)
        return _flash_kv_body(carry, (kg, vg, kp, qblk, qpos), scale)

    (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kb, vb, kpos))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qblk.dtype)


def window_qbody_probe(qt, ktp, vtp, idx, window: int):
    """One q-chunk step of chunked_window_attention. qt: (B,H,Sq,hd);
    ktp/vtp: (B,KV,Sq+window,hd) (pre-padded)."""
    B, H, Sq, hd = qt.shape
    KV = ktp.shape[1]
    G = H // KV
    qc = min(_CHUNK_Q, Sq)
    span = window + qc
    scale = 1.0 / math.sqrt(hd)
    qblk = jax.lax.dynamic_slice_in_dim(qt, idx * qc, qc, axis=2)
    kblk = jax.lax.dynamic_slice_in_dim(ktp, idx * qc, span, axis=2)
    vblk = jax.lax.dynamic_slice_in_dim(vtp, idx * qc, span, axis=2)
    qpos = idx * qc + jnp.arange(qc)
    kpos = idx * qc - window + jnp.arange(span)
    qg = qblk.reshape(B, KV, G, qc, hd)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg.astype(jnp.float32),
                   kblk.astype(jnp.float32)) * scale
    mask = ((qpos[:, None] >= kpos[None, :])
            & (qpos[:, None] - kpos[None, :] < window) & (kpos[None, :] >= 0))
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", w, vblk.astype(jnp.float32))
    return o.reshape(B, H, qc, hd).astype(qt.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """Single-token decode. q: (B,1,H,hd); caches: (B,T,KV,hd); pos: scalar
    int32 (current position, 0-based). ``window>0`` -> ring-buffer cache of
    size ``window`` (local attention)."""
    B, _, H, hd = q.shape
    T = k_cache.shape[1]
    scale = 1.0 / math.sqrt(hd)
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale  # (B,KV,G,1,T)
    slots = jnp.arange(T)
    if window:
        # Ring buffer of T == window slots: once pos+1 >= window every slot
        # holds a live entry from the last `window` positions; before that,
        # only slots 0..pos have been written. (The current token is written
        # to slot pos % window *before* attention, so it attends to itself.)
        valid = jnp.where(pos + 1 >= T, jnp.ones((T,), bool), slots <= pos)
    else:
        valid = slots <= pos
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", w, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, pos, *, window: int = 0):
    """Insert one token's k/v at ``pos`` (ring slot ``pos % window`` if local)."""
    slot = jnp.where(window > 0, pos % jnp.maximum(window, 1), pos) if window else pos
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, layers: Optional[int] = None):
    D, F = cfg.d_model, cfg.d_ff
    L = (layers,) if layers else ()
    lax_pref = ("layers",) if layers else ()
    pdt = _pdt(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        p = {
            "w_gate": normal_init(ks[0], L + (D, F), pdt, 1.0 / math.sqrt(D)),
            "w_up":   normal_init(ks[1], L + (D, F), pdt, 1.0 / math.sqrt(D)),
            "w_down": normal_init(ks[2], L + (F, D), pdt, 1.0 / math.sqrt(F)),
        }
        ax = {
            "w_gate": lax_pref + ("embed", "mlp"),
            "w_up":   lax_pref + ("embed", "mlp"),
            "w_down": lax_pref + ("mlp", "embed"),
        }
    else:
        p = {
            "w_up":   normal_init(ks[0], L + (D, F), pdt, 1.0 / math.sqrt(D)),
            "b_up":   jnp.zeros(L + (F,), pdt),
            "w_down": normal_init(ks[1], L + (F, D), pdt, 1.0 / math.sqrt(F)),
            "b_down": jnp.zeros(L + (D,), pdt),
        }
        ax = {
            "w_up":   lax_pref + ("embed", "mlp"),
            "b_up":   lax_pref + ("mlp",),
            "w_down": lax_pref + ("mlp", "embed"),
            "b_down": lax_pref + ("embed",),
        }
    return p, ax


def mlp(cfg, p, x):
    dt = x.dtype
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt)) + p["b_up"].astype(dt)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt)) + p["b_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embedding(key, cfg):
    pdt = _pdt(cfg)
    ks = jax.random.split(key, 2)
    p = {"tok": normal_init(ks[0], (cfg.vocab_size, cfg.d_model), pdt, 0.02)}
    ax = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["head"] = normal_init(ks[1], (cfg.d_model, cfg.vocab_size), pdt,
                                1.0 / math.sqrt(cfg.d_model))
        ax["head"] = ("embed", "vocab")
    return p, ax


def embed_tokens(cfg, emb_p, tokens):
    return jnp.take(emb_p["tok"], tokens, axis=0).astype(_dt(cfg))


def logits_from_hidden(cfg, emb_p, h):
    if cfg.tie_embeddings:
        w = emb_p["tok"].astype(h.dtype)  # (V, D)
        return jnp.einsum("bsd,vd->bsv", h, w)
    return jnp.einsum("bsd,dv->bsv", h, emb_p["head"].astype(h.dtype))


def cross_entropy_loss(logits, targets, *, z_loss: float = 1e-4):
    """Token-mean CE with optional z-loss; logits may be vocab-sharded
    (GSPMD inserts the collective for the logsumexp)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse * lse)
    return loss


CE_CHUNK = 512      # seq chunk for the streamed head+CE path


def ce_chunk_body(carry, xs, w_or_emb, tied: bool):
    """One seq-chunk of the streamed cross-entropy (scan body + cost probe).

    Computes the head projection AND the CE for one chunk so the full
    (B, S, V) logits tensor never materializes — the production fix for the
    vocab-memory blowup (DESIGN.md §7). carry=(nll_sum, z_sum);
    xs=(h_chunk (B,c,D), tgt_chunk (B,c), valid (B,c))."""
    nll_sum, z_sum = carry
    h, tgt, valid = xs
    if tied:
        logits = jnp.einsum("bcd,vd->bcv", h, w_or_emb.astype(h.dtype))
    else:
        logits = jnp.einsum("bcd,dv->bcv", h, w_or_emb.astype(h.dtype))
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, tgt[..., None], axis=-1)[..., 0]
    m = valid.astype(jnp.float32)
    return (nll_sum + jnp.sum((lse - gold) * m),
            z_sum + jnp.sum(lse * lse * m)), None


def chunked_cross_entropy(cfg, emb_p, h, targets, *, chunk: int = CE_CHUNK,
                          z_loss: float = 1e-4):
    """Streamed head+CE over seq chunks. h: (B,S,D); targets: (B,S)."""
    B, S, D = h.shape
    c = min(chunk, S)
    pS = (-S) % c
    if pS:
        h = jnp.pad(h, ((0, 0), (0, pS), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pS)))
    n = h.shape[1] // c
    hs = h.reshape(B, n, c, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, c).transpose(1, 0, 2)
    valid = ((jnp.arange(h.shape[1]) < S).reshape(n, 1, c)
             + jnp.zeros((n, B, c), bool))
    w = emb_p["tok"] if cfg.tie_embeddings else emb_p["head"]

    def body(carry, xs):
        return ce_chunk_body(carry, xs, w, cfg.tie_embeddings)

    # recompute the chunk logits in backward — never stash (B,c,V) residuals
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (nll_sum, z_sum), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), (hs, ts, valid))
    n_tok = B * S
    loss = nll_sum / n_tok
    if z_loss:
        loss = loss + z_loss * (z_sum / n_tok)
    return loss
