"""Bucketed, packed, PROTECTED prefill with an AOT compile cache (DESIGN.md §14).

Serving admission used to be the last unprotected, unamortized stage of the
pipeline: every admitted request ran a B=1 prefill jitted on its exact
(prompt_len, max_len) shape — a traffic-time XLA compile per new length and
one launch per request — and that prefill was single-execution, OUTSIDE the
replica/detection contract, so an SDC during admission silently poisoned a
slot's cache before the detect-before-commit guarantee ever applied. This
module closes all three gaps:

  * **Buckets** — prompts are right-padded to a small geometric set of
    length buckets (powers of two), collapsing the unbounded space of
    prompt lengths onto a handful of compiled shapes. Correctness of
    right-padding is a property of the dense decode path: causal attention
    means real positions never attend pad columns, the last-hidden gather
    happens at each row's true final position (`lm_prefill(lengths=...)`),
    and decode overwrites cache slot `pos` BEFORE attending it, so the pad
    garbage beyond a row's true length is never observed. Stateful
    families (recurrent/ssm/xlstm, ring-buffer windows, modality
    frontends) cannot skip padding — `supported` gates them onto the
    legacy exact-shape path.

  * **Packs** — up to `max_pack` waiting prompts of one bucket launch as a
    SINGLE (K, bucket) prefill computing all K caches + first tokens; a
    jitted scatter then inserts every admitted row into its slot (and the
    SlotRing admission snapshots cut in one batched pass). Pack sizes are
    powers of two; a partial pack pads with dummy rows so every launch
    hits a precompiled shape.

  * **AOT cache** — every (kind, bucket, K) program is lowered and
    compiled ONCE, ahead of traffic (`warmup()`), through an explicit
    compile cache. Each cache miss is noted through `count_compiles()` —
    the `hostsync.count_transfers()`-style hook that turns
    "no traffic-time compiles" from a hope into an asserted property.

  * **Protection** — the packed program carries a per-prompt LANE: row i's
    fused fingerprint over {its logits row, its cache rows}. Dual-replica
    backends (sequential/fused) execute the compiled pack twice and compare
    lanes, localizing a fault to the row whose lanes disagree; the
    replica-free backends (abft/hybrid) checksum-guard the (K, V) logits
    block (full-checksum encode -> verify -> single-element forward
    correction) and localize uncorrectable faults to the violated row
    residuals. Either way the verdict is a per-row int: the driver admits
    the clean rows and retries/rejects ONLY the faulty prompt — the rest
    of the pack is never held hostage.

Verdict encoding (`VERDICT_*`): 0 = faulty (retry/reject this row),
1 = clean, 2 = clean-after-forward-correction (admit; record the
detection). One `hostsync.batched_get([tok, verdict])` per launch is the
whole admission readback.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fingerprint import pytree_fingerprint_fused
from repro.core.injection import InjectionSpec, flip_bit, spec_step_hit

VERDICT_BAD = 0
VERDICT_CLEAN = 1
VERDICT_CORRECTED = 2


# ---------------------------------------------------------------------------
# Compile accounting (the hostsync.count_transfers of XLA compiles)
# ---------------------------------------------------------------------------

@dataclass
class CompileStats:
    """Counts of prefill-program compiles inside a `count_compiles` region."""

    compiles: int = 0
    by_key: Dict[Tuple, int] = field(default_factory=dict)

    def note(self, key: Tuple) -> None:
        self.compiles += 1
        self.by_key[key] = self.by_key.get(key, 0) + 1


_active: List[CompileStats] = []


@contextlib.contextmanager
def count_compiles() -> Iterator[CompileStats]:
    """Count every prefill-program compile (AOT-cache miss) in the block.

    Wrap the traffic loop (NOT the warmup) and assert `st.compiles == 0`:
    that is the `no_traffic_time_compiles` property."""
    st = CompileStats()
    _active.append(st)
    try:
        yield st
    finally:
        _active.remove(st)


# Process-wide metrics fan-in, installed by `repro.obs.enable_metrics()`
# (None when metrics are off).
_metrics_note = None


def _note_compile(key: Tuple) -> None:
    for st in _active:
        st.note(key)
    if _metrics_note is not None:
        _metrics_note(key)


# ---------------------------------------------------------------------------
# Bucket / pack geometry
# ---------------------------------------------------------------------------

DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256)


def make_buckets(max_prompt: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Geometric (power-of-two) bucket ladder covering `max_prompt`."""
    out = [b := max(int(min_bucket), 1)]
    while b < max_prompt:
        b *= 2
        out.append(b)
    return tuple(out)


def bucket_for(length: int, buckets: Sequence[int]) -> Optional[int]:
    """Smallest bucket >= length, or None (overflow -> legacy exact path)."""
    for b in sorted(buckets):
        if length <= b:
            return int(b)
    return None


def pack_sizes(max_pack: int) -> Tuple[int, ...]:
    """The compiled pack sizes: powers of two up to `max_pack`."""
    out, k = [], 1
    while k <= max(int(max_pack), 1):
        out.append(k)
        k *= 2
    return tuple(out)


def pack_for(n: int, max_pack: int) -> int:
    """Smallest compiled pack size >= n (n must not exceed max_pack)."""
    for k in pack_sizes(max_pack):
        if n <= k:
            return k
    raise ValueError(f"pack of {n} exceeds max_pack={max_pack}")


def group_packs(items: Sequence[Any], lengths: Sequence[int],
                buckets: Sequence[int], max_pack: int
                ) -> Tuple[List[Tuple[int, List[Any]]], List[Any]]:
    """Queue -> pack selection: group `items` by length bucket and chunk
    each group to at most `max_pack`. Returns (packs, overflow) where packs
    is [(bucket, [items...])] in first-come order within a bucket and
    overflow holds items longer than the largest bucket (legacy path)."""
    by_bucket: Dict[int, List[Any]] = {}
    overflow: List[Any] = []
    for it, ln in zip(items, lengths):
        b = bucket_for(int(ln), buckets)
        if b is None:
            overflow.append(it)
        else:
            by_bucket.setdefault(b, []).append(it)
    packs: List[Tuple[int, List[Any]]] = []
    cap = max(int(max_pack), 1)
    for b in sorted(by_bucket):
        grp = by_bucket[b]
        for i in range(0, len(grp), cap):
            packs.append((b, grp[i:i + cap]))
    return packs, overflow


# ---------------------------------------------------------------------------
# The bucketed AOT prefiller
# ---------------------------------------------------------------------------

class BucketedPrefill:
    """AOT-compiled bucketed/packed prefill programs + per-prompt lanes.

    Holds the compile cache keyed (kind, bucket, K); `warmup()` populates
    every key so traffic never compiles. The packed program's outputs are
    all device-resident:

      tok     (K, 1) int32   — each row's first (argmax) token
      rows    pytree         — cache rows in INSERT layout (K, L, 1, T, ...)
                               (leading axis = pack row, ready for a
                               vectorized `.at[slots].set(rows)` scatter)
      lanes   (K, 4) uint32  — per-prompt fused fingerprint over
                               {logits row, cache rows}
      verdict (K,) int32     — backend detection verdict (VERDICT_*)

    Faults: `InjectionSpec(target='prefill')` flips one bit of pack row
    `leaf_idx`'s logits on the chosen replica (the admission analogue of
    the decode 'slot' target); `target='kernel'` lands in the ABFT
    checksum window exactly as in decode."""

    def __init__(self, model, backend: str = "none",
                 inj_spec: Optional[InjectionSpec] = None, inj_flag=None,
                 buckets: Optional[Sequence[int]] = None, max_pack: int = 4):
        self.model = model
        self.backend = backend
        self.inj_spec = inj_spec
        self.inj_flag = inj_flag
        self.buckets = tuple(sorted(buckets)) if buckets else DEFAULT_BUCKETS
        self.max_pack = max(int(max_pack), 1)
        self.dual = backend in ("sequential", "fused")
        self.guarded = backend in ("abft", "hybrid")
        self._cache: Dict[Tuple, Any] = {}

    @property
    def supported(self) -> bool:
        """Right-padding is a dense-family property (see module docstring)."""
        cfg = self.model.cfg
        return (not cfg.block_pattern and not cfg.window_size
                and not cfg.frontend and cfg.family != "audio")

    def usable_buckets(self, max_len: int) -> Tuple[int, ...]:
        """Ladder restricted to buckets the cache can hold: prefill writes
        `bucket` positions into a max_len-deep cache, so an oversized
        bucket is an overflow (legacy exact-shape path), not a crash."""
        return tuple(b for b in self.buckets if b <= max_len)

    def bucket_for(self, length: int,
                   max_len: Optional[int] = None) -> Optional[int]:
        ladder = self.buckets if max_len is None else \
            self.usable_buckets(max_len)
        return bucket_for(length, ladder)

    # -- programs -------------------------------------------------------------

    def _plain_fn(self, max_len: int):
        """generate()'s bucketed path: padded prefill, model-layout cache."""
        model = self.model

        def fn(params, toks, lengths):
            return model.prefill(
                params, {"tokens": toks, "lengths": lengths}, max_len)

        return fn

    def _packed_fn(self, max_len: int):
        spec = self.inj_spec
        guarded = self.guarded
        model = self.model

        def fn(params, toks, lengths, replica_id, armed, tick):
            logits, cache = model.prefill(
                params, {"tokens": toks, "lengths": lengths}, max_len)
            K, V = logits.shape
            if (spec is not None and spec.target == "prefill"
                    and spec.leaf_idx < K):
                # pack-row-localized SDC (leaf_idx = the pack row, like the
                # decode 'slot' target); a pack too small to have that row
                # is compiled without the injection — the fault lane simply
                # is not occupied. `cond`, not `where`: the flip must
                # not give the logits producer a second consumer on the
                # clean path (see injection.inject_tree — fusion drift).
                fire = jnp.logical_and(
                    jnp.asarray(armed, jnp.bool_),
                    jnp.logical_and(
                        spec_step_hit(spec, tick),
                        jnp.asarray(replica_id) == spec.replica))
                idx = spec.leaf_idx * V + (spec.flat_idx % V)
                logits = jax.lax.cond(
                    fire, lambda x: flip_bit(x, idx, spec.bit),
                    lambda x: x, logits)
            verdict = jnp.full((K,), VERDICT_CLEAN, jnp.int32)
            if guarded:
                from repro.abft.executor import pack_checksum_guard
                logits, verdict, _report = pack_checksum_guard(
                    logits, spec, tick, armed)
            # insert layout: model cache leaves are (L, K, T, ...) with the
            # batch axis second — move the pack row out front and restore
            # the B=1 axis so row i is exactly a slot slice (L, 1, T, ...)
            rows = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0)[:, :, None],
                                cache)
            lanes = jax.vmap(lambda lg, row: pytree_fingerprint_fused(
                {"logits": lg, "cache": row}))(logits, rows)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            return {"tok": tok, "rows": rows, "lanes": lanes,
                    "verdict": verdict}

        return fn

    # -- the AOT compile cache ------------------------------------------------

    def _compiled(self, kind: str, bucket: int, k: int, max_len: int, params):
        key = (kind, bucket, k, max_len, self.backend)
        prog = self._cache.get(key)
        if prog is not None:
            return prog
        _note_compile(key)
        i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
        if kind == "plain":
            prog = jax.jit(self._plain_fn(max_len)).lower(
                params, i32(k, bucket), i32(k)).compile()
        else:
            prog = jax.jit(self._packed_fn(max_len)).lower(
                params, i32(k, bucket), i32(k), i32(), i32(), i32()
            ).compile()
        self._cache[key] = prog
        return prog

    def warmup(self, params, max_len: int, *, plain_batches: Sequence[int] = (1,),
               packed: bool = True) -> int:
        """Pre-lower + compile every (bucket, pack-size) program so traffic
        hits only the cache. Returns the number of programs compiled."""
        n = 0
        for b in self.usable_buckets(max_len):
            for bs in plain_batches:
                self._compiled("plain", b, int(bs), max_len, params)
                n += 1
            if packed:
                for k in pack_sizes(self.max_pack):
                    self._compiled("packed", b, k, max_len, params)
                    n += 1
        return n

    # -- execution ------------------------------------------------------------

    def prefill_padded(self, params, tokens, max_len: int):
        """Bucketed replacement for the exact-shape B=1/whole-batch prefill:
        pad to the bucket boundary, run the AOT plain program, return
        (logits, cache) in the model's native layout. Returns None when the
        prompt overflows the bucket ladder (caller falls back)."""
        B, S = tokens.shape
        bucket = self.bucket_for(S, max_len)
        if bucket is None:
            return None
        toks = jnp.asarray(tokens, jnp.int32)
        if bucket > S:
            toks = jnp.pad(toks, ((0, 0), (0, bucket - S)))
        lengths = jnp.full((B,), S, jnp.int32)
        prog = self._compiled("plain", bucket, B, max_len, params)
        return prog(params, toks, lengths)

    def _armed(self) -> int:
        # mirror of the engine's arming line: the once-only flag is the
        # paper's injected.txt — recovery re-executions must not re-inject
        return int(self.inj_flag is not None
                   and self.inj_flag.arm_spec(self.inj_spec) is not None)

    def protected_pack(self, params, prompts: Sequence[np.ndarray],
                       max_len: int, tick: int) -> Dict[str, Any]:
        """One protected packed prefill launch over <= max_pack prompts of a
        shared bucket. Pads the pack to the next compiled size (dummy rows
        are sliced off by the caller) and folds the backend's detection
        verdict device-side — the caller's ONLY readback is one
        `batched_get([tok, verdict])`. Dual backends run the SAME compiled
        executable twice (replica 0/1) and compare per-prompt lanes."""
        n = len(prompts)
        bucket = self.bucket_for(max(len(p) for p in prompts), max_len)
        if bucket is None:
            raise ValueError("prompt overflows the bucket ladder")
        k = pack_for(n, self.max_pack)
        toks = np.zeros((k, bucket), np.int32)
        lens = np.ones((k,), np.int32)       # dummy rows: length-1 zeros
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            lens[i] = len(p)
        toks_d = jnp.asarray(toks)
        lens_d = jnp.asarray(lens)
        prog = self._compiled("packed", bucket, k, max_len, params)
        a = jnp.asarray(self._armed(), jnp.int32)
        t = jnp.asarray(int(tick), jnp.int32)
        rid0 = jnp.asarray(0, jnp.int32)
        r0 = prog(params, toks_d, lens_d, rid0, a, t)
        verdict = r0["verdict"]
        if self.dual:
            r1 = prog(params, toks_d, lens_d, jnp.asarray(1, jnp.int32), a, t)
            verdict = _lane_verdict_jit(r0["lanes"], r1["lanes"])
        return {"tok": r0["tok"], "rows": r0["rows"], "lengths": lens_d,
                "verdict": verdict, "n": n, "pack_size": k}


@jax.jit
def _lane_verdict_jit(lanes0, lanes1):
    """Per-prompt replica compare: rows whose hash lanes (cols 0..1, the
    fingerprint contract) disagree are faulty. DMR cannot attribute WHICH
    replica corrupted the row — the verdict only says 'do not admit'."""
    agree = jnp.all(lanes0[:, :2] == lanes1[:, :2], axis=1)
    return jnp.where(agree, VERDICT_CLEAN, VERDICT_BAD).astype(jnp.int32)
