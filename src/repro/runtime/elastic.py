"""Elastic fail-in-place training across node loss (DESIGN.md §16).

`ElasticTrainer` wraps a SEDAR-protected trainer in a cluster-health loop:
train a segment, scan the heartbeat directory, and on a stale host run the
shrink/regrow protocol instead of dying:

  shrink  — consult `policy.choose_degraded_mode` (the temporal model's
            restart-vs-fail-in-place cost terms). Fail-in-place drops the
            lost data shards via `plan_elastic_remesh` (per-shard batch —
            and with it every compiled program shape — preserved), drops
            the volatile checkpoint rings (they lived in the failed
            topology's memory), restores the last validated L3 anchor from
            the durable tiers (the Tier-3 partner store when configured)
            onto the survivors, and keeps training in a SIDE workdir.
  regrow  — when every lost host beats again, the original full-width
            trainer (kept alive, so its compiled step functions and AOT
            caches are reused) restores the SAME anchor from the original
            untouched store and replays at full width.

The authoritative trajectory is the full-width one anchored at the last
validated checkpoint: the data pipeline is a pure function of (seed, step)
and the jitted step is deterministic, so the regrown run is bitwise
identical to an uninterrupted run at the same seed (asserted in
tests/test_elastic.py). Degraded-phase progress is best-effort — it keeps
serving/learning during the outage but is discarded on regrow.

Every transition is journaled as a recovery record with
`kind="elastic_remesh"` so `obs.kpi.compute_kpis` picks up the node-loss
downtime windows and the redone (discarded) work without new plumbing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import temporal_model as tm
from repro.core.policy import (DegradedModeDecision, choose_degraded_mode,
                               make_trainer)
from repro.runtime.cluster import (ClusterMonitor, elastic_restart,
                                   rebuild_mesh, surviving_devices)


@dataclass
class RemeshRecord:
    """One shrink/regrow/safe-stop transition of the elastic cycle."""

    phase: str                    # shrink | regrow | safe_stop
    trigger_step: int             # host-side step when the scan fired
    restore_step: Optional[int]   # anchor checkpoint version (None = scratch)
    restore_tier: Optional[str]   # tier the anchor came back from
    hosts: List[int]              # hosts lost (shrink) / returned (regrow)
    old_data: int
    new_data: int
    old_batch: int
    new_batch: int
    downtime_s: float             # wall time training was paused
    mode: str                     # fail_in_place | safe_stop
    protection_lost: bool = False

    def as_recovery_record(self) -> Dict[str, Any]:
        """The journal/KPI view: rides the standard recovery-record path.
        `at - step` is the work discarded by this transition (the engine's
        rollback convention), so redone/availability fall out of the
        existing `compute_kpis` reduction."""
        return {"kind": "elastic_remesh", "phase": self.phase,
                "step": self.restore_step if self.restore_step is not None
                else self.trigger_step,
                "at": self.trigger_step, "rollbacks": 0,
                "hosts": list(self.hosts),
                "old_data": self.old_data, "new_data": self.new_data,
                "tier": self.restore_tier,
                "downtime_s": self.downtime_s, "mode": self.mode}


@dataclass
class ElasticReport:
    """Aggregate of every training segment plus the remesh transitions."""

    steps_completed: int = 0
    remeshes: List[RemeshRecord] = field(default_factory=list)
    decisions: List[DegradedModeDecision] = field(default_factory=list)
    segments: List[Any] = field(default_factory=list)   # TrainReports
    stopped: bool = False
    completed_degraded: bool = False
    final_state_fp: Any = None
    wall_s: float = 0.0

    @property
    def detections(self):
        return [d for seg in self.segments for d in seg.detections]

    @property
    def recoveries(self):
        return [r for seg in self.segments for r in seg.recoveries]

    def node_loss_downtime_s(self) -> float:
        return sum(r.downtime_s for r in self.remeshes)

    def summary(self) -> str:
        phases = [r.phase for r in self.remeshes]
        return (f"steps={self.steps_completed} remeshes={phases} "
                f"downtime={self.node_loss_downtime_s():.3f}s "
                f"stopped={self.stopped} degraded={self.completed_degraded}")


class ElasticTrainer:
    """Drive a SEDAR trainer through node loss without a full restart.

    Requires SEDAR level 3: the shrink anchor must be a VALIDATED
    checkpoint (restoring an unvalidated one onto survivors would launder a
    silent corruption into the post-remesh trajectory).

    `clock` and `tick` exist for deterministic tests: `tick(step)` runs
    before every scan (simulated hosts beat there) and `clock()` supplies
    the scan's "now". Real deployments leave both defaulted and let each
    host process call `Heartbeat.beat()` from its own loop.
    """

    def __init__(self, run_cfg, workdir: str, *,
                 monitor: Optional[ClusterMonitor] = None,
                 n_hosts: Optional[int] = None,
                 hosts_per_data_shard: int = 1,
                 replica_hosts: Sequence[int] = (),
                 scan_interval: int = 2,
                 mesh=None,
                 params: Optional[tm.SedarParams] = None,
                 mtbe_hours: float = 1000.0,
                 outage_hours: float = 0.1,
                 sdc_risk_budget: float = 1.0,
                 clock: Callable[[], float] = time.time,
                 tick: Optional[Callable[[int], None]] = None,
                 **trainer_kw):
        if run_cfg.sedar.level < 3:
            raise ValueError(
                "ElasticTrainer requires SEDAR level 3: the remesh anchor "
                "must be a validated checkpoint (L3), or a silent fault "
                "could ride the restore onto the survivors")
        self.cfg = run_cfg
        self.workdir = workdir
        self.mesh = mesh
        self.hosts_per_data_shard = max(int(hosts_per_data_shard), 1)
        self.replica_hosts = set(int(h) for h in replica_hosts)
        self.scan_interval = max(int(scan_interval), 1)
        self.params = params or tm.SedarParams(
            T_prog=1.0, T_comp=0.01, T_rest=0.1, f_d=0.02,
            t_cs=0.01, t_ca=0.005, T_compA=0.01, t_i=0.25)
        self.mtbe_hours = mtbe_hours
        self.outage_hours = outage_hours
        self.sdc_risk_budget = sdc_risk_budget
        self.clock = clock
        self.tick = tick
        self.trainer_kw = dict(trainer_kw)
        hb_dir = os.path.join(workdir, "heartbeats")
        self.monitor = monitor or ClusterMonitor(
            hb_dir, n_hosts if n_hosts is not None else 1)
        with self._mesh_ctx(self.mesh):
            self.trainer = make_trainer(
                run_cfg, workdir, mesh=mesh,
                hosts_per_data_shard=self.hosts_per_data_shard,
                **self.trainer_kw)
        self._degraded = None       # (trainer, mesh) during an outage
        self._degraded_count = 0
        self._lost: set = set()

    @staticmethod
    def _mesh_ctx(mesh):
        return mesh if mesh is not None else contextlib.nullcontext()

    # -- anchor restore ----------------------------------------------------

    def _anchor(self):
        """(version, recovery) of the last validated full-width checkpoint
        in the ORIGINAL store — the authoritative trajectory's re-entry
        point for both shrink and regrow."""
        rec = self.trainer.recovery
        tiers = getattr(rec, "tiers", None)
        if tiers is not None:
            tiers.wait()
            return tiers.latest_valid(), rec
        store = getattr(rec, "store", None)
        if store is not None:
            store.wait()
            return store.latest(valid_only=True), rec
        return None, rec

    def _restore_onto(self, trainer, version, rec):
        """Restore anchor `version` from the full run's recovery stores and
        adopt it into `trainer`'s executor. Returns (dual, tier_name)."""
        if version is None:
            return None, None
        template = trainer.init_state()
        tiers = getattr(rec, "tiers", None)
        if tiers is not None:
            state, info = tiers.restore(version, template)
            tier = info.get("tier")
        else:
            state = rec.store.restore(version, template)
            tier = "disk"
        state = jax.tree.map(jnp.asarray, state)
        return trainer.engine.executor.adopt_single(state), tier

    # -- transitions -------------------------------------------------------

    def _decide(self, lost: set) -> DegradedModeDecision:
        protection_lost = bool(self.replica_hosts & lost)
        return choose_degraded_mode(
            self.params, self.mtbe_hours, self.outage_hours,
            protection_lost=protection_lost,
            sdc_risk_budget=self.sdc_risk_budget)

    def _shrink(self, lost: set, step: int, report: ElasticReport):
        """Node loss: decide, then either park (safe_stop) or rebuild a
        degraded trainer on the survivors from the Tier-3 anchor."""
        t0 = time.monotonic()
        decision = self._decide(lost)
        report.decisions.append(decision)
        old_data = self.cfg.mesh.shape[self._data_ax()] \
            if "data" in self.cfg.mesh.axis_names else 1
        if decision.mode == "safe_stop":
            rr = RemeshRecord(
                phase="safe_stop", trigger_step=step, restore_step=None,
                restore_tier=None, hosts=sorted(lost), old_data=old_data,
                new_data=old_data, old_batch=self.cfg.train.global_batch,
                new_batch=self.cfg.train.global_batch,
                downtime_s=time.monotonic() - t0, mode="safe_stop",
                protection_lost=decision.protection_lost)
            self._journal(rr, report)
            report.stopped = True
            return None, None
        anchor, rec = self._anchor()
        # the failed topology takes the volatile rings with it: restore can
        # only be served by the durable tiers (disk / Tier-3 partner)
        tiers = getattr(rec, "tiers", None)
        if tiers is not None:
            tiers.drop_volatile()
        self._degraded_count += 1
        side = os.path.join(self.workdir,
                            f"degraded_{self._degraded_count}")
        protection_lost = bool(self.replica_hosts & lost)
        if protection_lost:
            # the replica pod died: survivors run unprotected-but-
            # checkpointed at full data width (the policy's degraded mode)
            deg_cfg = dataclasses.replace(
                self.cfg, sedar=dataclasses.replace(
                    self.cfg.sedar, replication="none"))
            deg_mesh = self._degraded_mesh(set(), drop_replica=True)
            with self._mesh_ctx(deg_mesh):
                trainer = make_trainer(deg_cfg, side, mesh=deg_mesh,
                                       **self.trainer_kw)
            new_data, new_batch = old_data, self.cfg.train.global_batch
        else:
            shards = sorted({h // self.hosts_per_data_shard for h in lost})
            deg_mesh = self._degraded_mesh(shards)
            with self._mesh_ctx(deg_mesh):
                plan, trainer = elastic_restart(
                    self.cfg, side, sorted(lost),
                    hosts_per_data_shard=self.hosts_per_data_shard,
                    mesh=deg_mesh, **self.trainer_kw)
            new_data, new_batch = plan.new_data, plan.new_global_batch
        with self._mesh_ctx(deg_mesh):
            dual, tier = self._restore_onto(trainer, anchor, rec)
        rr = RemeshRecord(
            phase="shrink", trigger_step=step, restore_step=anchor,
            restore_tier=tier, hosts=sorted(lost), old_data=old_data,
            new_data=new_data, old_batch=self.cfg.train.global_batch,
            new_batch=new_batch, downtime_s=time.monotonic() - t0,
            mode="fail_in_place", protection_lost=protection_lost)
        self._journal(rr, report)
        self._degraded = (trainer, deg_mesh)
        return trainer, dual

    def _regrow(self, returned: set, step: int, report: ElasticReport):
        """Every lost host is back: re-anchor the kept-alive full-width
        trainer (compiled functions reused) and replay from the anchor."""
        t0 = time.monotonic()
        anchor, rec = self._anchor()
        with self._mesh_ctx(self.mesh):
            dual, tier = self._restore_onto(self.trainer, anchor, rec)
        full_data = self.cfg.mesh.shape[self._data_ax()] \
            if "data" in self.cfg.mesh.axis_names else 1
        shrinks = [r for r in report.remeshes if r.phase == "shrink"]
        rr = RemeshRecord(
            phase="regrow", trigger_step=step, restore_step=anchor,
            restore_tier=tier, hosts=sorted(returned),
            old_data=shrinks[-1].new_data if shrinks else full_data,
            new_data=full_data, old_batch=self.cfg.train.global_batch,
            new_batch=self.cfg.train.global_batch,
            downtime_s=time.monotonic() - t0, mode="fail_in_place")
        self._journal(rr, report)
        self._degraded = None
        return self.trainer, dual

    def _data_ax(self) -> int:
        names = list(self.cfg.mesh.axis_names)
        return names.index("data") if "data" in names else 0

    def _degraded_mesh(self, lost_shards: set, drop_replica: bool = False):
        if self.mesh is None:
            return None
        if drop_replica:
            import numpy as np
            devs = np.asarray(self.mesh.devices)
            ax = list(self.mesh.axis_names).index(
                self.cfg.sedar.replica_axis)
            devs2 = np.take(devs, [0], axis=ax)
            return rebuild_mesh(devs2.shape, self.mesh.axis_names,
                                devices=devs2.reshape(-1))
        shape, devices = surviving_devices(self.mesh, sorted(lost_shards))
        return rebuild_mesh(shape, self.mesh.axis_names, devices=devices)

    def _journal(self, rr: RemeshRecord, report: ElasticReport) -> None:
        report.remeshes.append(rr)
        obs.note_recovery(rr.as_recovery_record())
        if obs.metrics_enabled():
            obs.metrics.inc("sedar_elastic_remeshes_total", phase=rr.phase)
            obs.metrics.set_gauge("sedar_node_loss_downtime_s",
                                  sum(r.downtime_s for r in report.remeshes))

    # -- driver ------------------------------------------------------------

    def run(self, num_steps: int) -> ElasticReport:
        report = ElasticReport()
        t0 = time.time()
        active, active_mesh = self.trainer, self.mesh
        dual = None
        step = 0
        max_segments = 8 * (num_steps // self.scan_interval + 2)
        for _ in range(max_segments):
            if self.tick is not None:
                self.tick(step)
            stale = set(self.monitor.stale_hosts(self.clock()))
            newly_lost = stale - self._lost
            if self._degraded is None and newly_lost:
                self._lost = set(stale)
                got = self._shrink(self._lost, step, report)
                if report.stopped:
                    break
                active, dual = got
                active_mesh = self._degraded[1]
                step = None   # re-read from the restored state
            elif self._degraded is not None and not (self._lost & stale):
                returned = set(self._lost)
                # any OTHER stale host is re-detected by the next scan
                self._lost = set()
                active, dual = self._regrow(returned, step, report)
                active_mesh = self.mesh
                step = None
            if step is not None and step >= num_steps:
                break
            seg_end = num_steps if step is None else \
                min(step + self.scan_interval, num_steps)
            with self._mesh_ctx(active_mesh):
                if step is None:
                    # bound the first post-transition segment by the scan
                    # cadence from the restored (anchor) step
                    restored = 0 if dual is None else \
                        active._host_step(dual)
                    seg_end = min(restored + self.scan_interval, num_steps)
                dual, seg = active.run(seg_end, dual=dual)
            report.segments.append(seg)
            step = seg.steps_completed
            if seg.stopped:
                report.stopped = True
                break
        report.steps_completed = step if step is not None else 0
        report.completed_degraded = self._degraded is not None
        if report.segments:
            report.final_state_fp = report.segments[-1].final_state_fp
        report.wall_s = time.time() - t0
        return report
