"""Serving drivers over the unified SEDAR engine.

Two loops share the engine, the model and the detection machinery:

`generate()` — the original synchronous whole-batch loop (DESIGN.md §8):
decoding is deterministic (greedy), so a dual-replica serve step compares
logits fingerprints before emitting tokens — "validate the message before
sending it to the user". Every sequence in the batch advances in lockstep;
one corrupted replica compare stalls (or, under deferral, rolls back)
EVERY sequence in flight, and a retry-budget exhaustion safe-stops the
whole stream (the paper's L1 applied to the run).

`serve()` — continuous-batching protected decode (DESIGN.md §13): a
`SlotScheduler` packs independent requests into N sequence slots, each
carrying its own KV-cache slice, token and position. The engine's
protected step runs over the PACKED batch with a PER-SLOT fingerprint, so
`DetectionEvent`s are localized to sequence slots and the paper's recovery
levels re-scope from "the run" to "the request":

  * transient slot mismatch  -> partial commit + per-slot re-execution
    (L0 retry for one sequence; the other slots stream on),
  * deferred-window fault    -> rollback of ONLY the affected slots from a
    Tier-0 `SlotRing` (keyed device-resident snapshots, zero disk reads,
    zero host syncs — the PR-4 tier machinery per request),
  * exhausted slot budget    -> per-REQUEST rejection with notification
    (L1 safe-stop scoped to one sequence; the server keeps serving).

The fault-free hot path keeps the §11 zero-sync property — and extends it
through emission (DESIGN.md §18): with `validate_lag >= D` a decode tick
performs NO device->host transfer at all. Tokens park in the engine's
device-resident TokenRing and leave in ONE `batched_get` per flush window,
fused with the combined commit predicate (`token_emit` syncs are O(1/D),
asserted via `hostsync.count_transfers`); a detokenize consumer thread
streams them while the next window launches. Tier-0 snapshots/rollbacks
never touch disk (`checkpoint.count_disk_reads`).

Replica-free serving: the abft/hybrid backends guard every decode step's
logits block with a full-checksum ABFT pass (`_logits_checksum_guard`):
single-element corruption in the kernel-domain window is forward-corrected
and the corrected commit EMITS its token — no re-execution, rollbacks=0.

DMR attribution limit (unchanged from §8): with two replicas a PERSISTENT
state divergence cannot be attributed to the faulty replica. In the
continuous loop that degradation is per-request — after `max_retries`
consecutive failed re-executions of a slot, that REQUEST is rejected
rather than ever emitting an unvalidated token; the server itself never
dies (the paper's L1 guarantee, re-scoped).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import RunConfig
from repro.core import hostsync
from repro.core.detection import DetectionEvent, SedarSafeStop
from repro.core.engine import BoundarySchedule, SedarEngine
from repro.core.fingerprint import (pytree_fingerprint,
                                    pytree_fingerprint_fused,
                                    tensor_fingerprint)
from repro.core.injection import (InjectionSpec, MemoryInjectionFlag,
                                  flip_bit, inject_tree, make_kernel_fault,
                                  spec_step_hit)
from repro.core.policy import make_engine
from repro.core.recovery import RetryRecovery, SlotRecovery
from repro.models import build_model


@dataclass
class ServeReport:
    tokens_emitted: int = 0
    detections: List[DetectionEvent] = field(default_factory=list)
    retries: int = 0
    stopped: bool = False          # retry budget exhausted (safe stop)
    wall_s: float = 0.0


@dataclass
class BatchServeReport:
    """Outcome of one continuous-batching `serve()` run."""

    tokens_emitted: int = 0        # tokens delivered by COMPLETED requests
    steps: int = 0                 # protected decode steps executed
    wall_s: float = 0.0
    detections: List[DetectionEvent] = field(default_factory=list)
    retries: int = 0               # per-slot re-executions (L0)
    rollbacks: int = 0             # slot restores from the Tier-0 ring
    truncated_tokens: int = 0      # optimistic tokens rolled back + redone
    completed: List[int] = field(default_factory=list)   # request ids
    rejected: List[int] = field(default_factory=list)    # request ids
    stopped: bool = False
    prefill_packs: int = 0         # packed prefill launches (incl. retries)
    prefill_retries: int = 0       # per-prompt prefill re-executions

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_emitted / max(self.wall_s, 1e-9)

    @property
    def goodput_tokens_per_step(self) -> float:
        """Delivered tokens per protected step — the wall-clock-free
        continuous-batching figure of merit (a synchronous wave loop burns
        steps decoding slots whose requests already finished)."""
        return self.tokens_emitted / max(self.steps, 1)


@jax.jit
def _slot_write_jit(state, slot, cache_sl, tok_sl, pos_sl, active):
    """One fused scatter of a slot slice into the packed state (dynamic
    slot index). Jitted module-level so admissions/rollbacks cost one
    dispatch per replica instead of one per cache leaf."""
    cache = jax.tree.map(
        lambda full, s: full.at[slot].set(s.astype(full.dtype)),
        state["cache"], cache_sl)
    return {**state, "cache": cache,
            "tok": state["tok"].at[slot].set(tok_sl.astype(jnp.int32)),
            "pos": state["pos"].at[slot].set(pos_sl.astype(jnp.int32)),
            "active": state["active"].at[slot].set(active)}


@jax.jit
def _set_active_jit(state, slot, value):
    return {**state, "active": state["active"].at[slot].set(value)}


@jax.jit
def _pack_insert_jit(state, slots, sel, rows, toks, poss):
    """Vectorized admission scatter: pack rows `sel` of a protected prefill
    launch land in slots `slots` of the packed state in ONE fused program
    (maxtext's prefill_insert_batch shape) — cache rows, first tokens,
    positions and the active mask together, instead of one `_slot_write_jit`
    dispatch per admitted request."""
    cache = jax.tree.map(
        lambda full, r: full.at[slots].set(r[sel].astype(full.dtype)),
        state["cache"], rows)
    return {**state, "cache": cache,
            "tok": state["tok"].at[slots].set(toks[sel].astype(jnp.int32)),
            "pos": state["pos"].at[slots].set(poss[sel].astype(jnp.int32)),
            "active": state["active"].at[slots].set(
                jnp.ones(slots.shape, jnp.bool_))}


@jax.jit
def _slot_slice_jit(cache, tok, pos, slot):
    """Extract one slot's {cache, tok, pos} image (Tier-0 snapshot source)."""
    return {"cache": jax.tree.map(lambda x: x[slot], cache),
            "tok": tok[slot], "pos": pos[slot]}


def _logits_checksum_guard(logits, spec: Optional[InjectionSpec],
                           step, armed):
    """ABFT output guard over one decode step's logits block — shared with
    the packed-prefill guard; see `abft.executor.logits_checksum_guard`."""
    from repro.abft.executor import logits_checksum_guard
    return logits_checksum_guard(logits, spec, step, armed)


class SedarServer:
    """Prefill once, then decode step-by-step (optionally dual-executed)."""

    def __init__(self, run_cfg: RunConfig, dual: bool = False,
                 inj_spec: Optional[InjectionSpec] = None,
                 max_retries: int = 8, backend: Optional[str] = None,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 max_pack: int = 4):
        self.cfg = run_cfg
        self.model = build_model(run_cfg.model)
        self.dual = dual
        self.inj_spec = inj_spec
        self.inj_flag = MemoryInjectionFlag()
        self.max_retries = max_retries
        self._prefill = jax.jit(self._prefill_fn, static_argnums=(2,))
        self._decode = jax.jit(self._decode_fn)
        # Serving boundaries: TDC commit gate on every decode step; no
        # checkpoint boundary (the only mutable state is the KV cache,
        # recomputable from the prompt — recovery is re-execution). The
        # replica-free backends ("abft"/"hybrid", DESIGN.md §10) serve from
        # ONE decode state; hybrid additionally re-fingerprints the resident
        # {cache, tok} at the FSC cadence to catch at-rest cache corruption
        # that checksummed kernels cannot see. "fused" (DESIGN.md §11) runs
        # both decode replicas in one launch — token emission itself is the
        # only per-step readback left.
        backend = backend or ("sequential" if dual else "none")
        self.backend = backend
        fsc_interval = (int(run_cfg.sedar.param_validate_interval)
                        if backend == "hybrid" else 0)
        self._fsc_interval = fsc_interval
        fp_tree = ((lambda s: {"cache": s["cache"], "tok": s["tok"]})
                   if backend in ("abft", "hybrid")
                   else (lambda s: {"tok": s["tok"]}))
        self._fp_tree = fp_tree
        # continuous-batching engines, keyed (slots, max_len, lag): the
        # packed decode program depends on all three, and reusing the
        # engine across serve() calls reuses its jit cache
        self._batch_engines: Dict[Tuple[int, int, int],
                                  Tuple[SedarEngine, Any, SlotRecovery]] = {}
        self.engine: SedarEngine = make_engine(
            run_cfg.sedar,
            backend=backend,
            step_fn=self._decode,
            state_fp_fn=jax.jit(lambda s: pytree_fingerprint(fp_tree(s))),
            fast_state_fp_fn=jax.jit(lambda s: pytree_fingerprint_fused(
                fp_tree(s))),
            schedule=BoundarySchedule(
                commit_interval=1, validate_interval=fsc_interval,
                checkpoint_interval=0,
                toe_timeout_s=run_cfg.sedar.toe_timeout_s),
            recovery=RetryRecovery(max_retries=max_retries),
            inj_spec=inj_spec, inj_flag=self.inj_flag,
            notify=lambda e: None)
        # bucketed/packed AOT prefill (DESIGN.md §14): the default admission
        # path for the dense families; stateful/windowed/frontend families
        # (prefiller.supported False) keep the legacy exact-shape prefill
        from repro.runtime.prefill import BucketedPrefill
        self.prefiller = BucketedPrefill(
            self.model, backend=backend, inj_spec=inj_spec,
            inj_flag=self.inj_flag, buckets=prefill_buckets,
            max_pack=max_pack)

    def warmup_prefill(self, params, max_len: int, *,
                       plain_batches: Sequence[int] = (1,)) -> int:
        """AOT-compile every bucketed prefill program ahead of traffic.
        Returns the number of programs compiled (0 for unsupported
        families — they keep the legacy jit path)."""
        if not self.prefiller.supported:
            return 0
        return self.prefiller.warmup(params, max_len,
                                     plain_batches=plain_batches)

    def _prefill_fn(self, params, batch, max_len):
        return self.model.prefill(params, batch, max_len)

    def _decode_fn(self, state, params, replica_id, armed):
        """Engine step_fn: (decode state, params-as-batch, rid, armed) ->
        (candidate state, logits fingerprint, logits[, AbftReport])."""
        if (self.inj_spec is not None
                and self.inj_spec.target not in ("kernel", "prefill",
                                                 "prefill_kernel")):
            params = inject_tree(params, self.inj_spec, step=state["pos"],
                                 replica_id=replica_id, armed=armed)
        logits, cache = self.model.decode_step(params, state["cache"],
                                               state["tok"], state["pos"])
        report = None
        if self.backend in ("abft", "hybrid"):
            # replica-free detection: checksum-guard the logits block; a
            # forward-corrected commit advances the decode state and its
            # token is emitted (see generate()/serve() — no re-execution)
            logits, report = _logits_checksum_guard(
                logits, self.inj_spec, state["pos"], armed)
        fp = pytree_fingerprint_fused({"logits": logits})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cand = {"cache": cache, "tok": tok, "pos": state["pos"] + 1}
        if report is not None:
            return cand, fp, logits, report
        return cand, fp, logits

    def generate(self, params, prompt_batch: Dict[str, Any], steps: int,
                 max_len: Optional[int] = None
                 ) -> "tuple[np.ndarray, ServeReport]":
        rep = ServeReport()
        t0 = time.time()
        eng = self.engine
        eng.reset()
        self.inj_flag.reset()
        if isinstance(eng.recovery, RetryRecovery):
            eng.recovery.reset()
        B, S = prompt_batch["tokens"].shape
        P = (self.cfg.model.frontend_seq
             if (self.cfg.model.frontend and self.cfg.model.family == "vlm") else 0)
        max_len = max_len or (S + P + steps + 8)
        pre = None
        if (self.prefiller.supported
                and "frontend_embeds" not in prompt_batch):
            # bucketed path: pad to the bucket boundary so every prompt
            # length <= the ladder hits ONE precompiled program instead of
            # jitting `_prefill` per exact (prompt_len, max_len)
            pre = self.prefiller.prefill_padded(
                params, prompt_batch["tokens"], max_len)
        if pre is None:
            pre = self._prefill(params, prompt_batch, max_len)
        logits, cache = pre
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        pos = S + P
        dual = eng.executor.init_dual(
            {"cache": cache, "tok": tok, "pos": jnp.asarray(pos, jnp.int32)})

        while len(out) < steps:
            outcome = eng.run_protected_step(dual, params, pos)
            dual = outcome.dual
            if outcome.event is not None:
                # validate-before-send: on a gated mismatch the token is NOT
                # emitted and the step re-executes via the engine's retry
                # policy. An ABFT-instrumented decode step (backend "abft"/
                # "hybrid") may instead COMMIT FORWARD through repair() —
                # the position check below emits the corrected token instead
                # of re-executing (covered by tests/test_serve_batched.py).
                try:
                    dual = eng.on_detection(outcome.event, dual)
                except SedarSafeStop:
                    rep.stopped = True
                    break
                if hostsync.read_int(eng.executor.peek(dual, "pos"),
                                     label="decode_pos") > pos:
                    out.append(hostsync.read_scalar(
                        eng.executor.peek(dual, "tok"), label="token_emit"))
                    pos += 1
                continue
            # token emission is the product — the ONE per-step readback the
            # serving hot path keeps (validated by the commit gate above)
            out.append(hostsync.read_scalar(eng.executor.peek(dual, "tok"),
                                            label="token_emit"))
            pos += 1

        rep.detections = list(eng.detections)
        rep.retries = sum(1 for r in eng.recoveries
                          if r["kind"] in ("retry", "vote_retry"))
        rep.tokens_emitted = len(out) * B
        rep.wall_s = time.time() - t0
        return np.stack(out, axis=1), rep

    # ------------------------------------------------------------------
    # Continuous-batching protected decode (DESIGN.md §13)
    # ------------------------------------------------------------------

    def _make_packed_decode(self, n_slots: int):
        """Packed step_fn over N sequence slots, each with its own cache
        slice / token / position. Returns per-slot fingerprints (N, 4) so
        the slotted executors localize mismatches to slots. Inactive slots
        are excluded from the fingerprint (their rows are zeroed) and their
        positions are frozen; their cache garbage is unobservable — a
        refill overwrites the whole slice at prefill."""
        spec = self.inj_spec
        abft_guard = self.backend in ("abft", "hybrid")
        model = self.model

        def step(state, params, replica_id, armed):
            t = state["t"]
            if spec is not None and spec.target not in (
                    "kernel", "slot", "prefill", "prefill_kernel"):
                params = inject_tree(params, spec, step=t,
                                     replica_id=replica_id, armed=armed)
            logits, cache = jax.vmap(
                lambda c, tk, p: model.decode_step(params, c, tk, p)
            )(state["cache"], state["tok"], state["pos"])
            logits = logits.reshape(n_slots, -1)          # (N, V)
            if spec is not None and spec.target == "slot":
                # slot-localized SDC: flip one bit of ONE slot's logits
                # (spec.leaf_idx doubles as the slot index) on the chosen
                # replica — the per-slot fault the detection must localize
                fire = jnp.logical_and(
                    jnp.asarray(armed, jnp.bool_),
                    jnp.logical_and(
                        spec_step_hit(spec, t),
                        jnp.asarray(replica_id) == spec.replica))
                idx = spec.leaf_idx * logits.shape[-1] + spec.flat_idx
                logits = jnp.where(fire, flip_bit(logits, idx, spec.bit),
                                   logits)
            report = None
            if abft_guard:
                logits, report = _logits_checksum_guard(logits, spec, t,
                                                        armed)
            act = state["active"]
            fp = jax.vmap(tensor_fingerprint)(logits)     # (N, 4)
            fp = jnp.where(act[:, None], fp, jnp.zeros_like(fp))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            cand = {"cache": cache, "tok": tok,
                    "pos": jnp.where(act, state["pos"] + 1, state["pos"]),
                    "active": act, "t": t + 1}
            # aux = the emission pair: the engine's TokenRing parks these
            # refs per tick (DESIGN.md §18) — outputs the step computes
            # anyway, so parking adds no launch and no readback
            if report is not None:
                return cand, fp, (tok, cand["pos"]), report
            return cand, fp, (tok, cand["pos"])

        return step

    def _batch_engine(self, slots: int, max_len: int, lag: int
                      ) -> Tuple[SedarEngine, Any, SlotRecovery]:
        key = (slots, max_len, lag)
        if key in self._batch_engines:
            return self._batch_engines[key]
        from repro.checkpoint.tiers import SlotRing
        ring = SlotRing(slots_per_key=4)
        recovery = SlotRecovery(ring, max_retries=self.max_retries)
        fp_tree = self._fp_tree
        step = self._make_packed_decode(slots)
        if self.backend in ("sequential", "fused"):
            step = jax.jit(step)
        eng = make_engine(
            self.cfg.sedar,
            backend=self.backend,
            step_fn=step,
            state_fp_fn=jax.jit(lambda s: pytree_fingerprint(fp_tree(s))),
            fast_state_fp_fn=jax.jit(
                lambda s: pytree_fingerprint_fused(fp_tree(s))),
            schedule=BoundarySchedule(
                commit_interval=1, validate_interval=self._fsc_interval,
                checkpoint_interval=0,
                toe_timeout_s=self.cfg.sedar.toe_timeout_s,
                validate_lag=lag),
            recovery=recovery,
            inj_spec=self.inj_spec, inj_flag=self.inj_flag,
            notify=lambda e: None,
            slots=slots if self.backend in ("sequential", "fused") else None)
        self._batch_engines[key] = (eng, ring, recovery)
        return eng, ring, recovery

    # -- packed-state surgery (all device-side; no host syncs) ----------------

    def _write_slot(self, eng, dual, slot: int, sl, active: bool = True):
        """Write one slot slice into EVERY replica image (admission refill /
        rollback merge). One jitted device scatter per replica through
        `map_state`."""
        slot_d = jnp.asarray(slot, jnp.int32)
        cache_sl = jax.tree.map(jnp.asarray, sl["cache"])
        tok_sl = jnp.asarray(sl["tok"])
        pos_sl = jnp.asarray(sl["pos"])
        act = jnp.asarray(active, jnp.bool_)
        dual = eng.executor.map_state(
            lambda st: _slot_write_jit(st, slot_d, cache_sl, tok_sl,
                                       pos_sl, act), dual)
        eng.executor.note_external_update()
        return dual

    def _set_active(self, eng, dual, slot: int, value: bool):
        slot_d = jnp.asarray(slot, jnp.int32)
        val = jnp.asarray(value, jnp.bool_)
        dual = eng.executor.map_state(
            lambda st: _set_active_jit(st, slot_d, val), dual)
        eng.executor.note_external_update()
        return dual

    def _slot_slice(self, eng, dual, slot: int):
        return _slot_slice_jit(eng.executor.peek(dual, "cache"),
                               eng.executor.peek(dual, "tok"),
                               eng.executor.peek(dual, "pos"),
                               jnp.asarray(slot, jnp.int32))

    def _snapshot_slots(self, eng, dual, sched, ring, version: int) -> None:
        """Tier-0 per-slot snapshots at the deferred-validation cadence:
        every RUNNING slot's {cache, tok, pos} image enters its keyed
        device ring right after a clean flush — pure `jnp.copy`, zero disk
        reads, zero host syncs (the zero-sync property extends through
        per-request checkpointing, asserted by tests). One `save_many`
        batch per flush: the snapshot versions land exactly on the drain
        edges the emission ring delivers at, so a rollback target never
        predates a delivered token (DESIGN.md §18)."""
        slices = {slot: self._slot_slice(eng, dual, slot)
                  for slot, _req in sched.running_items()}
        if slices:
            ring.save_many(version, slices)

    def _admit_slot(self, eng, dual, params, slot: int, req, t: int,
                    ring, ring_on: bool, max_len: int):
        """Prefill `req` into a freed slot mid-flight: B=1 prefill, device
        scatter into the packed state, admission snapshot (version = the
        admit tick, so a deferred fault in the very first window has a
        rollback target), and emission of the prefill token."""
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        with obs.span("prefill_pack", step=t, pack=1, packed=False):
            logits, cache = self._prefill(params, {"tokens": prompt},
                                          max_len)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (1,)
        sl = {"cache": cache, "tok": tok,
              "pos": jnp.asarray(req.prompt_len, jnp.int32)}
        ring.evict(slot)           # never resurrect a previous tenant
        dual = self._write_slot(eng, dual, slot, sl, active=True)
        if ring_on:
            ring.save(slot, t, sl)
        req.pos0 = req.prompt_len
        # the prefill token is single-execution (like generate()): the
        # replica-validated stream starts at the first decode step
        req.tokens.append(int(hostsync.read_scalar(
            tok, label="prefill_emit")[0]))
        req.token_times.append(time.time())
        return dual

    def _admit_pack(self, eng, dual, params, pairs, t: int, ring,
                    ring_on: bool, max_len: int, rep: BatchServeReport,
                    sched, notify, events: List[DetectionEvent]):
        """Protected packed admission (DESIGN.md §14): ONE prefill launch
        computes caches + first tokens + per-prompt lanes for the whole
        pack, ONE `batched_get` reads back {tokens, verdicts}, ONE fused
        scatter inserts the admitted rows, and the SlotRing admission
        snapshots cut in one batched pass. A faulty row (lane mismatch /
        uncorrectable checksum residual) is retried ALONE — the clean rows
        of the pack are admitted immediately — and a persistent fault
        exhausts the retry budget into a per-request rejection."""
        spec = self.inj_spec
        for slot, _req in pairs:
            ring.evict(slot)       # never resurrect a previous tenant
        pairs = list(pairs)
        prompts = [r.prompt for _, r in pairs]
        need = list(range(len(pairs)))   # rows not yet admitted
        budget = self.max_retries
        while need:
            # retries RELAUNCH the original pack shape: a persistent (stuck
            # lane) fault must keep hitting the same occupant, not slide to
            # row 0 of a shrunken retry pack — already-admitted rows are
            # recomputed but not re-admitted
            with obs.span("prefill_pack", step=t, pack=len(pairs)):
                res = self.prefiller.protected_pack(params, prompts,
                                                    max_len, t)
            rep.prefill_packs += 1
            toks, verdicts = hostsync.batched_get(
                [res["tok"], res["verdict"]], label="prefill_emit")
            good = [i for i in need if int(verdicts[i]) != 0]
            bad = [i for i in need if int(verdicts[i]) == 0]
            if good:
                rows, toks_d, poss = res["rows"], res["tok"], res["lengths"]
                sel = jnp.asarray(good, jnp.int32)
                slots_d = jnp.asarray([pairs[i][0] for i in good], jnp.int32)
                dual = eng.executor.map_state(
                    lambda st: _pack_insert_jit(st, slots_d, sel, rows,
                                                toks_d, poss), dual)
                eng.executor.note_external_update()
                if ring_on:
                    ring.save_many(t, {
                        pairs[i][0]: {
                            "cache": jax.tree.map(
                                lambda x, j=i: x[j], rows),
                            "tok": toks_d[i], "pos": poss[i]}
                        for i in good})
                now_wall = time.time()
                for i in good:
                    _slot, req = pairs[i]
                    req.pos0 = req.prompt_len
                    # like the legacy path, the emitted prefill token is
                    # already past the detection contract: its row's lane
                    # (or checksum row) verified before this readback
                    req.tokens.append(int(toks[i, 0]))
                    req.token_times.append(now_wall)
            corrected = [i for i in good if int(verdicts[i]) == 2]
            if corrected:
                # prefill events never route through eng.on_detection (the
                # pack retries inline), so they are journaled HERE
                ev = DetectionEvent(
                    step=t, boundary="prefill", effect="abft_corrected",
                    detail={"slots": [pairs[i][0] for i in corrected],
                            "rids": [pairs[i][1].rid for i in corrected]})
                events.append(ev)
                obs.note_detection(ev)
            if (bad or corrected) and spec is not None and not spec.persistent:
                self.inj_flag.mark()   # paper's injected.txt: the transient
                # fault MANIFESTED (detected or forward-corrected) — it must
                # not re-fire on the retry or in a later stage
            if not bad:
                break
            ev = DetectionEvent(
                step=t, boundary="prefill", effect="TDC",
                detail={"slots": [pairs[i][0] for i in bad],
                        "rids": [pairs[i][1].rid for i in bad]})
            events.append(ev)
            obs.note_detection(ev)
            budget -= 1
            if budget <= 0:
                for i in bad:
                    slot, req = pairs[i]
                    sched.reject(slot, "prefill validation failed: "
                                 "consecutive retry budget exhausted")
                    rep.rejected.append(req.rid)
                    obs.note_rejection(t, rid=req.rid, slot=slot,
                                       reason="prefill_persistent")
                    if notify is not None:
                        notify(req, events[-1])
                break
            rep.prefill_retries += len(bad)
            need = bad
        return dual

    def _finish(self, sched, slot: int, rep: BatchServeReport) -> None:
        """Release a drained slot exactly once: release/reactivate cleared
        the slot (or flipped its status) before any second path — the final
        partial flush, `_release_drained` and the quiescence sweep — can
        reach it, so a no-longer-draining occupant is simply skipped."""
        req = sched.request(slot)
        if req is None or req.status != "draining":
            return
        req = sched.release(slot)
        rep.completed.append(req.rid)

    def _release_drained(self, eng, sched, rep: BatchServeReport) -> None:
        for slot, req in list(sched.draining_items()):
            if eng.validated_frontier >= req.finish_step:
                self._finish(sched, slot, rep)

    def _handle_event(self, eng, recovery, sched, ring, event, dual,
                      rep: BatchServeReport, notify=None, expected=None,
                      consumer=None):
        """Per-request recovery: route the event through the engine (slot
        retry / ring restore), then apply the request-level consequences —
        token-stream truncation for rolled-back slots, eviction +
        notification for rejected requests, early release for draining
        slots a failed flush proved clean.

        Drain mode (`expected` is the host-side token-count map): the
        failed flush already retracted the faulty slots' un-drained rows
        from the emission ring, so there is no stream to truncate here —
        the restore just resets the slot's optimistic count to the restored
        position. The consumer is quiesced FIRST so rejection callbacks
        (and any reader of request streams) see the delivered prefix."""
        if consumer is not None:
            consumer.quiesce()
        try:
            dual = eng.on_detection(event, dual)
        except SedarSafeStop:
            rep.stopped = True
            return dual
        for slot in recovery.take_rejections():
            req = sched.request(slot)
            if req is not None:
                sched.reject(slot, "per-request safe stop: consecutive "
                             "retry budget exhausted")
                rep.rejected.append(req.rid)
                obs.note_rejection(event.step, rid=req.rid, slot=slot,
                                   reason="persistent_fault")
                if notify is not None:
                    notify(req, event)
            ring.evict(slot)
            if expected is not None:
                expected.pop(slot, None)
            dual = self._set_active(eng, dual, slot, False)
        for slot, info in recovery.take_restores().items():
            req = sched.request(slot)
            if req is None:
                continue
            rep.rollbacks += 1
            keep = max(info["pos"] - req.pos0 + 1, 1)
            if expected is not None:
                expected[slot] = keep
            elif len(req.tokens) > keep:
                cut = len(req.tokens) - keep
                req.truncated_tokens += cut
                rep.truncated_tokens += cut
                del req.tokens[keep:]
                del req.token_times[keep:]
            if req.status == "draining":
                sched.reactivate(slot)   # rollback reached its final window
        if event.boundary == "deferred":
            # the failed flush EXAMINED every parked predicate: draining
            # slots not implicated are proven clean through their final
            # step — release them now (the global frontier regressed to the
            # faulty step and would otherwise hold them hostage)
            bad = set(event.detail.get("slots", []))
            for slot, _req in list(sched.draining_items()):
                if slot not in bad:
                    self._finish(sched, slot, rep)
        return dual

    def serve(self, params, requests, *, slots: int = 4,
              max_len: Optional[int] = None, validate_lag: Optional[int] = None,
              queue_depth: int = 0, max_steps: Optional[int] = None,
              notify_reject=None, packed_prefill: bool = True,
              autotune=None, drain_cadence: Optional[int] = None,
              on_token=None, consumer_depth: int = 8):
        """Continuous-batching protected decode over an open-loop request
        stream. Mutates and returns the `Request` objects (lifecycle fields
        are reset first, so a template list can be replayed for fault-free
        twins) plus a `BatchServeReport`.

        `validate_lag` > 1 arms the deferred window: the fault-free decode
        step performs NO host sync (detection lags by <= D steps, and a
        detected fault rolls back only the affected slots from the Tier-0
        ring) — token emission itself is deferred to the flush cadence
        through the engine's TokenRing and streamed from a detokenize
        consumer thread (DESIGN.md §18). `drain_cadence` sets how many
        parked ticks a drain waits for (None -> the validate lag, i.e.
        every flush; 1 -> the legacy per-tick emission readback, kept as
        the bench baseline); `on_token(req, tok, index)` streams each
        delivered token (called from the consumer thread in drain mode);
        `consumer_depth` bounds the detokenize queue (backpressure).
        `queue_depth` bounds the admission queue (backpressure ->
        immediate rejection). `autotune` (a policy.Autotuner with
        mode="serve") live-retunes the lag at clean flush boundaries; the
        engine's reset() restores the configured lag for the next serve()
        call."""
        from repro.runtime.emission import DetokenizeConsumer, TokenRing
        from repro.runtime.prefill import group_packs
        from repro.runtime.scheduler import (DRAINING, RUNNING, RequestQueue,
                                             SlotScheduler)
        if self.cfg.model.frontend:
            raise NotImplementedError(
                "continuous batching serves token-prompt families; frontend "
                "(VLM/audio) prompts need per-request embed plumbing")
        rep = BatchServeReport()
        t0 = time.time()
        for r in requests:
            r.status, r.slot = "pending", None
            r.tokens, r.token_times = [], []
            r.pos0, r.admit_step, r.finish_step = 0, None, None
            r.truncated_tokens, r.reject_reason = 0, ""
            r.arrival_time = None
        max_prompt = max((r.prompt_len for r in requests), default=8)
        max_new = max((r.max_new_tokens for r in requests), default=8)
        max_len = max_len or (max_prompt + max_new + 8)
        lag = int(validate_lag
                  if validate_lag is not None
                  else getattr(self.cfg.sedar, "validate_lag", 1))
        eng, ring, recovery = self._batch_engine(slots, max_len, max(lag, 1))
        eng.reset()
        recovery.reset()
        self.inj_flag.reset()
        recovery.merge = lambda dual, slot, sl: self._write_slot(
            eng, dual, slot, sl, active=True)
        ring_on = eng.validate_lag > 1   # clamped lag => pre-commit gating
        # lag-aligned batched drain (DESIGN.md §18): tokens leave the
        # device through flush_deferred's fused readback and reach the
        # request streams via the consumer thread. Per-tick emission
        # survives as `drain_cadence=1` (and as the only mode at lag 1,
        # where every commit is already a sync point).
        drain_on = ring_on and (drain_cadence is None
                                or int(drain_cadence) > 1)
        tokring = consumer = None
        expected: Dict[int, int] = {}   # slot -> optimistic token count
        if drain_on:
            consumer = DetokenizeConsumer(on_token=on_token,
                                          max_queue=consumer_depth).start()
            tokring = TokenRing(
                cadence=(int(drain_cadence) if drain_cadence
                         else eng.validate_lag),
                sink=consumer.submit)
            eng.emission_ring = tokring

        sched = SlotScheduler(slots, RequestQueue(queue_depth))
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        cache1, _ = self.model.init_cache(1, max_len)
        state = {"cache": jax.tree.map(
                     lambda x: jnp.stack([x] * slots), cache1),
                 "tok": jnp.zeros((slots, 1), jnp.int32),
                 "pos": jnp.zeros((slots,), jnp.int32),
                 "active": jnp.zeros((slots,), jnp.bool_),
                 "t": jnp.asarray(0, jnp.int32)}
        dual = eng.executor.init_dual(state)

        # packed_prefill=False keeps the legacy one-launch-per-request
        # admission — the equality oracle (and bench baseline) for the
        # bucketed pack path
        use_packed = packed_prefill and self.prefiller.supported
        prefill_events: List[DetectionEvent] = []
        t = 0
        cap = max_steps or (sum(r.max_new_tokens for r in requests)
                            + len(requests)) * 4 + 64
        while t < cap and (pending or len(sched.queue) or sched.busy):
            # the autotuner may have moved the lag at the last boundary
            ring_on = eng.validate_lag > 1
            while pending and pending[0].arrival <= t:
                req = pending.pop(0)
                req.arrival_time = time.time()     # TTFT reference stamp
                if not sched.queue.offer(req):
                    rep.rejected.append(req.rid)   # backpressure shed
            pairs = sched.admit(t)
            if pairs and use_packed:
                packs, overflow = group_packs(
                    pairs, [req.prompt_len for _, req in pairs],
                    self.prefiller.usable_buckets(max_len),
                    self.prefiller.max_pack)
                for _bucket, chunk in packs:
                    dual = self._admit_pack(eng, dual, params, chunk, t,
                                            ring, ring_on, max_len, rep,
                                            sched, notify_reject,
                                            prefill_events)
                for slot, req in overflow:   # longer than the ladder
                    dual = self._admit_slot(eng, dual, params, slot, req, t,
                                            ring, ring_on, max_len)
            else:
                for slot, req in pairs:
                    dual = self._admit_slot(eng, dual, params, slot, req, t,
                                            ring, ring_on, max_len)
            for slot, req in pairs:
                if req.status == RUNNING and drain_on:
                    # the prefill token was validated and delivered at
                    # admission; the optimistic count starts there
                    expected[slot] = 1
                if (req.status == RUNNING
                        and len(req.tokens) >= req.max_new_tokens):
                    # budget of 1: the prefill token already fills it —
                    # its validation (if any) happened at admission, so
                    # release immediately
                    dual = self._set_active(eng, dual, slot, False)
                    sched.drain(slot, finish_step=t)
                    self._finish(sched, slot, rep)
            if not sched.running_items():
                if sched.draining_items():
                    ev = eng.flush_deferred()
                    if ev is not None:
                        dual = self._handle_event(
                            eng, recovery, sched, ring, ev, dual, rep,
                            notify_reject,
                            expected=expected if drain_on else None,
                            consumer=consumer)
                    self._release_drained(eng, sched, rep)
                    # quiescence: no runners, no parked predicates — the
                    # remaining drainers were never proven bad (their
                    # evidence either flushed clean or was consumed by an
                    # event that did not implicate them) and nothing will
                    # ever re-examine them; holding them would spin forever
                    if not eng.pending_validation and \
                            not sched.running_items():
                        for slot, _req in list(sched.draining_items()):
                            self._finish(sched, slot, rep)
                    continue
                if pending or len(sched.queue):
                    # idle tick awaiting arrivals: advance the DEVICE decode
                    # tick too — state['t'] gates injection firing while the
                    # engine's once-only flag is marked on the DRIVER step,
                    # so letting the clocks drift would disarm a campaign's
                    # fault before the device ever reached its step
                    dual = eng.executor.map_state(
                        lambda st: {**st, "t": st["t"] + 1}, dual)
                    t += 1
                    continue
                break
            if drain_on:
                # owner snapshot for the rows this tick will park: the
                # ring copies it, so a later admission reusing the slot
                # cannot reroute this window's tokens
                tokring.owners = dict(sched.running_items())
            with obs.span("decode_tick", step=t):
                outcome = eng.run_protected_step(dual, params, t)
            dual = outcome.dual
            rep.steps += 1
            if drain_on:
                # host-side optimistic accounting — no readback: every
                # running slot's device position advanced by one (a frozen
                # fused slot over-counts until its flush event resets the
                # count from the restored position)
                for slot, _req in sched.running_items():
                    expected[slot] = expected.get(slot, 1) + 1
            if outcome.event is not None:
                dual = self._handle_event(
                    eng, recovery, sched, ring, outcome.event, dual, rep,
                    notify_reject, expected=expected if drain_on else None,
                    consumer=consumer)
            elif ring_on and not eng.pending_validation:
                # clean flush boundary: cut the Tier-0 per-slot snapshots
                self._snapshot_slots(eng, dual, sched, ring, version=t + 1)
            if autotune is not None:
                autotune.maybe_tune(eng, t + 1)
                if drain_on and eng.validate_lag == 1:
                    # the tuner left deferred mode (reconfig applies only
                    # at a clean boundary, so the predicate ring is empty):
                    # deliver everything parked and drop back to per-tick
                    # emission — the lag-1 path never parks
                    eng.flush_deferred(final=True)
                    consumer.quiesce()
                    eng.emission_ring = None
                    drain_on = False
            if drain_on:
                # flush-edge semantics: budget decisions ride the host
                # count, tokens surface through the consumer at the drain
                # cadence, and drained slots release when a flush moved
                # the validated frontier past their finish step
                for slot, req in sched.running_items():
                    if expected.get(slot, 1) >= req.max_new_tokens:
                        sched.drain(slot, finish_step=t + 1)
                        dual = self._set_active(eng, dual, slot, False)
                if not eng.pending_validation:
                    self._release_drained(eng, sched, rep)
            else:
                # per-tick emission (lag 1, or drain_cadence=1 baseline):
                # tok + pos fetched in a single transfer batch; per-slot
                # position deltas drive emission, so partial commits
                # (faulty slot frozen) and rollbacks (position regressed)
                # need no special-casing here
                toks, poss = hostsync.batched_get(
                    [eng.executor.peek(dual, "tok"),
                     eng.executor.peek(dual, "pos")], label="token_emit")
                now_wall = time.time()
                for slot, req in sched.running_items():
                    target = int(poss[slot]) - req.pos0 + 1
                    if target == len(req.tokens) + 1:
                        req.tokens.append(int(toks[slot, 0]))
                        req.token_times.append(now_wall)
                        obs.note_tokens(1)
                        if on_token is not None:
                            on_token(req, req.tokens[-1],
                                     len(req.tokens) - 1)
                    if len(req.tokens) >= req.max_new_tokens:
                        sched.drain(slot, finish_step=t + 1)
                        dual = self._set_active(eng, dual, slot, False)
                        if eng.validate_lag == 1:
                            # immediate mode: every emitted token passed
                            # the commit gate (emission follows committed
                            # position deltas), so the stream is already
                            # validated even if ANOTHER slot's event kept
                            # the global frontier behind — release now
                            self._finish(sched, slot, rep)
                self._release_drained(eng, sched, rep)
            t += 1

        # final flush: validates (and in drain mode DRAINS) the partial
        # window left when the loop exits — `final=True` forces the drain
        # below the cadence so no token stays parked past the run
        ev = eng.flush_deferred(final=True)
        if ev is not None:
            dual = self._handle_event(
                eng, recovery, sched, ring, ev, dual, rep, notify_reject,
                expected=expected if drain_on else None, consumer=consumer)
        self._release_drained(eng, sched, rep)
        # quiescence: drainers whose evidence was consumed by an event they
        # were not implicated in (ring cleared, frontier regressed) have no
        # pending predicates left and were never proven bad — release.
        # `_finish` skips slots already released by the final flush's
        # delivery path, so a drainer finishing inside the final partial
        # window releases exactly once (no duplicate, none stranded).
        if not eng.pending_validation:
            for slot, req in list(sched.draining_items()):
                if req.status == DRAINING:
                    self._finish(sched, slot, rep)
        if consumer is not None:
            consumer.quiesce()
            consumer.close()
            eng.emission_ring = None
            # ring retraction replaced driver-side truncation: aggregate
            # the per-request counts the consumer accumulated
            rep.truncated_tokens = sum(r.truncated_tokens for r in requests)

        rep.detections = prefill_events + list(eng.detections)
        rep.retries = sum(1 for r in eng.recoveries if r["kind"] == "retry")
        rep.tokens_emitted = sum(len(r.tokens) for r in requests
                                 if r.status == "done")
        rep.wall_s = time.time() - t0
        return requests, rep
