"""Batched serving loop — a thin driver over the unified SEDAR engine.

Serving follows the paper's inference-side story: decoding is deterministic
(greedy or fixed-seed sampling), so a dual-replica serve step can compare
logits fingerprints before emitting tokens — "validate the message before
sending it to the user". The decode step runs through the SAME
`SedarEngine.run_protected_step()` as training: each replica owns a full
decode state image ({cache, tok, pos}), the TDC commit gate withholds the
token on a mismatch, and recovery is the L0 `RetryRecovery` policy
(re-execute the step; transient faults do not repeat), which gives serving
the same external retry accounting the L2/L3 levels use instead of a
bespoke guard loop.

DMR attribution limit: with two replicas a PERSISTENT state divergence
(e.g. an SDC committed into one replica's KV cache that only manifests at
later positions) cannot be attributed to the faulty replica, so it is not
repairable — after `max_retries` consecutive failed re-executions the
stream safe-stops rather than emit an unvalidated token (the paper's L1
guarantee; re-seeding one replica from the other would risk silently
emitting the corrupted stream). Sporadic transients never hit the budget:
a committed step resets the consecutive count (DESIGN.md §8).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core import hostsync
from repro.core.detection import DetectionEvent, SedarSafeStop
from repro.core.engine import BoundarySchedule, SedarEngine
from repro.core.fingerprint import (pytree_fingerprint,
                                    pytree_fingerprint_fused)
from repro.core.injection import InjectionSpec, MemoryInjectionFlag, \
    inject_tree
from repro.core.policy import make_engine
from repro.core.recovery import RetryRecovery
from repro.models import build_model


@dataclass
class ServeReport:
    tokens_emitted: int = 0
    detections: List[DetectionEvent] = field(default_factory=list)
    retries: int = 0
    stopped: bool = False          # retry budget exhausted (safe stop)
    wall_s: float = 0.0


class SedarServer:
    """Prefill once, then decode step-by-step (optionally dual-executed)."""

    def __init__(self, run_cfg: RunConfig, dual: bool = False,
                 inj_spec: Optional[InjectionSpec] = None,
                 max_retries: int = 8, backend: Optional[str] = None):
        self.cfg = run_cfg
        self.model = build_model(run_cfg.model)
        self.dual = dual
        self.inj_spec = inj_spec
        self.inj_flag = MemoryInjectionFlag()
        self._prefill = jax.jit(self._prefill_fn, static_argnums=(2,))
        self._decode = jax.jit(self._decode_fn)
        # Serving boundaries: TDC commit gate on every decode step; no
        # checkpoint boundary (the only mutable state is the KV cache,
        # recomputable from the prompt — recovery is re-execution). The
        # replica-free backends ("abft"/"hybrid", DESIGN.md §10) serve from
        # ONE decode state; hybrid additionally re-fingerprints the resident
        # {cache, tok} at the FSC cadence to catch at-rest cache corruption
        # that checksummed kernels cannot see. "fused" (DESIGN.md §11) runs
        # both decode replicas in one launch — token emission itself is the
        # only per-step readback left.
        backend = backend or ("sequential" if dual else "none")
        self.backend = backend
        fsc_interval = (int(run_cfg.sedar.param_validate_interval)
                        if backend == "hybrid" else 0)
        fp_tree = ((lambda s: {"cache": s["cache"], "tok": s["tok"]})
                   if backend in ("abft", "hybrid")
                   else (lambda s: {"tok": s["tok"]}))
        self.engine: SedarEngine = make_engine(
            run_cfg.sedar,
            backend=backend,
            step_fn=self._decode,
            state_fp_fn=jax.jit(lambda s: pytree_fingerprint(fp_tree(s))),
            fast_state_fp_fn=jax.jit(lambda s: pytree_fingerprint_fused(
                fp_tree(s))),
            schedule=BoundarySchedule(
                commit_interval=1, validate_interval=fsc_interval,
                checkpoint_interval=0,
                toe_timeout_s=run_cfg.sedar.toe_timeout_s),
            recovery=RetryRecovery(max_retries=max_retries),
            inj_spec=inj_spec, inj_flag=self.inj_flag,
            notify=lambda e: None)

    def _prefill_fn(self, params, batch, max_len):
        return self.model.prefill(params, batch, max_len)

    def _decode_fn(self, state, params, replica_id, armed):
        """Engine step_fn: (decode state, params-as-batch, rid, armed) ->
        (candidate state, logits fingerprint, logits)."""
        if self.inj_spec is not None:
            params = inject_tree(params, self.inj_spec, step=state["pos"],
                                 replica_id=replica_id, armed=armed)
        logits, cache = self.model.decode_step(params, state["cache"],
                                               state["tok"], state["pos"])
        fp = pytree_fingerprint_fused({"logits": logits})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cand = {"cache": cache, "tok": tok, "pos": state["pos"] + 1}
        return cand, fp, logits

    def generate(self, params, prompt_batch: Dict[str, Any], steps: int,
                 max_len: Optional[int] = None
                 ) -> "tuple[np.ndarray, ServeReport]":
        rep = ServeReport()
        t0 = time.time()
        eng = self.engine
        eng.reset()
        self.inj_flag.reset()
        if isinstance(eng.recovery, RetryRecovery):
            eng.recovery.reset()
        B, S = prompt_batch["tokens"].shape
        P = (self.cfg.model.frontend_seq
             if (self.cfg.model.frontend and self.cfg.model.family == "vlm") else 0)
        max_len = max_len or (S + P + steps + 8)
        logits, cache = self._prefill(params, prompt_batch, max_len)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        pos = S + P
        dual = eng.executor.init_dual(
            {"cache": cache, "tok": tok, "pos": jnp.asarray(pos, jnp.int32)})

        while len(out) < steps:
            outcome = eng.run_protected_step(dual, params, pos)
            dual = outcome.dual
            if outcome.event is not None:
                # validate-before-send: the token is NOT emitted; the step
                # re-executes via the engine's retry policy. (NB if the
                # decode step is ever ABFT-instrumented, a forward-corrected
                # commit advances the decode state here — emit its token
                # instead of re-executing; see abft/executor.py.)
                try:
                    dual = eng.on_detection(outcome.event, dual)
                except SedarSafeStop:
                    rep.stopped = True
                    break
                if hostsync.read_int(eng.executor.peek(dual, "pos"),
                                     label="decode_pos") > pos:
                    out.append(hostsync.read_scalar(
                        eng.executor.peek(dual, "tok"), label="token_emit"))
                    pos += 1
                continue
            # token emission is the product — the ONE per-step readback the
            # serving hot path keeps (validated by the commit gate above)
            out.append(hostsync.read_scalar(eng.executor.peek(dual, "tok"),
                                            label="token_emit"))
            pos += 1

        rep.detections = list(eng.detections)
        rep.retries = sum(1 for r in eng.recoveries
                          if r["kind"] in ("retry", "vote_retry"))
        rep.tokens_emitted = len(out) * B
        rep.wall_s = time.time() - t0
        return np.stack(out, axis=1), rep
