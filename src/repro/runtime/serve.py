"""Batched serving loop with optional SEDAR detection on the decode path.

Serving follows the paper's inference-side story: decoding is deterministic
(greedy or fixed-seed sampling), so a dual-replica serve step can compare
logits fingerprints before emitting tokens — "validate the message before
sending it to the user". Recovery for serving is trivial (recompute the
step), so only detection (L1) applies.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core.fingerprint import fingerprints_equal, pytree_fingerprint
from repro.core.injection import InjectionSpec, inject_tree
from repro.models import build_model


@dataclass
class ServeReport:
    tokens_emitted: int = 0
    detections: List[int] = field(default_factory=list)   # positions
    retries: int = 0
    wall_s: float = 0.0


class SedarServer:
    """Prefill once, then decode step-by-step (optionally dual-executed)."""

    def __init__(self, run_cfg: RunConfig, dual: bool = False,
                 inj_spec: Optional[InjectionSpec] = None):
        self.cfg = run_cfg
        self.model = build_model(run_cfg.model)
        self.dual = dual
        self.inj_spec = inj_spec
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn, static_argnums=(2,))

    def _prefill_fn(self, params, batch, max_len):
        return self.model.prefill(params, batch, max_len)

    def _decode_fn(self, params, cache, tokens, pos, replica_id, armed):
        if self.inj_spec is not None:
            params = inject_tree(params, self.inj_spec, step=pos,
                                 replica_id=replica_id, armed=armed)
        logits, cache = self.model.decode_step(params, cache, tokens, pos)
        fp = pytree_fingerprint({"logits": logits})
        return logits, cache, fp

    def generate(self, params, prompt_batch: Dict[str, Any], steps: int,
                 max_len: Optional[int] = None) -> "tuple[np.ndarray, ServeReport]":
        rep = ServeReport()
        t0 = time.time()
        B, S = prompt_batch["tokens"].shape
        P = (self.cfg.model.frontend_seq
             if (self.cfg.model.frontend and self.cfg.model.family == "vlm") else 0)
        max_len = max_len or (S + P + steps + 8)
        logits, cache = self._prefill(params, prompt_batch, max_len)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(tok)]
        pos = S + P
        armed = jnp.asarray(True)
        guard = 0
        while len(out) < steps and guard < 4 * steps:
            guard += 1
            l0, c0, fp0 = self._decode(params, cache, tok, jnp.asarray(pos),
                                       jnp.asarray(0), armed)
            if self.dual:
                l1, _, fp1 = self._decode(params, cache, tok, jnp.asarray(pos),
                                          jnp.asarray(1), armed)
                if not bool(np.asarray(fingerprints_equal(fp0, fp1))):
                    # SDC on the serve path: validate-before-send — the token
                    # is NOT emitted; the step re-executes (transient faults
                    # do not repeat)
                    rep.detections.append(pos)
                    rep.retries += 1
                    armed = jnp.asarray(False)
                    continue
            cache = c0
            tok = jnp.argmax(l0, axis=-1).astype(jnp.int32)
            out.append(np.asarray(tok))
            pos += 1
        rep.tokens_emitted = len(out) * B
        rep.wall_s = time.time() - t0
        return np.stack(out, axis=1), rep
