"""Continuous-batching request scheduler for protected serving (DESIGN.md §13).

Three pieces, all host-side and deliberately dumb about the model:

  * `Request`      -- one generation request's full lifecycle record:
                      prompt, budget, arrival tick, emitted tokens with
                      wall-clock stamps, and the slot/recovery bookkeeping
                      the per-request fault story needs (admit step, finish
                      step, truncation count, rejection reason).
  * `RequestQueue` -- bounded FIFO admission queue. `offer()` applies
                      BACKPRESSURE: when the queue is full the request is
                      rejected immediately (load shedding) instead of
                      growing an unbounded backlog behind a fault storm.
  * `SlotScheduler`-- maps requests onto the fixed set of decode slots the
                      packed batch exposes. Slots join/evict mid-flight: a
                      freed slot (finished, rejected) is refilled by the
                      next queued prompt on the SAME decode tick, so the
                      packed protected step always runs over whatever is
                      active — no synchronous wave barrier.

Slot lifecycle:   FREE -> RUNNING -> DRAINING -> FREE
                            ^           |
                            +-- rollback reactivation (deferred fault hit
                                the request's final window)

DRAINING exists because of deferred validation (DESIGN.md §11): a request
that reaches its token budget inside the optimistic window keeps its slot
reserved (decode frozen via the active mask) until the engine's validated
frontier passes its finish step — releasing it earlier could hand the slot
to a new prompt while a pending flush can still prove the old request's
tail corrupt and need the slot's state back for rollback.

The traffic generator (`synthetic_requests`) produces the open-loop replay
workload the launcher and benchmarks drive: Poisson-ish arrivals at a
configurable rate on the decode-tick clock, a categorical prompt-length
mix, and per-request token budgets — all seeded, so fault campaigns are
bitwise reproducible against their fault-free twins.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.obs import percentile

# Request lifecycle states
PENDING = "pending"      # created, not yet arrived
QUEUED = "queued"        # in the admission queue
RUNNING = "running"      # owns a slot, decoding
DRAINING = "draining"    # token budget reached, awaiting validation
DONE = "done"
REJECTED = "rejected"


@dataclass
class Request:
    """One generation request and its lifecycle record."""

    rid: int
    prompt: np.ndarray                    # (L,) int32 token ids
    max_new_tokens: int
    arrival: int = 0                      # decode tick of arrival (open loop)
    arrival_time: Optional[float] = None  # wall stamp at queue offer (TTFT)
    status: str = PENDING
    slot: Optional[int] = None
    tokens: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)  # wall stamps
    pos0: int = 0                         # decode position of the 1st token
    admit_step: Optional[int] = None
    finish_step: Optional[int] = None
    truncated_tokens: int = 0             # rolled back + re-decoded
    reject_reason: str = ""

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.status in (DONE, REJECTED)


class RequestQueue:
    """Bounded FIFO with admission control. `max_depth=0` disables the
    bound (accept everything)."""

    def __init__(self, max_depth: int = 0):
        self.max_depth = int(max_depth)
        self._q: deque = deque()
        self.rejected: List[Request] = []

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, req: Request) -> bool:
        """Enqueue, or shed load: a full queue rejects the request NOW
        (status=rejected, reason=backpressure) so callers see bounded
        latency instead of an unbounded backlog."""
        if self.max_depth and len(self._q) >= self.max_depth:
            req.status = REJECTED
            req.reject_reason = "backpressure"
            self.rejected.append(req)
            obs.note_rejection(-1, rid=req.rid, slot=None,
                               reason="backpressure")
            return False
        req.status = QUEUED
        self._q.append(req)
        if obs.metrics_enabled():
            obs.metrics.set_gauge("sedar_serve_queue_depth", len(self._q))
        return True

    def pop(self) -> Optional[Request]:
        req = self._q.popleft() if self._q else None
        if req is not None and obs.metrics_enabled():
            obs.metrics.set_gauge("sedar_serve_queue_depth", len(self._q))
        return req


class SlotScheduler:
    """Slot ownership + lifecycle over the packed decode batch."""

    def __init__(self, n_slots: int, queue: Optional[RequestQueue] = None):
        self.n_slots = int(n_slots)
        # `queue or ...` would discard an EMPTY bounded queue (falsy via
        # __len__) — the same bug class as ClusterMonitor's now=0.0
        self.queue = RequestQueue() if queue is None else queue
        self.slots: List[Optional[Request]] = [None] * self.n_slots

    # -- queries ---------------------------------------------------------------

    def request(self, slot: int) -> Optional[Request]:
        return self.slots[slot]

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def items(self, status: str) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.status == status]

    def running_items(self) -> List[Tuple[int, Request]]:
        return self.items(RUNNING)

    def draining_items(self) -> List[Tuple[int, Request]]:
        return self.items(DRAINING)

    @property
    def busy(self) -> bool:
        return any(r is not None for r in self.slots)

    # -- transitions -----------------------------------------------------------

    def admit(self, step: int) -> List[Tuple[int, Request]]:
        """Pair every free slot with the next queued request (FIFO). The
        caller prefills each pair into the packed state."""
        pairs: List[Tuple[int, Request]] = []
        for slot in self.free_slots():
            req = self.queue.pop()
            if req is None:
                break
            req.slot = slot
            req.status = RUNNING
            req.admit_step = step
            self.slots[slot] = req
            pairs.append((slot, req))
        return pairs

    def drain(self, slot: int, finish_step: int) -> None:
        req = self.slots[slot]
        req.status = DRAINING
        req.finish_step = finish_step

    def reactivate(self, slot: int) -> None:
        """Rollback reached into a draining request's final window: it
        resumes decoding its truncated tail."""
        req = self.slots[slot]
        req.status = RUNNING
        req.finish_step = None

    def release(self, slot: int) -> Request:
        req = self.slots[slot]
        req.status = DONE
        req.slot = None
        self.slots[slot] = None
        if obs.metrics_enabled() and \
                req.arrival_time is not None and req.token_times:
            obs.metrics.observe(
                "sedar_serve_ttft_seconds",
                req.token_times[0] - req.arrival_time)
        return req

    def reject(self, slot: int, reason: str) -> Request:
        req = self.slots[slot]
        req.status = REJECTED
        req.reject_reason = reason
        req.slot = None
        self.slots[slot] = None
        return req


# ---------------------------------------------------------------------------
# Open-loop traffic replay
# ---------------------------------------------------------------------------

def synthetic_requests(n: int, *, arrival_rate: float = 1.0,
                       prompt_lengths: Sequence[int] = (4, 8),
                       length_weights: Optional[Sequence[float]] = None,
                       max_new_choices: Sequence[int] = (4, 12),
                       vocab: int = 200, seed: int = 0) -> List[Request]:
    """Seeded open-loop workload: `n` requests with exponential inter-
    arrival gaps at `arrival_rate` requests per decode tick, prompt lengths
    drawn from the categorical mix, and per-request decode budgets from
    `max_new_choices`. Deterministic per seed, so a fault campaign's
    unaffected streams can be compared bitwise against the fault-free run."""
    rs = np.random.RandomState(seed)
    if length_weights is not None:
        w = np.asarray(length_weights, np.float64)
        w = w / w.sum()
    else:
        w = None
    out: List[Request] = []
    t = 0.0
    for rid in range(n):
        if rid:
            t += rs.exponential(1.0 / max(arrival_rate, 1e-9))
        L = int(rs.choice(list(prompt_lengths), p=w))
        out.append(Request(
            rid=rid,
            prompt=rs.randint(0, vocab, (L,)).astype(np.int32),
            max_new_tokens=int(rs.choice(list(max_new_choices))),
            arrival=int(t)))
    return out


def token_latencies(requests: Iterable[Request]) -> List[float]:
    """Per-token INTER-TOKEN gaps across a request set (the streaming
    cadence a client sees); see `ttft_latencies` for time-to-first-token."""
    out: List[float] = []
    for r in requests:
        ts = r.token_times
        out.extend(b - a for a, b in zip(ts, ts[1:]))
    return out


def ttft_latencies(requests: Iterable[Request]) -> List[float]:
    """Time-to-first-token per request: first emitted token's wall stamp
    minus the arrival stamp the serve loop cut at queue offer. Requests
    that never emitted (rejected before admission) are excluded — their
    latency is the rejection notice, not a token."""
    out: List[float] = []
    for r in requests:
        if r.arrival_time is not None and r.token_times:
            out.append(r.token_times[0] - r.arrival_time)
    return out


def ttft_percentiles_ms(requests: Iterable[Request]
                        ) -> Tuple[float, float]:
    """(p50, p99) time-to-first-token in milliseconds (0.0, 0.0 when no
    request emitted a first token); nearest-rank via `obs.percentile`."""
    lat = ttft_latencies(requests)
    if not lat:
        return 0.0, 0.0
    return 1e3 * percentile(lat, 50), 1e3 * percentile(lat, 99)


def latency_percentiles_ms(requests: Iterable[Request]
                           ) -> Tuple[float, float]:
    """(p50, p99) inter-token latency in milliseconds (0.0, 0.0 when fewer
    than two tokens were streamed); nearest-rank via `obs.percentile`."""
    lat = token_latencies(requests)
    if not lat:
        return 0.0, 0.0
    return 1e3 * percentile(lat, 50), 1e3 * percentile(lat, 99)


def ttlt_latencies(requests: Iterable[Request]) -> List[float]:
    """Time-to-LAST-token per request (total turnaround a client waits for
    the full stream): last emitted token's wall stamp minus arrival. Under
    lag-aligned drain (DESIGN.md §18) whole windows land at once, so TTLT
    — not the now-bursty inter-token gap — is the end-to-end latency that
    drain cadence actually trades against throughput."""
    out: List[float] = []
    for r in requests:
        if r.arrival_time is not None and r.token_times:
            out.append(r.token_times[-1] - r.arrival_time)
    return out


def ttlt_percentiles_ms(requests: Iterable[Request]
                        ) -> Tuple[float, float]:
    """(p50, p99) time-to-last-token in milliseconds (0.0, 0.0 when no
    request completed a token); nearest-rank via `obs.percentile`."""
    lat = ttlt_latencies(requests)
    if not lat:
        return 0.0, 0.0
    return 1e3 * percentile(lat, 50), 1e3 * percentile(lat, 99)


def stream_stats_ms(requests: Iterable[Request]) -> Dict[str, float]:
    """One bundle of client-visible streaming percentiles in ms: TTFT
    (first token), ITL (inter-token gap) and TTLT (full turnaround) —
    what bench_serve rows and the `--continuous` CLI summary print."""
    reqs = list(requests)
    ttft50, ttft99 = ttft_percentiles_ms(reqs)
    itl50, itl99 = latency_percentiles_ms(reqs)
    ttlt50, ttlt99 = ttlt_percentiles_ms(reqs)
    return {"ttft_p50_ms": ttft50, "ttft_p99_ms": ttft99,
            "itl_p50_ms": itl50, "itl_p99_ms": itl99,
            "ttlt_p50_ms": ttlt50, "ttlt_p99_ms": ttlt99}
