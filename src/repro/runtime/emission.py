"""Device-resident token emission ring + detokenize consumer (DESIGN.md §18).

Token emission used to be the last per-step device->host readback on the
serving hot path: every decode tick fetched `{tok, pos}` so the driver
could append to the per-request streams. But under deferred validation
(DESIGN.md §11) a token only becomes *externally visible truth* at a clean
flush — reading it back earlier buys nothing except a sync. This module
moves emission to the flush cadence:

  * `TokenRing`   -- the device-resident emission ring, the sibling of the
                     engine's commit-predicate ring. Each deferred step
                     PARKS its `(tok, pos)` device refs (no launch, no
                     readback — the refs the jitted step already produced)
                     together with a host-side snapshot of the slot->request
                     owner map. At a flush the ring hands the engine two
                     stacked leaves to FUSE into the same `batched_get` as
                     the combined commit predicate: one transfer batch per
                     `validate_lag` commits carries the predicate AND every
                     token of the window.
  * rollback retraction -- a failed flush localizes `slot_first_bad`; the
                     ring marks the faulty slots' rows at-or-after their
                     first bad step DEAD before anything is delivered, so a
                     slot rollback retracts its un-drained tokens by
                     construction. Clean slots' rows in the same window were
                     examined by the localization read and deliver normally.
  * `DetokenizeConsumer` -- a bounded-queue worker thread (the maxtext
                     decode/detokenize split): the driver submits drained
                     batches and immediately proceeds with the next window's
                     launches; the consumer walks each batch in step order
                     and appends to the request streams. A full queue blocks
                     the driver (backpressure); `quiesce()` drains the queue
                     before any decision that reads request streams
                     (rejection notify, end of run).

Delivered-prefix property: `deliver_batch` appends a token only when its
position extends the stream by exactly one (`target == len(tokens) + 1`),
so frozen slots, re-decoded steps after a rollback and duplicate drains all
collapse to exactly-once delivery per position — and nothing is ever
delivered that a later flush could invalidate, because every delivered row
was validated (or proven clean by the localization read) at its own flush.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs


class _Parked:
    """One decode tick's parked emission: device refs + host bookkeeping."""

    __slots__ = ("step", "tok", "pos", "owners", "dead", "dead_all")

    def __init__(self, step: int, tok, pos, owners: Dict[int, Any]):
        self.step = int(step)
        self.tok = tok                  # (N, 1) device ref
        self.pos = pos                  # (N,)  device ref
        self.owners = owners            # slot -> Request (snapshot at park)
        self.dead: Set[int] = set()     # slots retracted by a failed flush
        self.dead_all = False           # scalar-predicate fallback


@dataclass
class DrainBatch:
    """One drained window, fully on host: what the consumer thread walks."""

    steps: List[int]
    toks: np.ndarray                    # (W, N, 1)
    poss: np.ndarray                    # (W, N)
    owners: List[Dict[int, Any]]        # per-row slot -> Request
    dead: List[Set[int]]                # per-row retracted slots
    dead_all: List[bool]


class TokenRing:
    """Device-resident emission ring, drained at flush boundaries.

    The engine calls `park(step, aux)` inside the deferred step (before its
    own flush check, so a window's last token is never stranded past its
    flush), `provide(final=)` when assembling a flush readback, `truncate`
    on a failed flush and `deliver` with the fetched host arrays. The
    driver owns `owners` (slot -> Request for the slots active this tick)
    and `sink` (usually `DetokenizeConsumer.submit`)."""

    def __init__(self, cadence: int = 1,
                 extract: Optional[Callable[[Any], Tuple[Any, Any]]] = None,
                 sink: Optional[Callable[[DrainBatch], None]] = None,
                 on_token: Optional[Callable[..., None]] = None):
        self.cadence = max(int(cadence), 1)
        self.extract = extract or (lambda aux: (aux[0], aux[1]))
        self.sink = sink
        self.on_token = on_token
        self.owners: Dict[int, Any] = {}
        self._entries: List[_Parked] = []
        self.parked = 0                 # cumulative rows parked
        self.drains = 0                 # drain batches issued
        self.delivered = 0              # tokens appended (inline sink only)
        self.retracted = 0              # tokens retracted (inline sink only)

    def __len__(self) -> int:
        return len(self._entries)

    # -- engine-facing ------------------------------------------------------

    def park(self, step: int, aux) -> None:
        """Park one tick's emission refs. No launch, no readback — the refs
        are the jitted step's own outputs; `owners` is snapshotted so a
        later admission reusing the slot cannot reroute old rows."""
        tok, pos = self.extract(aux)
        self._entries.append(_Parked(step, tok, pos, dict(self.owners)))
        self.parked += 1

    def provide(self, final: bool = False) -> Optional[List[Any]]:
        """Leaves to fuse into the flush readback: `[toks, poss]` stacked
        over the parked window, or None while the drain cadence says keep
        parking (a sub-cadence flush still validates predicates; the rows
        ride along until the cadence fills or the run ends)."""
        if not self._entries:
            return None
        if not final and len(self._entries) < self.cadence:
            return None
        return [jnp.stack([e.tok for e in self._entries]),
                jnp.stack([e.pos for e in self._entries])]

    def truncate(self, slot_first_bad: Optional[Dict[int, int]],
                 global_bad: Optional[int] = None) -> None:
        """Failed-flush retraction: mark faulty slots' rows at-or-after
        their first bad step dead. Applies only to rows parked so far —
        re-decoded rows parked after the rollback are new evidence and
        deliver normally (the position guard de-duplicates)."""
        for e in self._entries:
            if slot_first_bad:
                for slot, fb in slot_first_bad.items():
                    if e.step >= fb:
                        e.dead.add(int(slot))
            elif global_bad is not None and e.step >= global_bad:
                e.dead_all = True

    def deliver(self, vals: List[Any]) -> Optional[DrainBatch]:
        """Hand the fetched window to the sink and reset the ring. `vals`
        must be the host arrays for the leaves `provide()` returned."""
        if not self._entries:
            return None
        toks, poss = np.asarray(vals[0]), np.asarray(vals[1])
        batch = DrainBatch(
            steps=[e.step for e in self._entries],
            toks=toks, poss=poss,
            owners=[e.owners for e in self._entries],
            dead=[e.dead for e in self._entries],
            dead_all=[e.dead_all for e in self._entries])
        self._entries.clear()
        self.drains += 1
        obs.note_drain(len(batch.steps))
        if self.sink is not None:
            self.sink(batch)
        else:
            d, r = deliver_batch(batch, self.on_token)
            self.delivered += d
            self.retracted += r
        return batch

    def clear(self) -> None:
        self._entries.clear()
        self.owners = {}


def deliver_batch(batch: DrainBatch,
                  on_token: Optional[Callable[..., None]] = None,
                  now: Optional[float] = None) -> Tuple[int, int]:
    """Walk one drained window in step order, appending each row's token to
    its owner request when the position extends the stream by exactly one.

    Dead rows (retracted by a failed flush) are counted against the owner's
    `truncated_tokens` when they WOULD have extended the stream — the
    "rolled back + redone" semantics of the per-tick path, tracked through
    a virtual length so a frozen slot's repeated position is not
    over-counted. Returns (delivered, retracted)."""
    stamp = time.time() if now is None else now
    delivered = retracted = 0
    virt: Dict[int, int] = {}           # id(req) -> len(tokens) + retracted
    for i in range(len(batch.steps)):
        owners, dead, dead_all = (batch.owners[i], batch.dead[i],
                                  batch.dead_all[i])
        for slot, req in owners.items():
            target = int(batch.poss[i, slot]) - req.pos0 + 1
            if dead_all or slot in dead:
                v = virt.get(id(req), len(req.tokens))
                if target == v + 1:
                    virt[id(req)] = v + 1
                    req.truncated_tokens += 1
                    retracted += 1
                continue
            if target == len(req.tokens) + 1:
                req.tokens.append(int(batch.toks[i, slot, 0]))
                req.token_times.append(stamp)
                virt[id(req)] = len(req.tokens)
                if on_token is not None:
                    on_token(req, req.tokens[-1], len(req.tokens) - 1)
                delivered += 1
    obs.note_tokens(delivered)
    return delivered, retracted


_STOP = object()


class DetokenizeConsumer:
    """Bounded-queue detokenize thread (maxtext decode/detokenize split).

    The driver `submit()`s drained batches; the worker walks them with
    `deliver_batch` while the driver launches the next window. A full queue
    blocks `submit` (backpressure bounds memory behind a slow client).
    `quiesce()` joins the queue — call it before reading request streams
    (rejection notify, safe-stop, end of run); `close()` shuts the worker
    down after processing everything already queued."""

    def __init__(self, on_token: Optional[Callable[..., None]] = None,
                 max_queue: int = 8):
        self.on_token = on_token
        self._q: "queue.Queue" = queue.Queue(maxsize=max(int(max_queue), 1))
        self._thread: Optional[threading.Thread] = None
        self.delivered = 0
        self.retracted = 0
        self.batches = 0
        self.backlog_peak = 0
        self.errors: List[BaseException] = []

    def start(self) -> "DetokenizeConsumer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="sedar-detokenize", daemon=True)
            self._thread.start()
        return self

    def submit(self, batch: DrainBatch) -> None:
        if self._thread is None:        # inline fallback (no thread started)
            self._consume(batch)
            return
        self._q.put(batch)              # blocks when full: backpressure
        depth = self._q.qsize()
        if depth > self.backlog_peak:
            self.backlog_peak = depth
        if obs.metrics_enabled():
            obs.metrics.set_gauge("sedar_serve_consumer_backlog", depth)

    def _consume(self, batch: DrainBatch) -> None:
        with obs.span("detokenize", rows=len(batch.steps)):
            d, r = deliver_batch(batch, self.on_token)
        self.delivered += d
        self.retracted += r
        self.batches += 1

    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                self._consume(item)
            except BaseException as exc:   # noqa: BLE001 — surfaced at close
                self.errors.append(exc)
            finally:
                self._q.task_done()
                if obs.metrics_enabled():
                    obs.metrics.set_gauge("sedar_serve_consumer_backlog",
                                          self._q.qsize())

    def quiesce(self) -> None:
        """Block until every submitted batch has been fully delivered."""
        if self._thread is not None:
            self._q.join()

    def close(self) -> None:
        """Drain the queue, stop the worker, surface any worker error."""
        if self._thread is not None:
            self._q.put(_STOP)
            self._thread.join()
            self._thread = None
        if self.errors:
            raise self.errors[0]
