"""SEDAR training runtime: replicated step execution + leveled recovery.

Execution backends (SedarConfig.replication):
  * "none"       : plain training, no protection (half of the paper's manual
                   baseline; see also --manual-vote in launch/train.py).
  * "sequential" : both replicas run on the same devices one after the other
                   (time redundancy). Each replica owns a FULL TrainState —
                   the analogue of the paper's per-thread memory image — so
                   FSC-class corruption is representable and detectable.
  * "pod"        : replicas are pods of the production mesh (space
                   redundancy): one jit'd step, state logically replicated
                   over the "pod" axis, fingerprints exchanged with an
                   explicit all-gather inside shard_map.

Step anatomy (sequential):
    replica_step : grads -> [inject] -> grad fingerprint -> optimizer commit
                   candidate; returns (candidate_state, fp, loss)
    commit       : compare fingerprints; adopt candidates only when equal
                   (containment: a corrupted update is never committed —
                   the paper's validate-before-send)
    validate     : full-state fingerprints compared every
                   param_validate_interval steps (final-result compare)
    checkpoint   : L2 snapshots the dual state; L3 validates-then-commits a
                   single state (Algorithms 1 / 2 in core/recovery.py)
"""
from __future__ import annotations

import dataclasses
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.core.detection import (DetectionEvent, SedarSafeStop, Watchdog,
                                  make_pod_comparator, make_pod_injector)
from repro.core.fingerprint import (fingerprints_equal, mismatch_report,
                                    pytree_fingerprint)
from repro.core.injection import InjectionFlag, InjectionSpec, inject_tree
from repro.core.recovery import (MultiCheckpointRecovery, RecoveryAction,
                                 SafeStop, ValidatedCheckpointRecovery,
                                 make_recovery)
from repro.data import make_pipeline
from repro.models import build_model
from repro.optim import apply_updates, make_optimizer


@dataclass
class TrainReport:
    steps_completed: int = 0
    losses: List[float] = field(default_factory=list)
    detections: List[DetectionEvent] = field(default_factory=list)
    recoveries: List[Dict[str, Any]] = field(default_factory=list)
    checkpoints: List[int] = field(default_factory=list)
    stopped: bool = False
    wall_s: float = 0.0
    final_state_fp: Optional[np.ndarray] = None

    def summary(self) -> str:
        return (f"steps={self.steps_completed} detections={len(self.detections)} "
                f"recoveries={len(self.recoveries)} ckpts={len(self.checkpoints)} "
                f"stopped={self.stopped} wall={self.wall_s:.1f}s "
                f"loss={self.losses[-1] if self.losses else float('nan'):.4f}")


class SedarTrainer:
    """Drives SEDAR-protected training of any registered architecture."""

    def __init__(self, run_cfg: RunConfig, workdir: str,
                 mesh=None, rules=None,
                 inj_spec: Optional[InjectionSpec] = None,
                 toe_delay: Optional[Dict[str, Any]] = None,
                 data=None, notify: Optional[Callable] = None):
        self.cfg = run_cfg
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.model = build_model(run_cfg.model)
        self.opt = make_optimizer(run_cfg.train)
        self.mesh = mesh
        self.rules = rules
        self.backend = run_cfg.sedar.replication
        self.inj_spec = inj_spec
        self.inj_flag = InjectionFlag(os.path.join(workdir, "injected.json"))
        self.toe_delay = toe_delay or {}
        self.data = data or make_pipeline(run_cfg.model,
                                          run_cfg.train.global_batch,
                                          run_cfg.train.seq_len,
                                          run_cfg.train.seed)
        sedar = dataclasses.replace(run_cfg.sedar,
                                    checkpoint_dir=os.path.join(workdir, "ckpt"))
        self.sedar = sedar
        self.recovery = make_recovery(sedar, workdir)
        self.watchdog = Watchdog(sedar.toe_timeout_s)
        self.notify = notify or (lambda e: print(str(e), flush=True))
        self._build_step_fns()

    # -- state ---------------------------------------------------------------

    def init_state(self, seed: Optional[int] = None):
        key = jax.random.PRNGKey(self.cfg.train.seed if seed is None else seed)
        params = self.model.init(key)
        return {"params": params, "opt": self.opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def init_dual(self, seed: Optional[int] = None):
        s = self.init_state(seed)
        if self.backend == "sequential":
            return {"r0": s, "r1": jax.tree.map(jnp.copy, s)}
        return {"r0": s}   # pod / none: one logical copy

    # -- jitted step functions ---------------------------------------------------

    def _build_step_fns(self):
        model, opt, cfg = self.model, self.opt, self.cfg
        spec = self.inj_spec
        compare_full = (self.sedar.compare == "full")

        def grad_fp(grads):
            if compare_full:
                # paper's exact mode: compare entire buffers -> fingerprint
                # is the identity on a few probe elements + full hash anyway
                return pytree_fingerprint(grads)
            return pytree_fingerprint(grads)

        def replica_step(state, batch, replica_id, armed):
            def loss_fn(p):
                return model.loss(p, batch)[0]

            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            if spec is not None and spec.target == "grads":
                grads = inject_tree(grads, spec, step=state["step"],
                                    replica_id=replica_id, armed=armed)
            fp = grad_fp(grads)
            updates, new_opt = opt.update(grads, state["opt"],
                                          state["params"], state["step"])
            new_params = apply_updates(state["params"], updates)
            if spec is not None and spec.target == "params":
                new_params = inject_tree(new_params, spec, step=state["step"],
                                         replica_id=replica_id, armed=armed)
            if spec is not None and spec.target == "opt_state":
                new_opt = inject_tree(new_opt, spec, step=state["step"],
                                      replica_id=replica_id, armed=armed)
            cand = {"params": new_params, "opt": new_opt,
                    "step": state["step"] + 1}
            return cand, fp, loss

        def state_fp(state):
            return pytree_fingerprint({"params": state["params"],
                                       "opt": state["opt"]})

        self._replica_step = jax.jit(replica_step, static_argnums=())
        self._state_fp = jax.jit(state_fp)

        def commit(match, cand, old):
            return jax.tree.map(
                lambda a, b: jnp.where(match, a, b), cand, old)

        self._commit = jax.jit(commit)

        if self.backend in ("pod", "vote"):
            assert self.mesh is not None, "pod backend requires a mesh"
            self._pod_cmp = make_pod_comparator(self.mesh,
                                                self.sedar.replica_axis)
            if self.backend == "vote":
                from repro.core.detection import make_pod_broadcaster
                self._pod_bcast = make_pod_broadcaster(
                    self.mesh, self.sedar.replica_axis)
            self._pod_inject = (make_pod_injector(self.mesh, spec,
                                                  self.sedar.replica_axis)
                                if spec is not None else None)

            def pod_step(state, batch, armed):
                def loss_fn(p):
                    return model.loss(p, batch)[0]

                loss, grads = jax.value_and_grad(loss_fn)(state["params"])
                if self._pod_inject is not None and spec.target == "grads":
                    grads = jax.lax.cond(
                        armed,
                        lambda g: self._pod_inject(g, state["step"]),
                        lambda g: g, grads)
                fp = grad_fp(grads)
                eq, fp_all = self._pod_cmp(fp)
                updates, new_opt = opt.update(grads, state["opt"],
                                              state["params"], state["step"])
                new_params = apply_updates(state["params"], updates)
                if self._pod_inject is not None and spec.target == "params":
                    new_params = jax.lax.cond(
                        armed,
                        lambda p: self._pod_inject(p, state["step"]),
                        lambda p: p, new_params)
                cand = {"params": new_params, "opt": new_opt,
                        "step": state["step"] + 1}
                new_state = jax.tree.map(lambda a, b: jnp.where(eq, a, b),
                                         cand, state)
                return new_state, eq, fp_all, loss

            def pod_validate(state):
                fp = state_fp(state)
                return self._pod_cmp(fp)

            self._pod_step = jax.jit(pod_step)
            self._pod_validate = jax.jit(pod_validate)

    # -- driver -----------------------------------------------------------------

    def run(self, num_steps: int, dual=None, max_wall_steps: Optional[int] = None
            ) -> "tuple[dict, TrainReport]":
        rep = TrainReport()
        t0 = time.time()
        dual = dual or self.init_dual()
        budget = max_wall_steps or (6 * num_steps + 60)
        executed = 0

        while int(np.asarray(dual["r0"]["step"])) < num_steps:
            if executed >= budget:
                rep.stopped = True
                break
            executed += 1
            step = int(np.asarray(dual["r0"]["step"]))
            batch = {k: jnp.asarray(v) for k, v in
                     self.data.batch(step).items()}
            armed = jnp.asarray(1 if self.inj_flag.arm_spec(self.inj_spec)
                                else 0, jnp.bool_)
            try:
                if self.backend == "none":
                    dual, loss = self._step_plain(dual, batch, armed)
                elif self.backend in ("pod", "vote"):
                    dual, loss, event = self._step_pod(dual, batch, armed, step)
                    if event:
                        dual = self._handle(event, dual, rep)
                        continue
                else:
                    dual, loss, event = self._step_sequential(dual, batch,
                                                              armed, step)
                    if event:
                        dual = self._handle(event, dual, rep)
                        continue
            except SedarSafeStop:
                rep.stopped = True
                break
            rep.losses.append(float(np.asarray(loss)))
            new_step = step + 1

            # FSC boundary: full-state validation
            if (self.backend in ("sequential", "pod", "vote")
                    and new_step % self.sedar.param_validate_interval == 0):
                event = self._validate_states(dual, new_step)
                if event:
                    dual = self._handle(event, dual, rep)
                    continue

            # checkpoint boundary (right after validation — minimal window
            # of vulnerability, paper Sec. 3.2)
            dual, ck_event = self._maybe_checkpoint(dual, new_step, rep)
            if ck_event:
                dual = self._handle(ck_event, dual, rep)
                continue

        # final validation (paper: final results comparison)
        if self.backend in ("sequential", "pod", "vote") and not rep.stopped:
            event = self._validate_states(dual,
                                          int(np.asarray(dual["r0"]["step"])))
            if event is not None:
                event.boundary = "final"
                dual = self._handle(event, dual, rep)
        rep.steps_completed = int(np.asarray(dual["r0"]["step"]))
        rep.final_state_fp = np.asarray(self._state_fp(dual["r0"]))
        rep.wall_s = time.time() - t0
        return dual, rep

    # -- backend steps -------------------------------------------------------------

    def _step_plain(self, dual, batch, armed):
        cand, fp, loss = self._replica_step(dual["r0"], batch,
                                            jnp.asarray(0), armed)
        if self.inj_spec and not self.inj_flag.already_injected() and \
                int(np.asarray(dual["r0"]["step"])) == self.inj_spec.step:
            self.inj_flag.mark()
        return {"r0": cand}, loss

    def _step_sequential(self, dual, batch, armed, step):
        outs = {}
        exec_t = {}
        for rid in (0, 1):
            # one-shot scenario hook (the paper injects the delay once; the
            # re-execution after recovery is not delayed again)
            delay = self.toe_delay.pop((step, rid), None)
            t_r = time.monotonic()
            if delay:
                time.sleep(delay)
            outs[rid] = self._replica_step(dual[f"r{rid}"], batch,
                                           jnp.asarray(rid), armed)
            jax.block_until_ready(outs[rid][1])
            exec_t[rid] = time.monotonic() - t_r
            self.watchdog.beat(rid, step)
        if self.inj_spec and not self.inj_flag.already_injected() and \
                step == self.inj_spec.step:
            self.inj_flag.mark()

        # TOE: replica flow separation beyond the configured lapse
        dt0 = exec_t[0]
        dt1 = exec_t[1]
        if abs(dt1 - dt0) > self.sedar.toe_timeout_s:
            return dual, outs[0][2], DetectionEvent(
                step=step, boundary="toe", effect="TOE",
                detail={"dt0": dt0, "dt1": dt1,
                        "timeout_s": self.sedar.toe_timeout_s})

        (c0, fp0, loss0), (c1, fp1, loss1) = outs[0], outs[1]
        match = bool(np.asarray(fingerprints_equal(fp0, fp1)))
        if not match:
            detail = {"mismatch": mismatch_report(c0["params"], fp0, fp1)[:4]}
            return dual, loss0, DetectionEvent(step=step, boundary="commit",
                                               effect="TDC", detail=detail)
        new_dual = {"r0": self._commit(jnp.asarray(True), c0, dual["r0"]),
                    "r1": self._commit(jnp.asarray(True), c1, dual["r1"])}
        return new_dual, loss0, None

    def _step_pod(self, dual, batch, armed, step):
        new_state, eq, fp_all, loss = self._pod_step(dual["r0"], batch, armed)
        if self.inj_spec and not self.inj_flag.already_injected() and \
                step == self.inj_spec.step:
            self.inj_flag.mark()
        if not bool(np.asarray(eq)):
            return dual, loss, DetectionEvent(step=step, boundary="commit",
                                              effect="TDC")
        return {"r0": new_state}, loss, None

    # -- validation / checkpoint / recovery --------------------------------------------

    def _validate_states(self, dual, step) -> Optional[DetectionEvent]:
        if self.backend in ("pod", "vote"):
            eq, fp_all = self._pod_validate(dual["r0"])
            ok = bool(np.asarray(eq))
            if not ok:
                return DetectionEvent(step=step, boundary="validate",
                                      effect="FSC",
                                      detail={"fp_all": np.asarray(fp_all)})
            return None
        fp0 = self._state_fp(dual["r0"])
        fp1 = self._state_fp(dual["r1"])
        if bool(np.asarray(fingerprints_equal(fp0, fp1))):
            return None
        return DetectionEvent(step=step, boundary="validate", effect="FSC")

    def _state_fingerprints(self, dual):
        fp0 = self._state_fp(dual["r0"])
        if self.backend == "sequential":
            fp1 = self._state_fp(dual["r1"])
            return fp0, fp1
        return fp0, fp0

    def _maybe_checkpoint(self, dual, step, rep):
        r = self.recovery
        if isinstance(r, SafeStop):
            return dual, None
        if isinstance(r, MultiCheckpointRecovery):
            if r.maybe_checkpoint(step, dual,
                                  np.asarray(self._state_fp(dual["r0"]))):
                rep.checkpoints.append(step)
            return dual, None
        if isinstance(r, ValidatedCheckpointRecovery):
            if step == 0 or step % r.interval != 0:
                return dual, None
            fp0, fp1 = self._state_fingerprints(dual)
            if self.backend == "pod":
                eq, _ = self._pod_validate(dual["r0"])
                fp_equal = bool(np.asarray(eq))
            else:
                fp_equal = bool(np.asarray(fingerprints_equal(fp0, fp1)))
            ev = r.maybe_checkpoint(step, dual, np.asarray(fp0),
                                    fp_equal=fp_equal)
            if ev is None:
                rep.checkpoints.append(step)
            return dual, ev
        return dual, None

    def _handle(self, event: DetectionEvent, dual, rep) -> dict:
        rep.detections.append(event)
        self.notify(event)
        # beyond-paper N-modular redundancy: with >=3 replicas, a state
        # divergence is repaired FORWARD by broadcasting the majority
        # replica's state — no rollback, no recomputation (DESIGN.md §6)
        if (self.backend == "vote" and "fp_all" in event.detail
                and event.boundary in ("validate", "final")):
            from repro.core.detection import majority_replica
            src, ok = majority_replica(event.detail["fp_all"])
            if ok:
                repaired = self._pod_bcast(src)(dual["r0"])
                rep.recoveries.append({"kind": "vote_repair", "step": None,
                                       "rollbacks": 0, "at": event.step,
                                       "src_replica": src})
                return {"r0": repaired}
        if self.backend == "vote" and event.boundary == "commit":
            # transient gradient fault: simple re-execution (no rollback)
            rep.recoveries.append({"kind": "vote_retry", "step": None,
                                   "rollbacks": 0, "at": event.step})
            return dual
        action = self.recovery.on_detection(event)
        rep.recoveries.append({"kind": action.kind, "step": action.step,
                               "rollbacks": action.rollbacks,
                               "at": event.step})
        if action.kind == "stop":
            raise SedarSafeStop(event)
        if action.kind == "restart_scratch":
            return self.init_dual()
        # restore
        if isinstance(self.recovery, ValidatedCheckpointRecovery):
            single = self.recovery.restore(action, self._template_single(dual))
            single = jax.tree.map(jnp.asarray, single)
            if self.backend == "sequential":
                return {"r0": single, "r1": jax.tree.map(jnp.copy, single)}
            return {"r0": single}
        restored = self.recovery.restore(action, dual)
        return jax.tree.map(jnp.asarray, restored)

    def _template_single(self, dual):
        return dual["r0"]
