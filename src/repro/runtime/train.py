"""SEDAR training runtime — a thin driver over the unified engine.

All detection/recovery protocol (replica comparison, TDC commit gate, FSC
validation, TOE watchdog, checkpoint boundaries, L1/L2/L3 + NMR recovery)
lives in `repro.core.engine.SedarEngine`; this module only supplies the
training-specific pieces:

  * the jit'd replica step (grads -> [inject] -> update fingerprint ->
    optimizer commit candidate),
  * state fingerprints (per-leaf for reports/localization; fused whole-state
    for the hot comparison path when SedarConfig.fused_fingerprint),
  * the pod/vote shard_map step for space redundancy, and
  * the outer loop (data, loss bookkeeping, wall budget).

Execution backends (SedarConfig.replication): "none", "sequential", "pod",
"vote", "abft", "hybrid" — see core/engine.py, abft/executor.py and
DESIGN.md §4/§10 for their semantics. The replica-free abft/hybrid backends
run this driver unchanged (single state image; detection comes from
checksummed kernels in the step — when instrumented — plus hybrid's
commit-time fingerprint validation).
"""
from __future__ import annotations

import dataclasses
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import RunConfig
from repro.core import hostsync
from repro.core.detection import (DetectionEvent, SedarSafeStop, Watchdog,
                                  make_pod_comparator, make_pod_injector)
from repro.core.engine import SedarEngine
from repro.core.fingerprint import (pytree_fingerprint,
                                    pytree_fingerprint_fused)
from repro.core.injection import InjectionFlag, InjectionSpec, inject_tree
from repro.core.policy import make_engine
from repro.core.recovery import make_recovery
from repro.data import make_pipeline
from repro.models import build_model
from repro.optim import apply_updates, make_optimizer


@dataclass
class TrainReport:
    steps_completed: int = 0
    losses: List[float] = field(default_factory=list)
    detections: List[DetectionEvent] = field(default_factory=list)
    recoveries: List[Dict[str, Any]] = field(default_factory=list)
    checkpoints: List[int] = field(default_factory=list)
    stopped: bool = False
    wall_s: float = 0.0
    final_state_fp: Optional[np.ndarray] = None
    # which checkpoint tier each rollback restore was served from
    # (DESIGN.md §12; empty for flat-disk configs or runs without recovery)
    restored_from: List[str] = field(default_factory=list)

    def summary(self) -> str:
        tiers = f" restored_from={self.restored_from}" \
            if self.restored_from else ""
        return (f"steps={self.steps_completed} detections={len(self.detections)} "
                f"recoveries={len(self.recoveries)} ckpts={len(self.checkpoints)} "
                f"stopped={self.stopped} wall={self.wall_s:.1f}s "
                f"loss={self.losses[-1] if self.losses else float('nan'):.4f}"
                f"{tiers}")


class SedarTrainer:
    """Drives SEDAR-protected training of any registered architecture."""

    def __init__(self, run_cfg: RunConfig, workdir: str,
                 mesh=None, rules=None,
                 inj_spec: Optional[InjectionSpec] = None,
                 toe_delay: Optional[Dict[str, Any]] = None,
                 data=None, notify: Optional[Callable] = None,
                 hosts_per_data_shard: int = 1,
                 autotune=None):
        self.cfg = run_cfg
        self.workdir = workdir
        # closed-loop knob tuning (DESIGN.md §17): a policy.Autotuner whose
        # maybe_tune() ticks after every committed step
        self.autotune = autotune
        os.makedirs(workdir, exist_ok=True)
        self.model = build_model(run_cfg.model)
        self.opt = make_optimizer(run_cfg.train)
        self.mesh = mesh
        self.rules = rules
        self.backend = run_cfg.sedar.replication
        self.inj_spec = inj_spec
        self.inj_flag = InjectionFlag(os.path.join(workdir, "injected.json"))
        self.toe_delay = toe_delay or {}
        self.hosts_per_data_shard = max(int(hosts_per_data_shard), 1)
        self.data = data or make_pipeline(run_cfg.model,
                                          run_cfg.train.global_batch,
                                          run_cfg.train.seq_len,
                                          run_cfg.train.seed)
        sedar = dataclasses.replace(run_cfg.sedar,
                                    checkpoint_dir=os.path.join(workdir, "ckpt"))
        self.sedar = sedar
        self.recovery = make_recovery(sedar, workdir)
        self.watchdog = Watchdog(sedar.toe_timeout_s)
        self.notify = notify or (lambda e: print(str(e), flush=True))
        self._build_step_fns()
        self.engine: SedarEngine = make_engine(
            sedar, backend=self.backend,
            step_fn=self._replica_step, state_fp_fn=self._state_fp,
            fast_state_fp_fn=self._state_fp_fast,
            pod_step=getattr(self, "_pod_step", None),
            pod_validate=getattr(self, "_pod_validate", None),
            pod_broadcaster=getattr(self, "_pod_bcast", None),
            n_replicas=(self.mesh.shape[sedar.replica_axis]
                        if self.backend in ("pod", "vote") else 2),
            lane_hosts=getattr(self, "_lane_hosts", None),
            recovery=self.recovery, watchdog=self.watchdog,
            inj_spec=inj_spec, inj_flag=self.inj_flag,
            init_fn=self.init_dual, notify=self.notify,
            delay_source=lambda: self.toe_delay,
            donate=run_cfg.train.donate_state)

    # -- state ---------------------------------------------------------------

    def init_state(self, seed: Optional[int] = None):
        key = jax.random.PRNGKey(self.cfg.train.seed if seed is None else seed)
        params = self.model.init(key)
        return {"params": params, "opt": self.opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def init_dual(self, seed: Optional[int] = None):
        # the executor owns the dual representation ({"r0","r1"} images,
        # {"s"} stacked, {"r0"} per-pod) and any baseline state it keeps
        # (e.g. the hybrid fingerprint baseline on restart-from-scratch)
        return self.engine.executor.init_dual(self.init_state(seed))

    # -- jitted step functions ------------------------------------------------

    def _build_step_fns(self):
        model, opt = self.model, self.opt
        spec = self.inj_spec
        fused = bool(self.sedar.fused_fingerprint)

        def grad_fp(grads):
            # fused: ONE whole-state pass over the packed update buffer
            # (compare == "full" degenerates to the same fingerprint — the
            # hash covers every bit either way)
            if fused:
                return pytree_fingerprint_fused(grads)
            return pytree_fingerprint(grads)

        def replica_step(state, batch, replica_id, armed):
            def loss_fn(p):
                return model.loss(p, batch)[0]

            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            if spec is not None and spec.target == "grads":
                grads = inject_tree(grads, spec, step=state["step"],
                                    replica_id=replica_id, armed=armed)
            fp = grad_fp(grads)
            updates, new_opt = opt.update(grads, state["opt"],
                                          state["params"], state["step"])
            new_params = apply_updates(state["params"], updates)
            if spec is not None and spec.target == "params":
                new_params = inject_tree(new_params, spec, step=state["step"],
                                         replica_id=replica_id, armed=armed)
            if spec is not None and spec.target == "opt_state":
                new_opt = inject_tree(new_opt, spec, step=state["step"],
                                      replica_id=replica_id, armed=armed)
            cand = {"params": new_params, "opt": new_opt,
                    "step": state["step"] + 1}
            return cand, fp, loss

        def state_fp(state):
            return pytree_fingerprint({"params": state["params"],
                                       "opt": state["opt"]})

        def state_fp_fast(state):
            tree = {"params": state["params"], "opt": state["opt"]}
            if fused:
                return pytree_fingerprint_fused(tree)
            return pytree_fingerprint(tree)

        self._replica_step = jax.jit(replica_step)
        self._state_fp = jax.jit(state_fp)          # per-leaf: reports
        self._state_fp_fast = jax.jit(state_fp_fast)  # hot validation path

        if self.backend in ("pod", "vote"):
            assert self.mesh is not None, "pod backend requires a mesh"
            self._pod_cmp = make_pod_comparator(self.mesh,
                                                self.sedar.replica_axis)
            if self.backend == "vote":
                from repro.core.detection import make_pod_broadcaster
                self._pod_bcast = make_pod_broadcaster(
                    self.mesh, self.sedar.replica_axis)
            self._pod_inject = (make_pod_injector(self.mesh, spec,
                                                  self.sedar.replica_axis)
                                if spec is not None else None)

            # per-shard fingerprint lanes (DESIGN.md §16): one lane per data
            # shard so a divergence localizes to a device/host. Compare is a
            # pmax/pmin reduction over the replica axis — never a gather,
            # never a host readback on the hot path. The vote backend keeps
            # the legacy whole-state gather (its majority vote consumes
            # fp_all immediately).
            lanes = (dict(self.mesh.shape).get("data", 1)
                     if self.backend == "pod" else 0)
            self._n_lanes = lanes
            if lanes:
                from repro.core.detection import make_lane_comparator
                from repro.core.fingerprint import \
                    pytree_fingerprint_lanes as fp_lanes_fn
                self._lane_cmp = make_lane_comparator(
                    self.mesh, self.sedar.replica_axis)
                hpds = self.hosts_per_data_shard

                def _lane_hosts(lane_ids):
                    from repro.runtime.cluster import lanes_to_hosts
                    return lanes_to_hosts(lane_ids, hosts_per_data_shard=hpds)

                self._lane_hosts = _lane_hosts

            def pod_step(state, batch, armed):
                def loss_fn(p):
                    return model.loss(p, batch)[0]

                loss, grads = jax.value_and_grad(loss_fn)(state["params"])
                if self._pod_inject is not None and spec.target == "grads":
                    grads = jax.lax.cond(
                        armed,
                        lambda g: self._pod_inject(g, state["step"]),
                        lambda g: g, grads)
                if lanes:
                    eq = self._lane_cmp(fp_lanes_fn(grads, lanes))   # (L,)
                    ok = jnp.all(eq)
                    fp_all = None
                else:
                    fp = grad_fp(grads)
                    eq, fp_all = self._pod_cmp(fp)
                    ok = eq
                updates, new_opt = opt.update(grads, state["opt"],
                                              state["params"], state["step"])
                new_params = apply_updates(state["params"], updates)
                if self._pod_inject is not None and spec.target == "params":
                    new_params = jax.lax.cond(
                        armed,
                        lambda p: self._pod_inject(p, state["step"]),
                        lambda p: p, new_params)
                cand = {"params": new_params, "opt": new_opt,
                        "step": state["step"] + 1}
                new_state = jax.tree.map(lambda a, b: jnp.where(ok, a, b),
                                         cand, state)
                return new_state, eq, fp_all, loss

            def pod_validate(state):
                if lanes:
                    fpl = fp_lanes_fn({"params": state["params"],
                                       "opt": state["opt"]}, lanes)
                    # gather kept for the event detail (fault path only —
                    # pod_validate runs at validate/checkpoint boundaries,
                    # not per step)
                    _, fp_all = self._pod_cmp(fpl)
                    return self._lane_cmp(fpl), fp_all
                return self._pod_cmp(state_fp_fast(state))

            self._pod_step = jax.jit(pod_step)
            self._pod_validate = jax.jit(pod_validate)

    # -- driver ---------------------------------------------------------------

    def _host_step(self, dual) -> int:
        """ONE readback of the authoritative (device) step counter — paid at
        run start and after recoveries, never in the fault-free loop."""
        return hostsync.read_int(self.engine.executor.peek(dual, "step"),
                                 label="step_counter")

    def run(self, num_steps: int, dual=None, max_wall_steps: Optional[int] = None
            ) -> "tuple[dict, TrainReport]":
        """The zero-sync outer loop (DESIGN.md §11): the step counter is
        tracked host-side (committed outcomes advance it; recoveries resync
        it from the device once), per-step losses stay on device in
        `aux_buf` and drain in one batched transfer at the end — a
        fault-free protected step performs no device->host readback."""
        rep = TrainReport()
        t0 = time.time()
        eng = self.engine
        eng.reset()
        dual = dual or self.init_dual()
        budget = max_wall_steps or (6 * num_steps + 60)
        executed = 0
        step = self._host_step(dual)
        step0 = step
        # Loss bookkeeping: one device scalar per committed step, drained in
        # batched transfers (never one sync per step). `drained` holds the
        # host floats already fetched; the invariant `len(drained) +
        # len(aux_buf) == step - step0` lets a rollback truncate the record
        # so rep.losses matches the DELIVERED trajectory (the replay
        # re-records the window) instead of keeping corrupted-window losses.
        drained: List[float] = []
        aux_buf: List[Any] = []

        def drain():
            drained.extend(float(a) for a in
                           hostsync.batched_get(aux_buf, label="loss_drain"))
            aux_buf.clear()

        def truncate_to(n_keep: int):
            if n_keep <= len(drained):
                del drained[n_keep:]
                aux_buf.clear()
            else:
                del aux_buf[n_keep - len(drained):]

        while True:
            if step >= num_steps:
                # drain the deferred window before declaring completion: an
                # optimistic commit inside the last D steps may still fail
                event = eng.flush_deferred()
                if event is None:
                    break
                try:
                    dual = eng.on_detection(event, dual)
                except SedarSafeStop:
                    rep.stopped = True
                    break
                step = self._host_step(dual)
                truncate_to(step - step0)
                continue
            if executed >= budget:
                rep.stopped = True
                break
            executed += 1
            batch = {k: jnp.asarray(v) for k, v in
                     self.data.batch(step).items()}
            with obs.span("train_step", step=step):
                outcome = eng.run_protected_step(dual, batch, step)
            dual = outcome.dual
            # aux is None when the executor refused the step before running
            # it (hybrid resident-state check) — there is no loss to record
            if outcome.committed and outcome.aux is not None:
                aux_buf.append(outcome.aux)
                step += 1
            if outcome.event is not None:
                try:
                    dual = eng.on_detection(outcome.event, dual)
                except SedarSafeStop:
                    rep.stopped = True
                    break
                # an ABFT forward correction COMMITS the (repaired) step:
                # keep the loss record aligned with committed steps
                if (eng.recoveries
                        and eng.recoveries[-1]["kind"] == "abft_correct"
                        and outcome.aux is not None):
                    aux_buf.append(outcome.aux)
                step = self._host_step(dual)
                truncate_to(step - step0)
            elif len(aux_buf) >= 4096 and not eng.pending_validation:
                # bound the live device buffers: piggyback one batched
                # fetch on a step whose window is already flushed (no
                # extra sync inside a deferred window)
                drain()
            if self.autotune is not None:
                # host-side only (registry/journal reads); lag changes land
                # via apply_reconfig and only at clean flush boundaries
                self.autotune.maybe_tune(eng, step)

        # final validation (paper: final results comparison)
        if not rep.stopped:
            event = eng.validate_final(dual, step)
            if event is not None:
                try:
                    dual = eng.on_detection(event, dual)
                except SedarSafeStop:
                    rep.stopped = True
        drain()
        rep.losses = drained
        rep.detections = list(eng.detections)
        rep.recoveries = list(eng.recoveries)
        rep.checkpoints = list(eng.checkpoints)
        rep.steps_completed = self._host_step(dual)
        rep.restored_from = [r["tier"] for r in rep.recoveries
                             if r.get("tier")]
        rep.final_state_fp = hostsync.read_scalar(
            self._state_fp(eng.executor.primary(dual)), label="final_fp")
        # durability barrier: async checkpoint writers are daemon threads —
        # without this, process exit can strand .tmp staging dirs and the
        # on-disk chain is shorter than rep.checkpoints claims. Tiered
        # configs barrier every disk-backed tier (primary AND partner).
        tiers = getattr(self.recovery, "tiers", None)
        if tiers is not None:
            tiers.wait()
        else:
            store = getattr(self.recovery, "store", None)
            if store is not None:
                store.wait()
        rep.wall_s = time.time() - t0
        return dual, rep
