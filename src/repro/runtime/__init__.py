from repro.runtime.train import SedarTrainer, TrainReport

__all__ = ["SedarTrainer", "TrainReport"]
