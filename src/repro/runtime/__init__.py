from repro.runtime.train import SedarTrainer, TrainReport
from repro.runtime.serve import SedarServer, ServeReport

__all__ = ["SedarTrainer", "TrainReport", "SedarServer", "ServeReport"]
