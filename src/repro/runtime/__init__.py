from repro.runtime.scheduler import (Request, RequestQueue, SlotScheduler,
                                     synthetic_requests)
from repro.runtime.serve import BatchServeReport, SedarServer, ServeReport
from repro.runtime.train import SedarTrainer, TrainReport

__all__ = ["BatchServeReport", "Request", "RequestQueue", "SedarServer",
           "SedarTrainer", "ServeReport", "SlotScheduler", "TrainReport",
           "synthetic_requests"]
