"""Cluster-level fault tolerance: heartbeats, stragglers, elastic re-mesh.

The paper's TOE detector generalizes to the pod level: every host writes a
heartbeat file per step; a monitor flags hosts whose beat is stale (hang /
crash / TOE) and measures per-step skew quantiles (stragglers). On permanent
host loss the elastic planner rebuilds the mesh with a smaller data axis from
the last valid checkpoint (SEDAR L3 guarantees its validity).

On this container the monitor runs against simulated host directories; on a
real cluster each jax process calls `Heartbeat.beat()` after every step.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro import obs


@dataclass
class HostState:
    host_id: int
    last_beat: float
    step: int


class Heartbeat:
    """Per-host heartbeat writer (one file per host, atomic replace).

    A heartbeat is advisory: a transient IO error (full disk, ENOENT race
    on a recycled workdir, NFS hiccup) must never take the train loop down,
    so `beat()` retries a bounded number of times and then gives up
    silently — a missed beat at worst makes the monitor flag this host a
    little earlier. Exhausted attempts are counted in `io_errors` (and the
    `cluster_heartbeat_io_errors_total` metric) so the flakiness is still
    visible."""

    def __init__(self, directory: str, host_id: int, *,
                 retries: int = 3, retry_wait_s: float = 0.01):
        self.dir = directory
        self.host_id = host_id
        self.retries = max(int(retries), 1)
        self.retry_wait_s = retry_wait_s
        self.io_errors = 0
        try:
            os.makedirs(directory, exist_ok=True)
        except OSError:
            self.io_errors += 1

    def beat(self, step: int) -> bool:
        path = os.path.join(self.dir, f"host_{self.host_id:05d}.json")
        tmp = path + ".tmp"
        for attempt in range(self.retries):
            try:
                # re-create the directory every attempt: a concurrent
                # cleanup may remove it between beats (the ENOENT race)
                os.makedirs(self.dir, exist_ok=True)
                with open(tmp, "w") as f:
                    json.dump({"host": self.host_id, "step": step,
                               "t": time.time()}, f)
                os.replace(tmp, path)
                return True
            except OSError:
                if attempt + 1 < self.retries and self.retry_wait_s > 0:
                    time.sleep(self.retry_wait_s)
        self.io_errors += 1
        if obs.metrics_enabled():
            obs.metrics.inc("cluster_heartbeat_io_errors_total",
                            host=self.host_id)
        return False


class ClusterMonitor:
    """Scans heartbeat files; reports stale hosts and stragglers."""

    def __init__(self, directory: str, n_hosts: int, timeout_s: float = 60.0,
                 straggler_factor: float = 2.0):
        self.dir = directory
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor

    def scan(self) -> Dict[int, HostState]:
        """Best-effort read of every heartbeat file. Corrupted files
        (truncated writes, garbage, wrong JSON shape) and racing deletes
        are skipped — the host simply reads as missing/stale; a transient
        listdir failure gets one retry and then an empty scan rather than
        an exception into the caller's loop."""
        out: Dict[int, HostState] = {}
        if not os.path.isdir(self.dir):
            return out
        for attempt in range(2):
            try:
                names = os.listdir(self.dir)
                break
            except OSError:
                if attempt:
                    return out
                time.sleep(0.01)
        for name in names:
            if not name.startswith("host_") or not name.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.dir, name)) as f:
                    d = json.load(f)
                out[int(d["host"])] = HostState(int(d["host"]),
                                                float(d["t"]),
                                                int(d["step"]))
            except (json.JSONDecodeError, KeyError, OSError,
                    TypeError, ValueError):
                continue
        return out

    def stale_hosts(self, now: Optional[float] = None) -> List[int]:
        # `now or time.time()` would treat now=0.0 (a perfectly legal
        # simulated clock origin) as unset and silently substitute wall time
        now = time.time() if now is None else now
        seen = self.scan()
        stale = [h for h, s in seen.items() if now - s.last_beat > self.timeout_s]
        missing = [h for h in range(self.n_hosts) if h not in seen]
        return sorted(stale + missing)

    def stragglers(self) -> List[int]:
        """Hosts more than straggler_factor x slower than the median, i.e.
        whose step count has fallen below median / straggler_factor.

        A LARGER factor tolerates MORE lag before flagging (factor=2: flag
        below half the median progress; factor=10: only below a tenth). The
        previous formula used `med - step > med / factor`, which INVERTED
        that: raising the factor shrank the allowed lag and made detection
        more sensitive. A 2-step grace floor keeps early-run jitter (median
        of 1-2 steps) from flagging healthy hosts."""
        seen = self.scan()
        if len(seen) < 2:
            return []
        steps = sorted(s.step for s in seen.values())
        med = steps[len(steps) // 2]
        floor = med / self.straggler_factor
        return sorted(h for h, s in seen.items()
                      if med - s.step > 2 and s.step < floor)

    def publish(self, now: Optional[float] = None) -> Dict[str, object]:
        """One scan published into the observability stream: cluster-health
        gauges in the metrics registry (hosts seen / stale / stragglers,
        per-host step and heartbeat age) and a journaled heartbeat anomaly
        per stale host — so multi-host health lands in the SAME stream as
        fault events. Returns the summary it published."""
        now = time.time() if now is None else now
        seen = self.scan()
        stale = self.stale_hosts(now)
        strag = self.stragglers()
        m = obs.metrics
        if obs.metrics_enabled():
            m.set_gauge("cluster_hosts_seen", len(seen))
            m.set_gauge("cluster_hosts_expected", self.n_hosts)
            m.set_gauge("cluster_stale_hosts", len(stale))
            m.set_gauge("cluster_stragglers", len(strag))
            for h, s in seen.items():
                m.set_gauge("cluster_host_step", s.step, host=h)
                m.set_gauge("cluster_heartbeat_age_s",
                            max(0.0, now - s.last_beat), host=h)
        for h in stale:
            s = seen.get(h)
            # -1.0 = host never beat at all (no file to age)
            gap = (now - s.last_beat) if s is not None else -1.0
            obs.note_heartbeat_anomaly(h, gap, kind="stale")
        for h in strag:
            obs.note_heartbeat_anomaly(h, 0.0, kind="straggler")
        return {"seen": sorted(seen), "stale": stale, "stragglers": strag}


@dataclass
class ElasticPlan:
    old_data: int
    new_data: int
    new_global_batch: int
    dropped_hosts: List[int]
    note: str


def plan_elastic_remesh(data_axis: int, global_batch: int,
                        lost_hosts: List[int], hosts_per_data_shard: int = 1
                        ) -> ElasticPlan:
    """Shrink the data axis past lost hosts, keeping batch divisible.

    Policy: drop whole data shards containing lost hosts; rescale the global
    batch proportionally (keeps per-shard batch, so activation memory and the
    compiled program are unchanged -> restart reuses the compile cache).

    The rescale is derived FROM the per-shard batch, so a `global_batch`
    that does not divide `data_axis` is rejected up front: flooring
    `global_batch * new_data // data_axis` would silently change the
    per-shard batch the restart relies on (new shapes -> compile-cache
    miss, and a different effective batch than the run was tuned for)."""
    if global_batch % data_axis:
        raise ValueError(
            f"global_batch {global_batch} is not divisible by data_axis "
            f"{data_axis}: the per-shard batch is undefined, so an elastic "
            f"re-mesh cannot preserve it (compile-cache reuse)")
    per_shard = global_batch // data_axis
    lost_shards = sorted({h // hosts_per_data_shard for h in lost_hosts})
    new_data = data_axis - len(lost_shards)
    if new_data < 1:
        raise RuntimeError("all data shards lost")
    new_batch = per_shard * new_data
    return ElasticPlan(
        old_data=data_axis, new_data=new_data, new_global_batch=new_batch,
        dropped_hosts=lost_hosts,
        note=("per-shard batch preserved; data-axis collectives shrink; "
              "restore from last VALID checkpoint (L3) then continue"))


def rebuild_mesh(shape, axes, devices=None):
    """Version-compat mesh reconstruction for the elastic planner (the
    AxisType shim lives in launch/mesh.py; this is the cluster-side entry)."""
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat(tuple(shape), tuple(axes), devices=devices)


def data_axis_index(mesh_cfg, name: str = "data") -> int:
    """Position of the data axis in a MeshConfig — BY NAME, never by
    position: on the replicated ("pod", "data", "model") meshes the data
    axis is index 1, so `shape[0]` silently shrinks the replica axis."""
    try:
        return list(mesh_cfg.axis_names).index(name)
    except ValueError:
        raise ValueError(
            f"mesh axes {tuple(mesh_cfg.axis_names)} have no {name!r} axis "
            f"to shrink") from None


def surviving_devices(mesh, lost_shards: List[int],
                      data_axis: str = "data"):
    """Drop the lost data shards' device planes from a live mesh's device
    ndarray; returns (new_shape, devices) ready for `rebuild_mesh` with the
    same axis names. Device order within the survivors is preserved, so
    shard i of the shrunken mesh is survivor i in the old order."""
    import numpy as np
    devs = np.asarray(mesh.devices)
    axes = list(mesh.axis_names)
    ax = axes.index(data_axis)
    keep = [i for i in range(devs.shape[ax]) if i not in set(lost_shards)]
    devs2 = np.take(devs, keep, axis=ax)
    return tuple(devs2.shape), devs2.reshape(-1)


def lanes_to_hosts(lane_ids, hosts_per_data_shard: int = 1) -> List[int]:
    """Fingerprint-lane -> host translation (DESIGN.md §16): lane i covers
    data shard i, and shard i is owned by hosts [i*H, (i+1)*H). The inverse
    of `plan_elastic_remesh`'s `h // hosts_per_data_shard` shard map."""
    H = max(int(hosts_per_data_shard), 1)
    out: List[int] = []
    for lane in lane_ids:
        out.extend(range(int(lane) * H, (int(lane) + 1) * H))
    return out


def elastic_restart(run_cfg, workdir: str, lost_hosts: List[int], *,
                    hosts_per_data_shard: int = 1, mesh=None, **trainer_kw):
    """Host-loss recovery: shrink the data axis past the lost hosts and
    rebuild the training engine via the policy factory.

    Returns (plan, trainer). The new trainer starts UNINITIALIZED — the
    caller restores the anchor state (last valid L3 checkpoint, typically
    from the partner tier) and adopts it via
    `trainer.engine.executor.adopt_single`; `runtime/elastic.ElasticTrainer`
    drives the full shrink/regrow cycle. The rewritten config shrinks BOTH
    the mesh shape and the global batch so the per-shard batch (and with it
    every compiled program shape) is preserved."""
    import dataclasses as _dc

    from repro.core.policy import make_trainer

    mesh_cfg = run_cfg.mesh
    ax = data_axis_index(mesh_cfg)
    plan = plan_elastic_remesh(mesh_cfg.shape[ax],
                               run_cfg.train.global_batch, lost_hosts,
                               hosts_per_data_shard=hosts_per_data_shard)
    new_shape = tuple(plan.new_data if i == ax else s
                      for i, s in enumerate(mesh_cfg.shape))
    new_cfg = _dc.replace(
        run_cfg,
        mesh=_dc.replace(mesh_cfg, shape=new_shape),
        train=_dc.replace(run_cfg.train,
                          global_batch=plan.new_global_batch))
    trainer = make_trainer(new_cfg, workdir, mesh=mesh, **trainer_kw)
    return plan, trainer
