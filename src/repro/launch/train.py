"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 8 --level 3 [--smoke] [--replication sequential|pod|none] \
        [--inject-step N] [--manual-vote]

--smoke uses the reduced per-arch config (CPU-runnable); full configs are for
real accelerators (and are exercised shape-only via the dry-run).
--manual-vote runs the paper's BASELINE protocol: two independent instances,
final comparison, third run + majority vote on mismatch (Sec. 3, Eqs. 1-2).
"""
from __future__ import annotations

import argparse
import os
import shutil

import numpy as np

from repro import obs
from repro.configs import (RunConfig, SedarConfig, TrainConfig, get_config,
                           list_archs, reduce_for_smoke)
from repro.core.fingerprint import pytree_fingerprint
from repro.core.injection import InjectionSpec
from repro.core.policy import make_trainer
from repro.runtime.cluster import Heartbeat


def manual_vote_baseline(run_cfg: RunConfig, workdir: str, steps: int,
                         inj_spec=None) -> None:
    """Paper baseline: two instances + compare; on mismatch, a third run and
    majority vote (semi-automatic, Eqs. 1-2)."""
    import dataclasses
    fps = []
    for inst in range(2):
        rc = dataclasses.replace(
            run_cfg, sedar=SedarConfig(level=1, replication="none"))
        tr = make_trainer(rc, f"{workdir}/inst{inst}",
                          inj_spec=inj_spec if inst == 1 else None)
        _, rep = tr.run(steps)
        fps.append(rep.final_state_fp[:, :2])
        print(f"[baseline] instance {inst}: {rep.summary()}")
    if np.array_equal(fps[0], fps[1]):
        print("[baseline] results MATCH — accepted")
        return
    print("[baseline] MISMATCH — launching third instance for majority vote")
    rc = dataclasses.replace(run_cfg,
                             sedar=SedarConfig(level=1, replication="none"))
    tr = make_trainer(rc, f"{workdir}/inst2")
    _, rep = tr.run(steps)
    third = rep.final_state_fp[:, :2]
    winner = 0 if np.array_equal(third, fps[0]) else 1
    print(f"[baseline] majority: instances {winner} and 2 agree -> "
          f"instance {1 - winner} was corrupted")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--level", type=int, default=3, choices=(1, 2, 3))
    ap.add_argument("--replication", default="sequential",
                    choices=("none", "sequential", "fused", "pod"))
    ap.add_argument("--validate-lag", type=int, default=1,
                    help="deferred validation window D (DESIGN.md §11): "
                         "read commit predicates back every D steps")
    ap.add_argument("--ckpt-tiers", default="disk",
                    help="checkpoint tier hierarchy (DESIGN.md §12): comma-"
                         "list of device,host,disk,partner. device = on-"
                         "device snapshot ring (instant rollback, zero disk "
                         "reads), host = host-RAM ring, partner = redundant "
                         "second store (Tier-2 corruption fallback). "
                         "E.g. --ckpt-tiers device,host,disk")
    ap.add_argument("--ckpt-delta", action="store_true",
                    help="L2 delta checkpoints: leaves unchanged since the "
                         "previous version become manifest references "
                         "instead of re-serialized payloads")
    ap.add_argument("--ckpt-compress", action="store_true",
                    help="compress leaf payloads (np.savez_compressed); "
                         "bytes-on-disk reported in the manifest")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--ckpt-interval", type=int, default=4)
    ap.add_argument("--workdir", default="/tmp/sedar_train")
    ap.add_argument("--inject-step", type=int, default=None)
    ap.add_argument("--manual-vote", action="store_true")
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--metrics-dir", default=None,
                    help="enable the obs metrics registry + fault journal "
                         "(DESIGN.md §15): writes metrics.prom and "
                         "journal.jsonl here and prints the Prometheus "
                         "snapshot after the run")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record per-stage trace spans to a Chrome-trace "
                         "JSON (open at ui.perfetto.dev)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    rc = RunConfig(
        model=cfg,
        train=TrainConfig(global_batch=args.global_batch,
                          seq_len=args.seq_len, steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1), lr=1e-3),
        sedar=SedarConfig(level=args.level, replication=args.replication,
                          validate_lag=args.validate_lag,
                          checkpoint_interval=args.ckpt_interval,
                          param_validate_interval=args.ckpt_interval,
                          ckpt_tiers=args.ckpt_tiers,
                          ckpt_delta=args.ckpt_delta,
                          ckpt_compress=args.ckpt_compress))
    shutil.rmtree(args.workdir, ignore_errors=True)

    inj = None
    if args.inject_step is not None:
        inj = InjectionSpec(leaf_idx=3, flat_idx=11, bit=21,
                            step=args.inject_step, replica=1, target="grads")

    if args.manual_vote:
        manual_vote_baseline(rc, args.workdir, args.steps, inj)
        return

    ob = obs.configure(metrics_dir=args.metrics_dir, trace=args.trace)
    hb = Heartbeat(os.path.join(args.workdir, "heartbeats"), args.host_id)
    trainer = make_trainer(rc, args.workdir, inj_spec=inj)
    dual, rep = trainer.run(args.steps)
    hb.beat(rep.steps_completed)
    print(rep.summary())
    for e in rep.detections:
        print(f"  detection: {e}")
    for r in rep.recoveries:
        print(f"  recovery: {r}")
    if args.metrics_dir:
        kpis = ob.kpis(steps=rep.steps_completed)
        print(f"[obs] kpis: {kpis}")
    snap = ob.finalize()
    if snap:
        print(f"[obs] metrics snapshot ({args.metrics_dir}/metrics.prom):")
        print(snap, end="")
    if args.trace:
        print(f"[obs] trace written to {args.trace}")


if __name__ == "__main__":
    main()
