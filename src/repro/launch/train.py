"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 8 --level 3 [--smoke] [--replication sequential|pod|none] \
        [--inject-step N] [--manual-vote]

--smoke uses the reduced per-arch config (CPU-runnable); full configs are for
real accelerators (and are exercised shape-only via the dry-run).
--manual-vote runs the paper's BASELINE protocol: two independent instances,
final comparison, third run + majority vote on mismatch (Sec. 3, Eqs. 1-2).

--elastic drives the fail-in-place loop (DESIGN.md §16): an ElasticTrainer
run under a simulated cluster where one host can go dark mid-run and later
return — the run shrinks onto survivors from the last validated checkpoint,
then regrows and replays to a state bitwise-identical with an uninterrupted
run:

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 12 --level 3 --elastic --n-hosts 2 \
        --lose-host 1 --lose-at 300 --return-at 700
"""
from __future__ import annotations

import argparse
import json
import os
import shutil

import numpy as np

from repro import obs
from repro.configs import (MeshConfig, RunConfig, SedarConfig, TrainConfig,
                           get_config, list_archs, reduce_for_smoke)
from repro.core.fingerprint import pytree_fingerprint
from repro.core.injection import InjectionSpec
from repro.core.policy import make_trainer
from repro.runtime.cluster import Heartbeat


def manual_vote_baseline(run_cfg: RunConfig, workdir: str, steps: int,
                         inj_spec=None) -> None:
    """Paper baseline: two instances + compare; on mismatch, a third run and
    majority vote (semi-automatic, Eqs. 1-2)."""
    import dataclasses
    fps = []
    for inst in range(2):
        rc = dataclasses.replace(
            run_cfg, sedar=SedarConfig(level=1, replication="none"))
        tr = make_trainer(rc, f"{workdir}/inst{inst}",
                          inj_spec=inj_spec if inst == 1 else None)
        _, rep = tr.run(steps)
        fps.append(rep.final_state_fp[:, :2])
        print(f"[baseline] instance {inst}: {rep.summary()}")
    if np.array_equal(fps[0], fps[1]):
        print("[baseline] results MATCH — accepted")
        return
    print("[baseline] MISMATCH — launching third instance for majority vote")
    rc = dataclasses.replace(run_cfg,
                             sedar=SedarConfig(level=1, replication="none"))
    tr = make_trainer(rc, f"{workdir}/inst2")
    _, rep = tr.run(steps)
    third = rep.final_state_fp[:, :2]
    winner = 0 if np.array_equal(third, fps[0]) else 1
    print(f"[baseline] majority: instances {winner} and 2 agree -> "
          f"instance {1 - winner} was corrupted")


def run_elastic(run_cfg: RunConfig, args) -> None:
    """Fail-in-place demo loop (DESIGN.md §16). This process plays every
    host's heartbeat writer: each training segment advances a simulated
    clock 100 s and refreshes all heartbeats except the designated lost
    host during its dark window — the ClusterMonitor then sees a real
    stale-host and the ElasticTrainer shrinks/regrows exactly as it would
    under a genuine node loss."""
    from repro.runtime.elastic import ElasticTrainer

    hb_dir = os.path.join(args.workdir, "heartbeats")
    sim = {"now": 0.0}

    def write_beat(host: int, step: int) -> None:
        os.makedirs(hb_dir, exist_ok=True)
        with open(os.path.join(hb_dir, f"host_{host:05d}.json"), "w") as f:
            json.dump({"host": host, "step": int(step or 0),
                       "t": sim["now"]}, f)

    def tick(step) -> None:
        sim["now"] += 100.0
        for h in range(args.n_hosts):
            dark = (args.lose_host is not None and h == args.lose_host
                    and args.lose_at <= sim["now"] < args.return_at)
            if not dark:
                write_beat(h, step or 0)

    et = ElasticTrainer(run_cfg, args.workdir, n_hosts=args.n_hosts,
                        scan_interval=args.scan_interval,
                        clock=lambda: sim["now"], tick=tick)
    rep = et.run(args.steps)
    print(rep.summary())
    for r in rep.remeshes:
        print(f"  remesh[{r.phase}]: trigger step {r.trigger_step}, "
              f"restored step {r.restore_step} from tier "
              f"{r.restore_tier}, hosts {sorted(r.hosts)}, data "
              f"{r.old_data}->{r.new_data}, batch "
              f"{r.old_batch}->{r.new_batch}")
    for d in rep.decisions:
        print(f"  decision: {d.mode} (fail_in_place "
              f"{d.fail_in_place_hours:.3f} h vs restart "
              f"{d.restart_hours:.3f} h) — {d.notes}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--level", type=int, default=3, choices=(1, 2, 3))
    ap.add_argument("--replication", default="sequential",
                    choices=("none", "sequential", "fused", "pod"))
    ap.add_argument("--validate-lag", type=int, default=1,
                    help="deferred validation window D (DESIGN.md §11): "
                         "read commit predicates back every D steps")
    ap.add_argument("--ckpt-tiers", default="disk",
                    help="checkpoint tier hierarchy (DESIGN.md §12): comma-"
                         "list of device,host,disk,partner. device = on-"
                         "device snapshot ring (instant rollback, zero disk "
                         "reads), host = host-RAM ring, partner = redundant "
                         "second store (Tier-2 corruption fallback). "
                         "E.g. --ckpt-tiers device,host,disk")
    ap.add_argument("--ckpt-delta", action="store_true",
                    help="L2 delta checkpoints: leaves unchanged since the "
                         "previous version become manifest references "
                         "instead of re-serialized payloads")
    ap.add_argument("--ckpt-compress", action="store_true",
                    help="compress leaf payloads (np.savez_compressed); "
                         "bytes-on-disk reported in the manifest")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--ckpt-interval", type=int, default=4)
    ap.add_argument("--workdir", default="/tmp/sedar_train")
    ap.add_argument("--inject-step", type=int, default=None)
    ap.add_argument("--manual-vote", action="store_true")
    ap.add_argument("--host-id", type=int, default=0)
    # -- elastic fail-in-place (DESIGN.md §16) -------------------------------
    ap.add_argument("--elastic", action="store_true",
                    help="run under an ElasticTrainer: monitor heartbeats, "
                         "shrink onto survivors on node loss, regrow on "
                         "return (requires --level 3)")
    ap.add_argument("--n-hosts", type=int, default=2,
                    help="cluster width; the data axis gets one shard per "
                         "host in the demo mesh")
    ap.add_argument("--scan-interval", type=int, default=2,
                    help="steps per training segment between cluster scans")
    ap.add_argument("--lose-host", type=int, default=None,
                    help="simulate this host going dark (heartbeats stop)")
    ap.add_argument("--lose-at", type=float, default=300.0,
                    help="simulated-clock second the host goes dark "
                         "(the clock advances 100 s per segment)")
    ap.add_argument("--return-at", type=float, default=700.0,
                    help="simulated-clock second the host comes back")
    ap.add_argument("--metrics-dir", default=None,
                    help="enable the obs metrics registry + fault journal "
                         "(DESIGN.md §15): writes metrics.prom and "
                         "journal.jsonl here and prints the Prometheus "
                         "snapshot after the run")
    # -- closed-loop autotuning (DESIGN.md §17) ------------------------------
    ap.add_argument("--autotune", action="store_true",
                    help="closed-loop calibration: estimate t_step/t_sync/"
                         "MTBE online and retune the deferred-validation "
                         "lag + tier cadences at clean flush boundaries "
                         "(requires --metrics-dir for the estimator's "
                         "inputs)")
    ap.add_argument("--autotune-interval", type=int, default=16,
                    help="steps between autotuner evaluations")
    ap.add_argument("--slo-availability", type=float, default=None,
                    help="availability SLO target (e.g. 0.999); burn-rate "
                         "alerts fire when the error budget burns fast")
    ap.add_argument("--slo-goodput", type=float, default=None,
                    help="goodput SLO target as a 0-1 fraction of the "
                         "fault-free rate")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record per-stage trace spans to a Chrome-trace "
                         "JSON (open at ui.perfetto.dev)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    mesh_cfg = None
    if args.elastic:
        if args.level < 3:
            ap.error("--elastic requires --level 3 (a validated checkpoint "
                     "anchor is what makes shrink/regrow exact)")
        if args.global_batch % args.n_hosts:
            ap.error("--global-batch must divide evenly across --n-hosts")
        mesh_cfg = MeshConfig(shape=(args.n_hosts, 1),
                              axis_names=("data", "model"))
    rc = RunConfig(
        model=cfg,
        train=TrainConfig(global_batch=args.global_batch,
                          seq_len=args.seq_len, steps=args.steps,
                          warmup_steps=max(args.steps // 10, 1), lr=1e-3),
        mesh=mesh_cfg if mesh_cfg is not None else MeshConfig(),
        sedar=SedarConfig(level=args.level, replication=args.replication,
                          validate_lag=args.validate_lag,
                          checkpoint_interval=args.ckpt_interval,
                          param_validate_interval=args.ckpt_interval,
                          ckpt_tiers=args.ckpt_tiers,
                          ckpt_delta=args.ckpt_delta,
                          ckpt_compress=args.ckpt_compress))
    shutil.rmtree(args.workdir, ignore_errors=True)

    inj = None
    if args.inject_step is not None:
        inj = InjectionSpec(leaf_idx=3, flat_idx=11, bit=21,
                            step=args.inject_step, replica=1, target="grads")

    if args.manual_vote:
        manual_vote_baseline(rc, args.workdir, args.steps, inj)
        return

    ob = obs.configure(metrics_dir=args.metrics_dir, trace=args.trace)
    if args.elastic:
        run_elastic(rc, args)
        if args.metrics_dir:
            print(f"[obs] kpis: {ob.kpis(steps=args.steps)}")
        snap = ob.finalize()
        if snap:
            print(f"[obs] metrics snapshot "
                  f"({args.metrics_dir}/metrics.prom):")
            print(snap, end="")
        return
    tuner = None
    if args.autotune:
        from repro.core import temporal_model as tm
        from repro.core.policy import Autotuner, AutotuneConfig
        if not args.metrics_dir:
            ap.error("--autotune needs --metrics-dir (the estimator reads "
                     "the stage-duration histograms and the fault journal)")
        tuner = Autotuner(
            tm.PAPER_TABLE3["JACOBI"],
            AutotuneConfig(interval_steps=args.autotune_interval,
                           mode="train", backend=args.replication,
                           slo_availability=args.slo_availability,
                           slo_goodput=args.slo_goodput))
    hb = Heartbeat(os.path.join(args.workdir, "heartbeats"), args.host_id)
    trainer = make_trainer(rc, args.workdir, inj_spec=inj, autotune=tuner)
    dual, rep = trainer.run(args.steps)
    hb.beat(rep.steps_completed)
    print(rep.summary())
    for e in rep.detections:
        print(f"  detection: {e}")
    for r in rep.recoveries:
        print(f"  recovery: {r}")
    if args.metrics_dir:
        kpis = ob.kpis(steps=rep.steps_completed)
        print(f"[obs] kpis: {kpis}")
    if tuner is not None:
        snap = tuner.estimator.calibrated_params()
        print(f"[autotune] calibrated: t_step={snap.params.t_step:.3e} h, "
              f"t_sync={snap.params.t_sync:.3e} h, "
              f"mtbe={snap.mtbe_hours:.3g} h, "
              f"confidence={snap.confidence:.2f} "
              f"({snap.sample_counts})")
        print(f"[autotune] {len(tuner.alerts.records)} alert(s), "
              f"{tuner.evaluations} evaluation(s)")
    snap = ob.finalize()
    if snap:
        print(f"[obs] metrics snapshot ({args.metrics_dir}/metrics.prom):")
        print(snap, end="")
    if args.trace:
        print(f"[obs] trace written to {args.trace}")


if __name__ == "__main__":
    main()
