"""Serving launcher.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
        --batch 4 --steps 16 [--dual]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (RunConfig, TrainConfig, get_config, list_archs,
                           reduce_for_smoke)
from repro.core.policy import make_server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--dual", action="store_true",
                    help="SEDAR dual-execution detection on decode")
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    srv = make_server(RunConfig(model=cfg, train=TrainConfig()),
                      dual=args.dual)
    params = srv.model.init(jax.random.PRNGKey(0))
    prompts = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, min(cfg.vocab_size, 200),
                                         (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.frontend:
        prompts["frontend_embeds"] = 0.1 * jnp.ones(
            (args.batch, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
    toks, rep = srv.generate(params, prompts, steps=args.steps)
    tps = rep.tokens_emitted / max(rep.wall_s, 1e-9)
    print(f"{args.arch}: {rep.tokens_emitted} tokens, {tps:.1f} tok/s "
          f"(CPU smoke), detections={len(rep.detections)}")


if __name__ == "__main__":
    main()
