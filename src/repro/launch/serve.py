"""Serving launcher: synchronous batch or continuous-batching traffic replay.

Synchronous whole-batch decode (the original loop):

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
        --batch 4 --steps 16 [--dual]

Continuous-batching protected serving (DESIGN.md §13) replays an open-loop
synthetic traffic trace — arrival rate, prompt-length mix, per-request
token budgets — through the slot scheduler, optionally with a fault
campaign injected into the decode stream:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --continuous --requests 16 --slots 4 --arrival-rate 0.5 \
        --prompt-mix 4:0.5,8:0.3,16:0.2 --max-new 4,12 \
        --validate-lag 8 --backend sequential \
        --fault-slot 1 --fault-step 5

    # per-request rejection demo: a stuck bit on one slot
    ... --fault-slot 1 --fault-step 5 --fault-persistent --max-retries 3

    # bucketed packed prefill with AOT warmup (DESIGN.md §14): every
    # (bucket, pack) prefill program compiles BEFORE traffic — admission
    # then never pays a traffic-time compile
    ... --continuous --warmup --prefill-buckets 8,16,32 --max-pack 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import (RunConfig, TrainConfig, get_config, list_archs,
                           reduce_for_smoke)
from repro.core.policy import make_server


def _parse_prompt_mix(spec: str):
    """'4:0.5,8:0.5' -> (lengths, weights)."""
    lengths, weights = [], []
    for part in spec.split(","):
        length, _, w = part.partition(":")
        lengths.append(int(length))
        weights.append(float(w) if w else 1.0)
    return tuple(lengths), tuple(weights)


def _continuous(args, cfg, ob=None) -> None:
    from repro.core.injection import InjectionSpec
    from repro.runtime.scheduler import stream_stats_ms, synthetic_requests

    spec = None
    if args.fault_slot is not None:
        if args.backend in ("abft", "hybrid"):
            # replica-free backends execute ONE instance (replica_id 0) and
            # a pre-encode logits flip is invisible to the checksum guard by
            # construction — inject in the KERNEL domain instead (between
            # compute and verify, the fault class ABFT exists to catch),
            # into the chosen slot's row of the checksummed block
            spec = InjectionSpec(
                leaf_idx=0,
                flat_idx=args.fault_slot * (cfg.vocab_size + 1) + 7,
                bit=30, step=args.fault_step, replica=0, target="kernel",
                persistent=args.fault_persistent)
        else:
            # replica 0 for the unprotected baseline (there IS no replica
            # 1 — the corruption must land on the instance that runs, and
            # the stream visibly corrupts with nothing detecting it)
            replica = 0 if args.backend == "none" else 1
            spec = InjectionSpec(
                leaf_idx=args.fault_slot, flat_idx=7, bit=30,
                step=args.fault_step, replica=replica, target="slot",
                persistent=args.fault_persistent)
    buckets = (tuple(int(b) for b in args.prefill_buckets.split(","))
               if args.prefill_buckets else None)
    srv = make_server(RunConfig(model=cfg, train=TrainConfig()),
                      dual=(args.backend == "sequential"),
                      backend=args.backend, inj_spec=spec,
                      max_retries=args.max_retries,
                      prefill_buckets=buckets, max_pack=args.max_pack)
    params = srv.model.init(jax.random.PRNGKey(0))
    lengths, weights = _parse_prompt_mix(args.prompt_mix)
    reqs = synthetic_requests(
        args.requests, arrival_rate=args.arrival_rate,
        prompt_lengths=lengths, length_weights=weights,
        max_new_choices=tuple(int(x) for x in args.max_new.split(",")),
        vocab=min(cfg.vocab_size, 200), seed=args.seed)
    if args.warmup:
        # same max_len formula serve() uses, so the warmed programs are the
        # ones traffic hits (DESIGN.md §14 AOT warmup contract)
        max_len = (max(r.prompt_len for r in reqs)
                   + max(r.max_new_tokens for r in reqs) + 8)
        n = srv.warmup_prefill(params, max_len)
        print(f"[SEDAR] prefill warmup: {n} (bucket, pack) programs "
              f"compiled ahead of traffic")
    tuner = None
    if args.autotune:
        from repro.core import temporal_model as tm
        from repro.core.policy import Autotuner, AutotuneConfig
        tuner = Autotuner(
            tm.PAPER_TABLE3["JACOBI"],
            AutotuneConfig(interval_steps=args.autotune_interval,
                           mode="serve", serve_slots=args.slots,
                           backend=args.backend,
                           slo_availability=args.slo_availability,
                           slo_goodput=args.slo_goodput))
    out, rep = srv.serve(
        params, reqs, slots=args.slots, validate_lag=args.validate_lag,
        queue_depth=args.queue_depth, autotune=tuner,
        drain_cadence=args.drain_cadence,
        notify_reject=lambda r, e: print(
            f"[SEDAR] request {r.rid} REJECTED after {e.boundary} fault "
            f"(per-request safe stop)", flush=True))
    ms = stream_stats_ms(out)
    print(f"{args.arch}: {rep.tokens_emitted} tokens delivered over "
          f"{rep.steps} protected steps ({rep.tokens_per_s:.1f} tok/s, "
          f"goodput {rep.goodput_tokens_per_step:.2f} tok/step), "
          f"p50/p99 inter-token {ms['itl_p50_ms']:.2f}/"
          f"{ms['itl_p99_ms']:.2f} ms, "
          f"p50/p99 TTFT {ms['ttft_p50_ms']:.2f}/{ms['ttft_p99_ms']:.2f} ms, "
          f"p50/p99 TTLT {ms['ttlt_p50_ms']:.2f}/{ms['ttlt_p99_ms']:.2f} ms")
    print(f"  completed={len(rep.completed)} rejected={rep.rejected} "
          f"detections={len(rep.detections)} retries={rep.retries} "
          f"rollbacks={rep.rollbacks} "
          f"truncated+redecoded={rep.truncated_tokens} tokens, "
          f"prefill packs={rep.prefill_packs} "
          f"prefill retries={rep.prefill_retries}")
    for e in rep.detections:
        print(f"  {e} slots={e.detail.get('slots')}")
    if ob is not None and ob.journal is not None:
        kpis = ob.kpis(steps=rep.steps, tokens=rep.tokens_emitted)
        print(f"[obs] kpis: {kpis}")
        rows = obs.reconcile_with_advice(kpis,
                                         validate_lag=args.validate_lag)
        for row in rows:
            print(f"[obs] predicted-vs-observed {row['metric']}: "
                  f"predicted {row['predicted']}, observed "
                  f"{row['observed']} -> {'OK' if row['ok'] else 'MISS'}")
    if tuner is not None:
        snap = tuner.estimator.calibrated_params()
        print(f"[autotune] calibrated: t_step={snap.params.t_step:.3e} h, "
              f"t_sync={snap.params.t_sync:.3e} h, "
              f"mtbe={snap.mtbe_hours:.3g} h, "
              f"confidence={snap.confidence:.2f}")
        print(f"[autotune] {len(tuner.alerts.records)} alert(s), "
              f"{tuner.evaluations} evaluation(s)")


def _sync(args, cfg) -> None:
    srv = make_server(RunConfig(model=cfg, train=TrainConfig()),
                      dual=args.dual)
    params = srv.model.init(jax.random.PRNGKey(0))
    prompts = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, min(cfg.vocab_size, 200),
                                         (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.frontend:
        prompts["frontend_embeds"] = 0.1 * jnp.ones(
            (args.batch, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
    toks, rep = srv.generate(params, prompts, steps=args.steps)
    tps = rep.tokens_emitted / max(rep.wall_s, 1e-9)
    print(f"{args.arch}: {rep.tokens_emitted} tokens, {tps:.1f} tok/s "
          f"(CPU smoke), detections={len(rep.detections)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--dual", action="store_true",
                    help="SEDAR dual-execution detection on decode")
    ap.add_argument("--smoke", action="store_true", default=True)
    # -- continuous-batching traffic replay (DESIGN.md §13) -----------------
    ap.add_argument("--continuous", action="store_true",
                    help="slot-scheduled continuous batching with "
                         "per-request recovery")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arrival-rate", type=float, default=1.0,
                    help="open-loop arrivals per decode tick")
    ap.add_argument("--prompt-mix", default="4:0.5,8:0.5",
                    help="len:weight[,len:weight...] prompt-length mix")
    ap.add_argument("--max-new", default="4,12",
                    help="comma list of per-request token budgets")
    ap.add_argument("--queue-depth", type=int, default=0,
                    help="admission-queue bound (0 = unbounded); a full "
                         "queue sheds load (backpressure rejection)")
    ap.add_argument("--validate-lag", type=int, default=None,
                    help="deferred-validation window D (DESIGN.md §11/§13)")
    ap.add_argument("--drain-cadence", type=int, default=None,
                    help="parked decode ticks per token drain (DESIGN.md "
                         "§18): default = the validate lag (one fused "
                         "readback per flush); 1 = legacy per-tick "
                         "emission; >lag accumulates across flushes")
    ap.add_argument("--backend", default="sequential",
                    choices=["none", "sequential", "fused", "abft",
                             "hybrid"])
    ap.add_argument("--max-retries", type=int, default=8,
                    help="consecutive per-slot failures before the request "
                         "is rejected (per-request L1)")
    ap.add_argument("--prefill-buckets", default="",
                    help="comma list of prompt-length buckets for packed "
                         "admission prefill (empty = geometric default, "
                         "DESIGN.md §14)")
    ap.add_argument("--max-pack", type=int, default=4,
                    help="max prompts packed into one prefill launch")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile every (bucket, pack) prefill program "
                         "before traffic (no traffic-time compiles)")
    ap.add_argument("--seed", type=int, default=0)
    # fault campaign
    ap.add_argument("--fault-slot", type=int, default=None,
                    help="inject a slot-localized SDC into this slot")
    ap.add_argument("--fault-step", type=int, default=5)
    ap.add_argument("--fault-persistent", action="store_true",
                    help="stuck bit: re-inject every step (drives the "
                         "per-request rejection path)")
    # -- cluster membership (DESIGN.md §16) ---------------------------------
    ap.add_argument("--heartbeat-dir", default=None,
                    help="publish this server's liveness to a shared "
                         "heartbeat directory and report any stale peers "
                         "after the run (a fleet supervisor uses the same "
                         "directory to drain a dead replica's traffic)")
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    ap.add_argument("--hb-timeout", type=float, default=60.0,
                    help="seconds without a heartbeat before a peer is "
                         "declared stale")
    # -- observability (DESIGN.md §15) --------------------------------------
    ap.add_argument("--metrics-dir", default=None,
                    help="enable the obs metrics registry + fault journal: "
                         "writes metrics.prom and journal.jsonl here and "
                         "prints the Prometheus snapshot after the run")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record per-stage trace spans to a Chrome-trace "
                         "JSON (open at ui.perfetto.dev)")
    # -- closed-loop autotuning (DESIGN.md §17) ------------------------------
    ap.add_argument("--autotune", action="store_true",
                    help="closed-loop calibration: estimate decode-tick/"
                         "flush costs and MTBE online, retune the serve "
                         "lag at clean flush boundaries (needs "
                         "--metrics-dir + --continuous)")
    ap.add_argument("--autotune-interval", type=int, default=16,
                    help="decode ticks between autotuner evaluations")
    ap.add_argument("--slo-availability", type=float, default=None,
                    help="availability SLO target (e.g. 0.999)")
    ap.add_argument("--slo-goodput", type=float, default=None,
                    help="goodput SLO target as a 0-1 fraction")
    args = ap.parse_args()
    if args.autotune and not (args.continuous and args.metrics_dir):
        ap.error("--autotune needs --continuous and --metrics-dir")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    ob = obs.configure(metrics_dir=args.metrics_dir, trace=args.trace)
    hb = mon = None
    if args.heartbeat_dir:
        from repro.runtime.cluster import ClusterMonitor, Heartbeat
        hb = Heartbeat(args.heartbeat_dir, args.host_id)
        hb.beat(0)
        mon = ClusterMonitor(args.heartbeat_dir, args.n_hosts,
                             timeout_s=args.hb_timeout)
    if args.continuous:
        _continuous(args, cfg, ob)
    else:
        _sync(args, cfg)
    if hb is not None:
        if not hb.beat(args.steps):
            print(f"[cluster] heartbeat write failed "
                  f"({hb.io_errors} IO errors) — peers will see this "
                  f"host as stale")
        stale = mon.stale_hosts()
        print(f"[cluster] host {args.host_id} of {args.n_hosts}: "
              f"{'stale peers ' + str(stale) if stale else 'all peers live'}")
    snap = ob.finalize()
    if snap:
        print(f"[obs] metrics snapshot ({args.metrics_dir}/metrics.prom):")
        print(snap, end="")
    if args.trace:
        print(f"[obs] trace written to {args.trace}")


if __name__ == "__main__":
    main()
