"""Live status view over a run's observability directory (DESIGN.md §17).

Tails the fault journal and the Prometheus snapshot a `--metrics-dir` run
writes, and renders one consolidated terminal page: journal record counts,
per-stage timings, reliability KPIs, the calibrated temporal-model view
(with the lag the analytic optimum would pick right now), and the most
recent alerts / reconfig transitions.

    PYTHONPATH=src python -m repro.launch.status --metrics-dir /tmp/obs
    # one-shot render (no screen clearing, exits immediately):
    PYTHONPATH=src python -m repro.launch.status --metrics-dir /tmp/obs --once

Read-only: this process never writes to the directory it watches, so it is
safe to point at a live run from another terminal.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, List

from repro.core import temporal_model as tm
from repro.obs import parse_prometheus
from repro.obs.estimator import OnlineEstimator, STEP_STAGES, SYNC_STAGE
from repro.obs.journal import FaultJournal
from repro.obs.kpi import compute_kpis


def _load(metrics_dir: str):
    recs: List[Dict[str, Any]] = []
    jpath = os.path.join(metrics_dir, "journal.jsonl")
    if os.path.exists(jpath) or os.path.exists(jpath + ".1"):
        recs = FaultJournal.load(jpath)
    types: Dict[str, str] = {}
    samples: Dict[Any, Any] = {}
    ppath = os.path.join(metrics_dir, "metrics.prom")
    if os.path.exists(ppath):
        with open(ppath) as f:
            types, samples = parse_prometheus(f.read())
    return recs, types, samples


def _stage_means(samples) -> List[Dict[str, Any]]:
    """[{stage, count, mean_s}] from the stage-duration histogram family."""
    sums = samples.get("sedar_stage_duration_seconds_sum", {})
    counts = samples.get("sedar_stage_duration_seconds_count", {})
    rows = []
    for lk, total in sorted(sums.items()):
        n = int(counts.get(lk, 0))
        if n <= 0:
            continue
        rows.append({"stage": dict(lk).get("stage", "?"), "count": n,
                     "mean_s": total / n})
    return rows


def _estimator_view(stages, recs) -> Dict[str, Any]:
    """Replay the parsed aggregates through an OnlineEstimator — the same
    calibration the in-process autotuner runs, reconstructed offline."""
    est = OnlineEstimator(tm.PAPER_TABLE3["JACOBI"])
    for row in stages:
        if row["stage"] in STEP_STAGES:
            est.observe_step_s(row["mean_s"], weight=row["count"])
        elif row["stage"] == SYNC_STAGE:
            est.observe_sync_s(row["mean_s"], weight=row["count"])
    est.ingest(journal=recs)
    snap = est.calibrated_params()
    lag = tm.optimal_validate_lag(snap.params, snap.mtbe_hours)
    return {"snap": snap, "lag": lag}


def render(metrics_dir: str, tail: int = 5) -> str:
    recs, types, samples = _load(metrics_dir)
    out: List[str] = []
    out.append(f"== SEDAR status: {metrics_dir} "
               f"({time.strftime('%H:%M:%S')}) ==")

    by_kind: Dict[str, int] = {}
    max_step = 0
    for r in recs:
        by_kind[r.get("kind", "?")] = by_kind.get(r.get("kind", "?"), 0) + 1
        if r.get("step") is not None:
            max_step = max(max_step, int(r["step"]))
    if recs:
        kinds = ", ".join(f"{k}={v}" for k, v in sorted(by_kind.items()))
        out.append(f"journal: {len(recs)} records ({kinds}), "
                   f"frontier step {max_step}")
    else:
        out.append("journal: empty")

    stages = _stage_means(samples)
    if stages:
        out.append("stages (mean):")
        for row in stages:
            out.append(f"  {row['stage']:<18} n={row['count']:<6} "
                       f"{1e3 * row['mean_s']:.3f} ms")

    depth = samples.get("sedar_serve_queue_depth")
    if depth:
        out.append(f"serve queue depth: {next(iter(depth.values())):g}")

    if recs:
        kpis = compute_kpis(recs, steps=max_step or None)
        out.append("kpis: " + ", ".join(
            f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in kpis.items()))

    if stages or recs:
        view = _estimator_view(stages, recs)
        snap = view["snap"]
        out.append(f"calibrated: t_step={snap.params.t_step:.3e} h, "
                   f"t_sync={snap.params.t_sync:.3e} h, "
                   f"mtbe={snap.mtbe_hours:.3g} h, "
                   f"confidence={snap.confidence:.2f} -> "
                   f"optimal validate lag {view['lag']}")

    alerts = [r for r in recs if r.get("kind") == "alert"][-tail:]
    if alerts:
        out.append(f"alerts (last {len(alerts)}):")
        for a in alerts:
            rec = a.get("record", {}) or {}
            out.append(f"  [{rec.get('severity', '?'):>8}] "
                       f"step {rec.get('step', '?')}: "
                       f"{rec.get('name', '?')} — "
                       f"{rec.get('message', '')}")
    reconfigs = [r for r in recs if r.get("kind") == "reconfig"][-tail:]
    if reconfigs:
        out.append(f"reconfigs (last {len(reconfigs)}):")
        for rc in reconfigs:
            rec = rc.get("record", {}) or {}
            changes = rec.get("changes", {})
            desc = ", ".join(
                f"{k}: {v.get('from')}->{v.get('to')}"
                if isinstance(v, dict) and "from" in v else f"{k}"
                for k, v in changes.items())
            out.append(f"  step {rec.get('step', '?')}: {desc} "
                       f"({rec.get('reason', '')})")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics-dir", required=True,
                    help="directory a run was launched with via "
                         "--metrics-dir (journal.jsonl + metrics.prom)")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="seconds between refreshes")
    ap.add_argument("--once", action="store_true",
                    help="render a single snapshot and exit (no screen "
                         "clearing; what the tests drive)")
    ap.add_argument("--tail", type=int, default=5,
                    help="how many recent alerts/reconfigs to show")
    args = ap.parse_args()

    if args.once:
        print(render(args.metrics_dir, tail=args.tail))
        return
    try:
        while True:
            print("\x1b[2J\x1b[H", end="")
            print(render(args.metrics_dir, tail=args.tail), flush=True)
            time.sleep(max(args.interval, 0.1))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
