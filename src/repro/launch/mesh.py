"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while smoke tests must see the
real single device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_mesh_compat(shape: Tuple[int, ...], axes: Tuple[str, ...],
                     devices=None):
    """Version-compat mesh construction.

    `jax.sharding.AxisType` (and the `axis_types=` kwarg of `jax.make_mesh`)
    only exist on newer JAX; older releases (e.g. 0.4.3x) reject either.
    Ladder: make_mesh+axis_types -> make_mesh -> plain Mesh construction.
    All three produce an Auto-axes mesh, which is what every call site here
    wants."""
    devices = list(devices if devices is not None else jax.devices())
    n = 1
    for s in shape:
        n *= s
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count "
            "BEFORE any jax import (see launch/dryrun.py)")
    devs = devices[:n]
    axis_type = getattr(jax.sharding, "AxisType", None)
    make = getattr(jax, "make_mesh", None)
    if make is not None:
        if axis_type is not None:
            try:
                return make(shape, axes, devices=devs,
                            axis_types=(axis_type.Auto,) * len(axes))
            except TypeError:
                pass
        try:
            return make(shape, axes, devices=devs)
        except TypeError:
            pass
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devs).reshape(shape), axes)


def _mk(shape: Tuple[int, ...], axes: Tuple[str, ...], devices=None):
    return make_mesh_compat(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment: one v5e pod 16x16 = 256 chips, or 2 pods = 512.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    Under SEDAR dual-replication the "pod" axis carries the two replicas
    (DESIGN.md §2/§6); in the unprotected baseline it is an extra data axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2, 2),
                   axes: Tuple[str, ...] = ("pod", "data", "model")):
    """Small mesh for CPU multi-device tests (needs forced host devices)."""
    return _mk(shape, axes)
