"""Multi-pod dry-run: lower + compile every (arch x shape x mesh x flavor)
cell on the production mesh and extract memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-large-123b \
        --shape train_4k --mesh single --flavor baseline

Artifacts: one JSON per cell under artifacts/dryrun/. The roofline report
(benchmarks/roofline.py) reads them.

Flavors:
  baseline : no protection; multi-pod meshes use the pod axis for data
             parallelism (batch over ("pod","data")).
  sedar    : the paper's dual-modular-redundant training step — the pod axis
             carries the two replicas, gradient fingerprints are exchanged
             over it (shard_map all-gather) and the commit is gated on the
             comparison. Proves the paper's mechanism lowers/shards at
             production scale.

Scan-cost correction (DESIGN.md §7): XLA counts each scan body once, so every
cell also lowers the model's Probe programs; corrected totals are
    total = full_program + sum_i multiplier_i * probe_i.
"""
# The VERY FIRST lines, before ANY other import (jax locks the device count
# on first init):
import os                                                     # noqa: E402
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import (SHAPES, SHAPE_BY_NAME, get_config,  # noqa: E402
                           shape_applicable, ASSIGNED_ARCHS)
from repro.launch import input_specs as ispec  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import apply_updates, make_optimizer  # noqa: E402
from repro.sharding import Resolver, ShardingRules  # noqa: E402

# -- hardware model (TPU v5e, task spec) ---------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum per-device result bytes of every collective op in compiled HLO."""
    per_kind: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(type_str):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_per_kind": per_kind, "count_per_kind": count,
            "total_bytes": sum(per_kind.values())}


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# NB: the while argument may contain nested parens (older jax prints the
# full tuple type: `while((s32[], f32[...]) %tuple.10), condition=...`), so
# the argument is matched non-greedily up to the condition/body attributes.
_WHILE_RE = re.compile(
    r"\bwhile\(.*?(?:condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)"
    r"|body=%?([\w.\-]+)\s*,\s*condition=%?([\w.\-]+))")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def parse_collective_bytes_loopaware(hlo_text: str) -> Dict[str, Any]:
    """Exact collective accounting: per-computation collective bytes weighted
    by the product of enclosing while-loop trip counts (scan bodies execute
    trip-count times, not once). Trip counts come from the s32 constants in
    each loop's condition computation (max constant = loop bound).

    This reads the REAL compiled program, so there are no probe-isolation
    artifacts; it is the collective source of truth for the roofline."""
    comps: Dict[str, dict] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = {"coll": {}, "whiles": [], "consts": [], "calls": []}
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        cm = _COLL_RE.search(line)
        if cm:
            nbytes = 0
            for sm in _SHAPE_RE.finditer(cm.group(1)):
                dt, dims = sm.group(1), sm.group(2)
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            kind = cm.group(2)
            comps[cur]["coll"][kind] = comps[cur]["coll"].get(kind, 0) + nbytes
        wm = _WHILE_RE.search(line)
        if wm:
            cond = wm.group(1) or wm.group(4)
            body = wm.group(2) or wm.group(3)
            comps[cur]["whiles"].append((cond, body))
        elif "=" in line:
            for dm in _CALL_RE.finditer(line):
                for name in dm.group(1).split(","):
                    comps[cur]["calls"].append(name.strip().lstrip("%"))
        for km in _CONST_RE.finditer(line):
            comps[cur]["consts"].append(int(km.group(1)))

    def trip_count(cond: str) -> int:
        cs = comps.get(cond, {}).get("consts", [])
        return max([c for c in cs if c > 0] or [1])

    totals: Dict[str, float] = {}
    counted = {}

    def visit(name: str, mult: float, depth=0):
        if name not in comps or depth > 12:
            return
        c = comps[name]
        for kind, b in c["coll"].items():
            totals[kind] = totals.get(kind, 0.0) + mult * b
        for cond, body in c["whiles"]:
            visit(body, mult * trip_count(cond), depth + 1)
            visit(cond, mult * trip_count(cond), depth + 1)
        for callee in c["calls"]:
            if callee in comps and callee != name:
                visit(callee, mult, depth + 1)

    if entry:
        visit(entry, 1.0)
    return {"bytes_per_kind": {k: float(v) for k, v in totals.items()},
            "total_bytes": float(sum(totals.values()))}


def _analyze(compiled) -> Dict[str, Any]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # older jax: one dict per partition
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    coll = parse_collective_bytes(text)
    coll_loop = parse_collective_bytes_loopaware(text)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collective": coll,
            "collective_loopaware": coll_loop}


# ---------------------------------------------------------------------------
# Program builders
# ---------------------------------------------------------------------------

def _half_params(params):
    """Pre-cast f32 masters to bf16 BEFORE the per-layer FSDP all-gathers, so
    weight gathers and gradient reduce-scatters move bf16 (2x less ICI)."""
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
        params)


def build_train_program(cfg, shape, mesh, resolver, flavor, train_cfg=None,
                        microbatches: int = 1):
    """Full train step: grads (accumulated over `microbatches`) + AdamW commit.

    Gradient accumulation is the standard fit-the-batch mechanism at 100B
    scale: per-microbatch activations shrink by M while the f32 accumulator
    costs one params-sized buffer. The dry-run auto-raises M until the cell
    fits HBM (recorded in the artifact)."""
    from repro.configs.base import TrainConfig
    from repro.models.transformer import ShardCtx
    model = build_model(cfg)
    opt = make_optimizer(train_cfg or TrainConfig())
    ctx = ShardCtx(mesh, resolver)
    M = microbatches

    state_specs, state_axes = ispec.train_state_specs(cfg)
    bspecs, baxes = ispec.batch_specs(cfg, shape)

    pshard = resolver.tree_shardings(state_axes["params"],
                                     state_specs["params"]) \
        if hasattr(resolver, "tree_shardings") else None

    def _pin_grads(grads):
        """Constrain bf16 grads to the parameter sharding BEFORE the f32
        cast, so the cross-data reduction moves bf16 (reduce-scatter), not
        f32 partials (§Perf C9)."""
        if pshard is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, pshard)

    def accumulate_grads(half, batch):
        def loss_fn(ph, b):
            return model.loss(ph, b, ctx)[0]

        if M <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(half, batch)
            grads = _pin_grads(grads)
            return loss, jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        mb = jax.tree.map(
            lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]), batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), half)

        def micro(acc, b):
            loss, g = jax.value_and_grad(loss_fn)(half, b)
            g = _pin_grads(g)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) / M, acc, g)
            return acc, loss

        grads, losses = jax.lax.scan(micro, zeros, mb)
        return jnp.mean(losses), grads

    if flavor == "sedar":
        from repro.core.detection import make_pod_comparator
        from repro.core.fingerprint import pytree_fingerprint
        pod_cmp = make_pod_comparator(mesh, "pod")

        def step(state, batch):
            half = _half_params(state["params"])
            loss, grads = accumulate_grads(half, batch)
            fp = pytree_fingerprint(grads)
            eq, fp_all = pod_cmp(fp)
            updates, new_opt = opt.update(grads, state["opt"],
                                          state["params"], state["step"])
            new_params = apply_updates(state["params"], updates)
            cand = {"params": new_params, "opt": new_opt,
                    "step": state["step"] + 1}
            # Commit gating is RUNTIME-side at production scale: an in-jit
            # where(eq, cand, state) select keeps two full TrainStates live
            # (+~params*12 bytes/chip at 123B — the difference between
            # fitting HBM and not). The runtime reads `eq` before the state
            # is checkpointed or otherwise externalized, so the paper's
            # containment ("never send corrupted data") holds; a mismatch
            # triggers L2/L3 rollback of the uncommitted step instead.
            return cand, (loss, eq, fp_all)
    else:
        def step(state, batch):
            half = _half_params(state["params"])
            loss, grads = accumulate_grads(half, batch)
            updates, new_opt = opt.update(grads, state["opt"],
                                          state["params"], state["step"])
            new_params = apply_updates(state["params"], updates)
            return ({"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}, loss)

    in_shardings = (resolver.tree_shardings(state_axes, state_specs),
                    resolver.tree_shardings(baxes, bspecs))
    fn = jax.jit(step, in_shardings=in_shardings, donate_argnums=(0,))
    return fn, (state_specs, bspecs)


def build_prefill_program(cfg, shape, mesh, resolver):
    from repro.models.transformer import ShardCtx
    model = build_model(cfg)
    ctx = ShardCtx(mesh, resolver)
    pspecs, paxes = ispec.serve_param_specs(cfg)
    bspecs, baxes = ispec.batch_specs(cfg, shape)

    # decode cache must hold prompt + visual prefix for VLM archs
    max_len = shape.seq_len + (cfg.frontend_seq if cfg.family == "vlm" else 0)

    def prefill(params, batch):
        return model.prefill(params, batch, max_len, ctx)

    in_shardings = (resolver.tree_shardings(paxes, pspecs),
                    resolver.tree_shardings(baxes, bspecs))
    fn = jax.jit(prefill, in_shardings=in_shardings)
    return fn, (pspecs, bspecs)


def build_decode_program(cfg, shape, mesh, resolver):
    from repro.models.transformer import ShardCtx
    model = build_model(cfg)
    ctx = ShardCtx(mesh, resolver)
    pspecs, paxes = ispec.serve_param_specs(cfg)
    dspecs, daxes = ispec.decode_specs(cfg, shape)

    def decode(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, ctx)

    in_shardings = (resolver.tree_shardings(paxes, pspecs),
                    resolver.tree_shardings(daxes["cache"], dspecs["cache"]),
                    resolver.tree_shardings(daxes["tokens"], dspecs["tokens"]),
                    None)
    fn = jax.jit(decode, in_shardings=in_shardings, donate_argnums=(1,))
    return fn, (pspecs, dspecs["cache"], dspecs["tokens"], dspecs["pos"])


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, flavor: str,
             out_dir: str, with_probes: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    t0 = time.time()
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "flavor": flavor}

    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        cell.update({"status": "skipped", "reason": reason})
        return _emit(cell, out_dir)

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    # sequence-parallel activation sharding for full-sequence programs: the
    # residual-stream carries saved by the layer scans shard over the model
    # axis (Megatron-SP), which is what lets the biggest train cells fit HBM.
    # Hillclimb knobs (recorded in the artifact): REPRO_NO_SEQP=1 disables
    # SP; REPRO_MICRO=n pins the accumulation factor; REPRO_REMAT overrides
    # the remat policy.
    seqp = shape.kind != "decode" and not os.environ.get("REPRO_NO_SEQP")
    if os.environ.get("REPRO_REMAT"):
        cfg = dataclasses.replace(cfg, remat=os.environ["REPRO_REMAT"])
    cell["knobs"] = {"seqp": seqp, "remat": cfg.remat,
                     "forced_micro": os.environ.get("REPRO_MICRO")}
    if flavor == "sedar":
        if not multi:
            cell.update({"status": "skipped",
                         "reason": "sedar flavor needs the pod axis"})
            return _emit(cell, out_dir)
        rules = ShardingRules(data_axes=("data",),        # pod = replica axis
                              sequence_parallel=seqp)
    else:
        rules = ShardingRules(data_axes=(("pod", "data") if multi
                                         else ("data",)),
                              sequence_parallel=seqp)
    resolver = Resolver(mesh, rules)

    micro = int(os.environ.get("REPRO_MICRO", 1))
    HBM = 16 * 2**30
    try:
        with mesh:
            if shape.kind == "train":
                # auto-raise gradient-accumulation factor until the cell fits
                while True:
                    fn, args = build_train_program(cfg, shape, mesh, resolver,
                                                   flavor, microbatches=micro)
                    compiled = fn.lower(*args).compile()
                    ma = compiled.memory_analysis()
                    per_dev = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                               + ma.output_size_in_bytes - ma.alias_size_in_bytes)
                    B = shape.global_batch
                    # sedar-dual pods carry the FULL replica batch (the
                    # paper's 2x redundancy), so allow deeper accumulation
                    cap = 32 if flavor == "sedar" else 16
                    if per_dev <= HBM or micro * 2 > min(cap, B):
                        break
                    micro *= 2
            elif shape.kind == "prefill":
                fn, args = build_prefill_program(cfg, shape, mesh, resolver)
                compiled = fn.lower(*args).compile()
                ma = compiled.memory_analysis()
            else:
                fn, args = build_decode_program(cfg, shape, mesh, resolver)
                compiled = fn.lower(*args).compile()
                ma = compiled.memory_analysis()
            full = _analyze(compiled)
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug, record it
        cell.update({"status": "failed", "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-2000:]})
        return _emit(cell, out_dir)
    cell["microbatches"] = micro

    per_dev_bytes = int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                        + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    cell["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "per_device_bytes": per_dev_bytes,
        "fits_16GiB": bool(per_dev_bytes <= 16 * 2**30),
    }
    cell["full_program"] = full

    # -- corrections -------------------------------------------------------------
    # Collectives: the loop-aware HLO walk of the REAL program is exact
    # (trip-count-weighted), so probes contribute nothing there. FLOPs/bytes:
    # cost_analysis has no per-op attribution, so scan bodies are corrected
    # with probe programs; with gradient accumulation the per-microbatch
    # structure repeats M times:
    #   total = full + (M-1)*P_micro + M * sum_i mult_i * P_i(micro shape)
    model = build_model(cfg)
    tot_flops, tot_bytes = full["flops"], full["bytes"]
    tot_coll = float(full["collective_loopaware"]["total_bytes"])
    probes_out = []
    if with_probes:
        probe_shape = (dataclasses.replace(
            shape, global_batch=shape.global_batch // micro)
            if micro > 1 else shape)
        scale = micro if shape.kind == "train" else 1
        probe_list = list(model.probes(probe_shape))
        if micro > 1:
            from repro.models.model import Probe, _grad_probe
            hspecs, haxes = ispec.serve_param_specs(cfg)   # bf16 weights
            mb_specs, mb_axes = ispec.batch_specs(cfg, probe_shape)

            def loss_micro(ph, b):
                return model.loss(ph, b, None)[0]

            probe_list.append(Probe("micro", _grad_probe(loss_micro),
                                    (hspecs, mb_specs), (haxes, mb_axes),
                                    multiplier=(micro - 1) / scale))
        try:
            with mesh:
                for p in probe_list:
                    shardings = tuple(
                        resolver.tree_shardings(ax, sp)
                        for ax, sp in zip(p.arg_axes, p.arg_specs))
                    pc = _lower_probe(mesh, p, shardings)
                    pa = _analyze(pc)
                    mult = p.multiplier * scale
                    probes_out.append({"name": p.name,
                                       "multiplier": mult,
                                       **{k: pa[k] for k in ("flops", "bytes")},
                                       "collective_bytes":
                                       float(pa["collective"]["total_bytes"])})
                    tot_flops += mult * pa["flops"]
                    tot_bytes += mult * pa["bytes"]
        except Exception as e:  # noqa: BLE001
            cell["probe_error"] = f"{type(e).__name__}: {e}"
    cell["probes"] = probes_out

    # -- roofline terms (per task spec; quantities are per-device) ---------------
    compute_s = tot_flops / PEAK_FLOPS
    memory_s = tot_bytes / HBM_BW
    coll_s = tot_coll / ICI_BW
    dominant = max((compute_s, "compute"), (memory_s, "memory"),
                   (coll_s, "collective"))[1]

    n_params = model.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * shape.global_batch

    hlo_flops_global = tot_flops * chips
    cell.update({
        "status": "ok",
        "chips": int(chips),
        "corrected": {"flops_per_device": tot_flops,
                      "bytes_per_device": tot_bytes,
                      "collective_bytes_per_device": tot_coll},
        "roofline": {"compute_s": compute_s, "memory_s": memory_s,
                     "collective_s": coll_s, "dominant": dominant,
                     "bound_s": max(compute_s, memory_s, coll_s)},
        "model_flops": float(model_flops),
        "hlo_flops_global": float(hlo_flops_global),
        "useful_flops_ratio": float(model_flops / hlo_flops_global)
        if hlo_flops_global else 0.0,
        "params": int(n_params),
        "active_params": int(n_active),
        "sharding_fallbacks": resolver.fallback_report()[:40],
        "elapsed_s": round(time.time() - t0, 1),
    })
    return _emit(cell, out_dir)


def _lower_probe(mesh, p, shardings):
    """Grad probes return (value, [grads-of-float-args]); pin the grads to
    their argument shardings so XLA does not append replication all-reduces
    that the real in-loop program never performs."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    leaves, _ = jax.tree_util.tree_flatten(p.arg_specs)
    sh_leaves = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    float_sh = [s for l, s in zip(leaves, sh_leaves)
                if jnp.issubdtype(l.dtype, jnp.floating)]
    scalar = NamedSharding(mesh, P())
    try:
        fn = jax.jit(p.fn, in_shardings=shardings,
                     out_shardings=(scalar, float_sh))
        return fn.lower(*p.arg_specs).compile()
    except (TypeError, ValueError):
        return jax.jit(p.fn, in_shardings=shardings)             .lower(*p.arg_specs).compile()


def _emit(cell: Dict[str, Any], out_dir: str) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{cell['arch']}__{cell['shape']}__{cell['mesh']}__{cell['flavor']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(cell, f, indent=1, default=str)
    status = cell.get("status")
    roof = cell.get("roofline", {})
    print(f"[dryrun] {cell['arch']:24s} {cell['shape']:12s} {cell['mesh']:6s} "
          f"{cell['flavor']:8s} {status:8s} "
          f"dom={roof.get('dominant', '-'):10s} "
          f"fit={cell.get('memory', {}).get('fits_16GiB', '-')} "
          f"t={cell.get('elapsed_s', '-')}s"
          + (f" err={cell.get('error', '')[:90]}" if status == "failed" else ""),
          flush=True)
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--flavor", default="baseline",
                    choices=["baseline", "sedar", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    flavors = ["baseline", "sedar"] if args.flavor == "both" else [args.flavor]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                for fl in flavors:
                    if fl == "sedar" and (mk != "multi" or shape != "train_4k"):
                        continue
                    cell = run_cell(arch, shape, mk, fl, args.out,
                                    with_probes=not args.no_probes)
                    if cell.get("status") == "failed":
                        n_fail += 1
    print(f"[dryrun] done, failures: {n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
