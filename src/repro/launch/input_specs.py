"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero device allocation (the shannon/kernels pattern).

Returns (specs, logical_axes) pytrees per (arch config, ShapeSpec)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Dict, Dict]:
    """Training / prefill batch: tokens + targets (+ frontend embeddings for
    the modality-stub archs, per the task spec)."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    axes: Dict[str, Any] = {"tokens": ("batch", None)}
    if shape.kind == "train":
        specs["targets"] = _sds((B, S), jnp.int32)
        axes["targets"] = ("batch", None)
    if cfg.frontend:
        specs["frontend_embeds"] = _sds((B, cfg.frontend_seq, cfg.frontend_dim),
                                        jnp.dtype(cfg.dtype))
        axes["frontend_embeds"] = ("batch", None, None)
    return specs, axes


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Dict, Dict]:
    """Serve-step inputs: one new token per sequence + position + cache."""
    from repro.models import build_model
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)

    holder = {}

    def mk():
        c, ax = model.init_cache(B, S, jnp.bfloat16)
        holder["ax"] = ax
        return c

    cache = jax.eval_shape(mk)
    specs = {"tokens": _sds((B,), jnp.int32), "pos": _sds((), jnp.int32),
             "cache": cache}
    axes = {"tokens": ("batch",), "pos": (), "cache": holder["ax"]}
    return specs, axes


def train_state_specs(cfg: ModelConfig) -> Tuple[Dict, Dict]:
    """Full TrainState: f32 master params + AdamW moments (realistic memory)."""
    from repro.models import build_model
    model = build_model(cfg)
    pshapes, paxes = model.abstract_params()
    f32 = jax.tree.map(lambda s: _sds(s.shape, jnp.float32), pshapes)
    specs = {"params": f32,
             "opt": {"m": f32, "v": f32},
             "step": _sds((), jnp.int32)}
    axes = {"params": paxes, "opt": {"m": paxes, "v": paxes}, "step": ()}
    return specs, axes


def serve_param_specs(cfg: ModelConfig) -> Tuple[Dict, Dict]:
    """Serving deployment: bf16 weights."""
    from repro.models import build_model
    model = build_model(cfg)
    pshapes, paxes = model.abstract_params()
    bf16 = jax.tree.map(lambda s: _sds(s.shape, jnp.bfloat16), pshapes)
    return bf16, paxes
