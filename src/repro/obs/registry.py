"""Process-wide metrics registry: counters / gauges / histograms with labels.

This is the single sink that absorbs the repo's three ad-hoc counting hooks
(`hostsync.count_transfers`, `prefill.count_compiles`,
`checkpoint.store.count_disk_reads`) plus every engine-level event counter
(detections, recoveries, rollbacks, rejections, per-tier checkpoint
saves/restores). The old context managers stay as thin compatibility shims
for scoped assertions; the registry is the PROCESS-WIDE, CROSS-THREAD view.

Design constraints (DESIGN.md §15):

  * **Metrics-off is a no-op.** The registry starts disabled; the producer
    hooks installed into hostsync/prefill/store are `None` until
    `enable()` runs, so the disabled fast path is one `is None` test —
    nothing allocates, nothing locks. Benchmarks assert < 3% overhead for
    the ENABLED path (`bench_observability.py`).
  * **Cross-thread aggregation is explicit.** Every mutation takes the
    registry lock, so counts from a background consumer thread (the
    ROADMAP's detokenize-drain item) aggregate correctly — unlike the
    `TransferStats` shim, which is thread-local BY DESIGN and documents
    that choice with a test (tests/test_obs.py).
  * **Zero extra host syncs.** The registry only ever records host-side
    facts that already exist (a label string, an event dict, a wall
    clock); no producer hook may issue a device readback.

`percentile(values, q)` is the repo's one shared nearest-rank percentile
(matches `numpy.percentile(..., method="inverted_cdf")`); the scheduler's
TTFT/latency reports and the bench harness use it instead of hand-rolled
index formulas.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Bounded per-histogram sample buffer: enough for smoke-scale percentile
# reporting without unbounded growth on long runs (old samples are dropped
# FIFO; count/sum/min/max stay exact).
HIST_MAX_SAMPLES = 4096

# Default cumulative-bucket ladder: 1-2.5-5 decades from 1ms-scale to
# 1000-scale, covering both seconds-valued spans and ms-valued latency
# histograms with one generic ladder. Override per-metric with
# `MetricsRegistry.set_buckets` before the first observe.
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0)


def percentile(values: Iterable[float], q: float) -> float:
    """Nearest-rank percentile (the one percentile implementation).

    rank = ceil(q/100 * N) clamped to [1, N]; returns values[rank-1] of the
    sorted list. Matches ``numpy.percentile(values, q,
    method="inverted_cdf")`` (property-tested in tests/test_obs.py), which
    makes p50 a true median draw and p99 clamp to the max for small N —
    the two corners the previous per-call-site formulas disagreed on.
    """
    vals = sorted(values)
    if not vals:
        return 0.0
    n = len(vals)
    rank = math.ceil((float(q) / 100.0) * n)
    return float(vals[min(max(rank, 1), n) - 1])


class _Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0


class _Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0


class _Histogram:
    __slots__ = ("count", "total", "vmin", "vmax", "samples",
                 "buckets", "bucket_counts")

    def __init__(self, buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.samples: List[float] = []
        # bucket_counts[i] counts observations <= buckets[i] (per-bucket,
        # not cumulative; exposition cumulates). Exact even after the
        # sample buffer drops old values FIFO.
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.bucket_counts = [0] * len(self.buckets)

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += value
        self.vmin = min(self.vmin, value)
        self.vmax = max(self.vmax, value)
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                self.bucket_counts[i] += 1
                break
        self.samples.append(value)
        if len(self.samples) > HIST_MAX_SAMPLES:
            del self.samples[: len(self.samples) - HIST_MAX_SAMPLES]

    def quantile(self, q: float) -> float:
        return percentile(self.samples, q)

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """[(upper_bound, cumulative_count)] — Prometheus `le` semantics;
        the implicit +Inf bucket (== count) is appended by the renderer."""
        out, acc = [], 0
        for ub, c in zip(self.buckets, self.bucket_counts):
            acc += c
            out.append((ub, acc))
        return out


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Lock-protected, label-aware metric store.

    One registry per process (`repro.obs.metrics`); mutation from any
    thread is safe and aggregates into the same series. Names follow the
    Prometheus convention (`*_total` counters, unit-suffixed gauges/
    histograms); the full catalog lives in DESIGN.md §15.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._kinds: Dict[str, str] = {}
        self._series: Dict[Tuple[str, LabelKey], object] = {}
        self._buckets: Dict[str, Tuple[float, ...]] = {}

    # -- internals -----------------------------------------------------------

    def _get(self, kind: str, name: str, labels: Dict[str, object]):
        prev = self._kinds.setdefault(name, kind)
        if prev != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{prev}, not {kind}")
        key = (name, _label_key(labels))
        m = self._series.get(key)
        if m is None:
            if kind == "histogram":
                m = _Histogram(self._buckets.get(name, DEFAULT_BUCKETS))
            else:
                m = {"counter": _Counter, "gauge": _Gauge}[kind]()
            self._series[key] = m
        return m

    def set_buckets(self, name: str, buckets: Iterable[float]) -> None:
        """Pin a histogram's bucket ladder; must precede the first observe
        (existing series keep the ladder they were created with)."""
        with self._lock:
            self._buckets[name] = tuple(sorted(float(b) for b in buckets))

    # -- producers -----------------------------------------------------------

    def inc(self, name: str, value: float = 1, **labels) -> None:
        with self._lock:
            self._get("counter", name, labels).value += value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._get("gauge", name, labels).value = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        with self._lock:
            self._get("histogram", name, labels).observe(float(value))

    # -- consumers -----------------------------------------------------------

    def get(self, name: str, **labels) -> float:
        """Current value of a counter/gauge series (0.0 when unseen)."""
        with self._lock:
            m = self._series.get((name, _label_key(labels)))
            return float(m.value) if m is not None else 0.0

    def get_histogram(self, name: str, **labels) -> Optional[_Histogram]:
        with self._lock:
            m = self._series.get((name, _label_key(labels)))
            return m if isinstance(m, _Histogram) else None

    def labels_of(self, name: str) -> List[Dict[str, str]]:
        with self._lock:
            return [dict(lk) for (n, lk) in self._series if n == name]

    def snapshot(self) -> Dict[str, Dict[LabelKey, float]]:
        """{name: {label_key: value}} for counters/gauges (histograms
        surface their count)."""
        out: Dict[str, Dict[LabelKey, float]] = {}
        with self._lock:
            for (name, lk), m in self._series.items():
                val = m.count if isinstance(m, _Histogram) else m.value
                out.setdefault(name, {})[lk] = float(val)
        return out

    def reset(self) -> None:
        with self._lock:
            self._kinds.clear()
            self._series.clear()
            self._buckets.clear()

    # -- Prometheus text exposition ------------------------------------------

    def render_prometheus(self) -> str:
        """Prometheus-style text snapshot (`--metrics-dir` writes this as
        metrics.prom; the launchers print it after a run). Histograms render
        as cumulative le-labeled `_bucket` lines (with the implicit `+Inf`
        bucket) plus `_sum`/`_count` — the real Prometheus histogram
        exposition, scrapeable and `parse_prometheus`-round-trippable."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._kinds):
                kind = self._kinds[name]
                lines.append(f"# TYPE {name} "
                             f"{'histogram' if kind == 'histogram' else kind}")
                series = sorted((lk, m) for (n, lk), m in
                                self._series.items() if n == name)
                for lk, m in series:
                    lab = ",".join(f'{k}="{v}"' for k, v in lk)
                    if kind == "histogram":
                        blab = (lab + "," if lab else "")
                        for ub, cum in m.cumulative_buckets():
                            lines.append(
                                f"{name}_bucket{{{blab}le=\"{ub:g}\"}} "
                                f"{cum}")
                        lines.append(
                            f"{name}_bucket{{{blab}le=\"+Inf\"}} {m.count}")
                        lines.append(f"{name}_sum"
                                     f"{'{' + lab + '}' if lab else ''} "
                                     f"{m.total:g}")
                        lines.append(f"{name}_count"
                                     f"{'{' + lab + '}' if lab else ''} "
                                     f"{m.count}")
                    else:
                        body = f"{{{lab}}}" if lab else ""
                        lines.append(f"{name}{body} {m.value:g}")
        return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str):
    """Parse the text exposition back into structured samples.

    Returns ``(types, samples)`` where ``types`` maps metric family name to
    its declared kind and ``samples`` maps sample name (including
    ``_bucket``/``_sum``/``_count`` suffixes) to ``{label_key: value}``.
    Used by the round-trip pin test and the live `launch/status.py` view;
    tolerant of comments and blank lines, strict about sample syntax.
    """
    types: Dict[str, str] = {}
    samples: Dict[str, Dict[LabelKey, float]] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        name_labels, _, value = line.rpartition(" ")
        name, brace, rest = name_labels.partition("{")
        labels: Dict[str, str] = {}
        if brace:
            body = rest.rsplit("}", 1)[0]
            for pair in filter(None, body.split(",")):
                k, _, v = pair.partition("=")
                labels[k.strip()] = v.strip().strip('"')
        samples.setdefault(name, {})[_label_key(labels)] = float(value)
    return types, samples
