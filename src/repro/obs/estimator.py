"""Online temporal-model calibration from live telemetry (DESIGN.md §17).

The paper's Section-7 model predicts every strategy's cost from a handful
of parameters — step time, sync cost, per-tier checkpoint costs, MTBE, SDC
mix — that PR 7's registry and journal already *measure*. This module
closes the gap: `OnlineEstimator` folds the live streams into a calibrated
`SedarParams`/`TierCosts` snapshot the autotuner can re-plan from (Aupy et
al.'s optimal verification cadence is a closed-form function of exactly
these quantities).

Two intake paths, same accumulators:

  * ``ingest(metrics, journal)`` — pull deltas since the last call from
    the stage-duration histograms (count/total per stage label) and the
    journal (records past the last seen seq). This is what the Autotuner
    calls between steps; it reads ONLY host-side aggregates the engine
    already produced, so the zero-extra-hostsync contract holds trivially.
  * ``observe_*`` — direct push for benches/tests that synthesize streams
    without a running engine.

Estimates are EWMA-smoothed with a sliding window for dispersion; MTBE is
the smoothed inter-detection gap with a Bayesian-style prior so a
fault-free stretch decays toward "rarer than observed horizon" instead of
jumping to infinity.

Pure Python + `repro.core.temporal_model` (also pure) — importable
without jax, like the rest of `repro.obs`.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, Optional

from repro.core.temporal_model import SedarParams, TierCosts, \
    default_tier_costs

S_PER_H = 3600.0

# stage-duration labels (obs.span names) feeding each estimate
STEP_STAGES = ("train_step", "decode_tick")
SYNC_STAGE = "deferred_flush"
TIER_STAGES = ("device", "host", "disk", "partner")


class _Ewma:
    """EWMA mean + a bounded sliding window for variance/extremes."""

    __slots__ = ("alpha", "mean", "n", "window")

    def __init__(self, alpha: float = 0.2, window: int = 256):
        self.alpha = float(alpha)
        self.mean: Optional[float] = None
        self.n = 0
        self.window: Deque[float] = deque(maxlen=window)

    def add(self, x: float, weight: int = 1) -> None:
        x = float(x)
        for _ in range(max(int(weight), 1)):
            self.mean = (x if self.mean is None
                         else self.alpha * x + (1 - self.alpha) * self.mean)
            self.n += 1
        self.window.append(x)

    def std(self) -> float:
        if len(self.window) < 2:
            return 0.0
        m = sum(self.window) / len(self.window)
        return math.sqrt(sum((v - m) ** 2 for v in self.window)
                         / (len(self.window) - 1))


@dataclass(frozen=True)
class CalibratedSnapshot:
    """One self-consistent calibration the control loop can plan from."""

    params: SedarParams                 # base params with measured overrides
    tier_costs: Dict[str, TierCosts]
    mtbe_hours: float
    sdc_fraction: float                 # detections that were SDCs (vs hangs)
    sample_counts: Dict[str, int] = field(default_factory=dict)
    confidence: float = 0.0             # 0..1, saturating in sample count

    def is_confident(self, floor: float = 0.5) -> bool:
        return self.confidence >= floor


class OnlineEstimator:
    """Fits SedarParams/TierCosts online from metrics + journal streams.

    ``base`` supplies every parameter telemetry cannot see (T_rest, f_d,
    redundancy_wall, ...); the snapshot overrides only what was measured
    (`t_step`, `t_sync`, tier save/restore costs). ``prior_mtbe_hours``
    anchors the failure-rate estimate until enough detections arrive —
    with ``n`` observed gaps the estimate is ``(elapsed + prior) /
    (n + 1)``, i.e. one pseudo-observation of the prior.
    """

    # confidence saturates once this many step samples have been seen
    CONF_STEPS = 64

    def __init__(self, base: SedarParams,
                 prior_mtbe_hours: float = 24.0,
                 alpha: float = 0.2, window: int = 256):
        self.base = base
        self.prior_mtbe_hours = float(prior_mtbe_hours)
        self._step_s = _Ewma(alpha, window)
        self._sync_s = _Ewma(alpha, window)
        self._tier_save_s = {t: _Ewma(alpha, window) for t in TIER_STAGES}
        self._tier_restore_s = {t: _Ewma(alpha, window) for t in TIER_STAGES}
        self._gap_s = _Ewma(alpha, window)
        self._n_gaps = 0
        self._n_detections = 0
        self._n_sdc = 0
        self._last_det_t: Optional[float] = None
        self._elapsed_s = 0.0
        # ingest cursors
        self._hist_seen: Dict[Any, tuple] = {}
        self._journal_seq = -1

    # -- direct push (benches/tests) ----------------------------------------

    def observe_step_s(self, seconds: float, weight: int = 1) -> None:
        self._step_s.add(seconds, weight)
        self._elapsed_s += float(seconds) * max(int(weight), 1)

    def observe_sync_s(self, seconds: float, weight: int = 1) -> None:
        self._sync_s.add(seconds, weight)

    def observe_tier_save_s(self, tier: str, seconds: float) -> None:
        if tier in self._tier_save_s:
            self._tier_save_s[tier].add(seconds)

    def observe_tier_restore_s(self, tier: str, seconds: float) -> None:
        if tier in self._tier_restore_s:
            self._tier_restore_s[tier].add(seconds)

    def observe_fault(self, t_s: float, sdc: bool = True) -> None:
        """A detection at monotonic offset ``t_s`` (journal t_mono)."""
        self._n_detections += 1
        if sdc:
            self._n_sdc += 1
        if self._last_det_t is not None and t_s > self._last_det_t:
            self._gap_s.add(t_s - self._last_det_t)
            self._n_gaps += 1
        self._last_det_t = t_s

    # -- pull path: registry histograms + journal ---------------------------

    def ingest(self, metrics=None, journal=None) -> None:
        """Fold in everything new since the last ingest.

        ``metrics`` is a MetricsRegistry whose `sedar_stage_duration_seconds`
        histograms carry per-stage (count, total); deltas since the last
        call are attributed at the per-stage mean. ``journal`` is a
        FaultJournal (or a plain record list) scanned past the last seen
        seq for detections and tier restores.
        """
        if metrics is not None:
            for labels in metrics.labels_of("sedar_stage_duration_seconds"):
                stage = labels.get("stage", "")
                h = metrics.get_histogram("sedar_stage_duration_seconds",
                                          **labels)
                if h is None:
                    continue
                key = tuple(sorted(labels.items()))
                seen_c, seen_t = self._hist_seen.get(key, (0, 0.0))
                dc, dt = h.count - seen_c, h.total - seen_t
                self._hist_seen[key] = (h.count, h.total)
                if dc <= 0:
                    continue
                mean = dt / dc
                if stage in STEP_STAGES:
                    self.observe_step_s(mean, weight=dc)
                elif stage == SYNC_STAGE:
                    self.observe_sync_s(mean, weight=dc)
                elif stage == "checkpoint":
                    # engine-level span; per-tier costs arrive via the
                    # journal's tier_restore lines and the tier-labeled
                    # histograms when present
                    self.observe_tier_save_s("disk", mean)
        if journal is not None:
            recs = journal.records() if hasattr(journal, "records") \
                else list(journal)
            for rec in recs:
                if rec.get("seq", -1) <= self._journal_seq:
                    continue
                self._journal_seq = max(self._journal_seq,
                                        rec.get("seq", -1))
                kind = rec.get("kind")
                if kind == "detection":
                    ev = rec.get("event", {})
                    self.observe_fault(
                        float(rec.get("t_mono", 0.0)),
                        sdc=(ev.get("effect") != "hang"))

    # -- estimates ----------------------------------------------------------

    def mtbe_hours(self) -> float:
        """Smoothed MTBE with a one-pseudo-observation prior."""
        if self._n_gaps >= 2 and self._gap_s.mean:
            return self._gap_s.mean / S_PER_H
        elapsed_h = self._elapsed_s / S_PER_H
        return (elapsed_h + self.prior_mtbe_hours) / (self._n_detections + 1)

    def calibrated_params(self) -> CalibratedSnapshot:
        p = self.base
        over = {}
        if self._step_s.mean:
            over["t_step"] = self._step_s.mean / S_PER_H
        if self._sync_s.mean:
            over["t_sync"] = self._sync_s.mean / S_PER_H
        if over:
            p = dataclasses.replace(p, **over)
        costs = dict(default_tier_costs(p))
        for tier in TIER_STAGES:
            save, rest = self._tier_save_s[tier], self._tier_restore_s[tier]
            if save.mean or rest.mean:
                cur = costs[tier]
                costs[tier] = TierCosts(
                    t_save=(save.mean / S_PER_H if save.mean
                            else cur.t_save),
                    t_restore=(rest.mean / S_PER_H if rest.mean
                               else cur.t_restore),
                    slots=cur.slots)
        counts = {
            "step": self._step_s.n, "sync": self._sync_s.n,
            "detections": self._n_detections, "gaps": self._n_gaps,
            **{f"tier_save_{t}": self._tier_save_s[t].n
               for t in TIER_STAGES if self._tier_save_s[t].n},
        }
        conf = min(1.0, self._step_s.n / float(self.CONF_STEPS))
        if self._sync_s.n == 0:
            conf *= 0.5        # t_sync still the prior — halve confidence
        return CalibratedSnapshot(
            params=p, tier_costs=costs, mtbe_hours=self.mtbe_hours(),
            sdc_fraction=(self._n_sdc / self._n_detections
                          if self._n_detections else 1.0),
            sample_counts=counts, confidence=conf)
