"""Per-stage trace spans in Chrome-trace / Perfetto JSON.

Spans cover the protected pipeline's host-visible stages — prefill pack,
decode tick, train step, deferred flush, validate, checkpoint (per tier),
rollback, restore plan — as "X" (complete) events. Load the output at
https://ui.perfetto.dev or chrome://tracing.

Timing uses `time.monotonic()` only: a span brackets work the host was
already blocking on, so tracing adds zero device syncs by construction.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional


class TraceRecorder:
    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.monotonic()
        self._lock = threading.Lock()

    @contextmanager
    def span(self, name: str, cat: str = "sedar", **args):
        start = time.monotonic()
        try:
            yield
        finally:
            end = time.monotonic()
            ev = {
                "name": name,
                "cat": cat,
                "ph": "X",
                "ts": (start - self._t0) * 1e6,
                "dur": (end - start) * 1e6,
                "pid": 0,
                "tid": threading.get_ident() & 0xFFFF,
            }
            if args:
                ev["args"] = {k: _arg(v) for k, v in args.items()}
            with self._lock:
                self.events.append(ev)

    def write(self, path: str) -> None:
        with self._lock:
            doc = {"traceEvents": list(self.events),
                   "displayTimeUnit": "ms"}
        with open(path, "w") as fh:
            json.dump(doc, fh)

    def by_name(self, name: str) -> List[Dict[str, Any]]:
        with self._lock:
            return [e for e in self.events if e["name"] == name]


def _arg(v: Any) -> Any:
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)
