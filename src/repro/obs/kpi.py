"""Live reliability KPIs computed from the fault journal.

MTTD, MTTR, redone work, availability, goodput, and SDC coverage — the
measured side of the paper's Section-7 predicted-vs-observed check.
`reconcile_with_advice` lines the measurements up against the temporal
model's `policy.advise` outputs (validate_lag bound, serve availability)
and reports per-metric pass/fail rows.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from .journal import payloads


def compute_kpis(records: Iterable[Dict[str, Any]], *,
                 steps: Optional[int] = None,
                 tokens: Optional[int] = None,
                 injected: Optional[int] = None,
                 wall_s: Optional[float] = None) -> Dict[str, Any]:
    """Reduce a journal to reliability KPIs.

    - ``mttd_steps``: mean detection latency in steps — for a deferred
      detection `detail["detected_at"] − step` (fault commit → flush that
      surfaced it), else 0 (caught at its own boundary).
    - ``mttr_s``: mean wall time from a detection line to the SDC recovery
      line that resolved it (journal `t_mono` deltas). Elastic remesh
      recoveries are excluded — they pair with heartbeat anomalies and
      report separately as ``elastic_mttr_s``.
    - ``redone_steps``: total steps re-executed by rollbacks
      (`record["at"] − record["step"]` summed over rollback recoveries).
    - ``availability``: 1 − redone/steps (useful-work fraction).
    - ``goodput_tokens_per_step``: tokens / steps when both known.
    - ``sdc_detected`` / ``sdc_coverage``: detections vs injected faults.
    """
    recs = list(records)
    det_lines = [r for r in recs if r.get("kind") == "detection"]
    rec_lines = [r for r in recs if r.get("kind") == "recovery"]
    dets = payloads(recs, "detection", "event")

    lags: List[float] = []
    for d in dets:
        detail = d.get("detail", {}) or {}
        lags.append(float(detail.get("detected_at", d["step"])) -
                    float(d["step"]))

    # Elastic remesh transitions (DESIGN.md §16) are node-loss recoveries,
    # not SDC recoveries: pairing one with an SDC detection line would both
    # corrupt MTTR (the remesh did not resolve that detection) and leave
    # the real recovery line unpaired. Split them out and pair them with
    # the heartbeat anomaly that triggered the transition instead.
    def _is_remesh(rl: Dict[str, Any]) -> bool:
        return (rl.get("record") or {}).get("kind") == "elastic_remesh"

    sdc_rec_lines = [r for r in rec_lines if not _is_remesh(r)]
    remesh_lines = [r for r in rec_lines if _is_remesh(r)]
    hb_lines = [r for r in recs if r.get("kind") == "heartbeat_anomaly"]

    # Pair each SDC recovery with the nearest preceding unclaimed detection.
    mttrs: List[float] = []
    free = list(det_lines)
    for rl in sdc_rec_lines:
        prior = [dl for dl in free if dl["seq"] < rl["seq"]]
        if prior:
            dl = prior[-1]
            free.remove(dl)
            mttrs.append(rl["t_mono"] - dl["t_mono"])

    # Elastic MTTR: stale-host heartbeat anomaly -> remesh completion.
    elastic_mttrs: List[float] = []
    free_hb = list(hb_lines)
    for rl in remesh_lines:
        prior = [h for h in free_hb if h["seq"] < rl["seq"]]
        if prior:
            h = prior[-1]
            free_hb.remove(h)
            elastic_mttrs.append(rl["t_mono"] - h["t_mono"])

    redone = 0
    rollbacks = 0
    corrected = 0
    remeshes = 0
    downtime_s = 0.0
    for r in payloads(recs, "recovery", "record"):
        rollbacks += int(r.get("rollbacks", 0) or 0)
        if r.get("at") is not None and r.get("step") is not None:
            redone += max(0, int(r["at"]) - int(r["step"]))
        if r.get("kind") in ("abft_correct", "vote_repair", "corrected"):
            corrected += 1
        if r.get("kind") == "elastic_remesh":
            # node-loss transitions (DESIGN.md §16): their `at - step` spans
            # already feed `redone` above (work discarded by re-anchoring);
            # the transition pauses themselves are a separate downtime axis
            remeshes += 1
            downtime_s += float(r.get("downtime_s", 0.0) or 0.0)
    # prefill-corrected events are repaired inline (no recovery record)
    corrected += sum(1 for d in dets
                     if d.get("effect") == "abft_corrected")

    out: Dict[str, Any] = {
        "detections": len(dets),
        "recoveries": len(rec_lines),
        "rollbacks": rollbacks,
        "corrected": corrected,
        "mttd_steps": (sum(lags) / len(lags)) if lags else 0.0,
        "mttd_max_steps": max(lags) if lags else 0.0,
        "mttr_s": (sum(mttrs) / len(mttrs)) if mttrs else 0.0,
        "redone_steps": redone,
    }
    if remeshes:
        out["elastic_remeshes"] = remeshes
        out["node_loss_downtime_s"] = downtime_s
        if elastic_mttrs:
            out["elastic_mttr_s"] = sum(elastic_mttrs) / len(elastic_mttrs)
    if steps:
        out["steps"] = int(steps)
        out["availability"] = max(0.0, 1.0 - redone / float(steps))
        if remeshes and wall_s:
            # node-loss downtime windows are wall time where NO useful work
            # happens at all — fold them in as an uptime factor on top of
            # the redone-work fraction
            out["availability"] *= max(0.0,
                                       1.0 - downtime_s / float(wall_s))
    if tokens is not None and steps:
        out["goodput_tokens_per_step"] = tokens / float(steps)
    if injected is not None:
        out["sdc_injected"] = int(injected)
        out["sdc_detected"] = len(dets)
        out["sdc_coverage"] = (len(dets) / float(injected)) if injected \
            else 1.0
    if wall_s is not None:
        out["wall_s"] = float(wall_s)
    return out


def reconcile_with_advice(kpis: Dict[str, Any], *,
                          advice: Any = None,
                          validate_lag: Optional[int] = None,
                          predicted_downtime_s: Optional[float] = None
                          ) -> List[Dict[str, Any]]:
    """Predicted-vs-observed rows. Hard bound checked here: every deferred
    detection must surface within the validation window
    (``mttd_max_steps ≤ validate_lag``). When a `policy.Advice` is given,
    its serve-availability prediction becomes a floor-with-slack check on
    the measured availability. `predicted_downtime_s` is the temporal
    model's fail-in-place transition estimate
    (`tm.remesh_overhead × transitions`, in seconds) checked against the
    measured node-loss downtime with a generous slack band — transition
    wall time is dominated by restore IO, which the model only scales."""
    rows: List[Dict[str, Any]] = []
    lag = validate_lag
    if lag is None and advice is not None:
        lag = getattr(advice, "serve_validate_lag", None) or \
            getattr(advice, "validate_lag", None)
    if lag is not None:
        rows.append({
            "metric": "mttd_max_steps",
            "predicted": f"<= {lag}",
            "observed": kpis.get("mttd_max_steps", 0.0),
            "ok": kpis.get("mttd_max_steps", 0.0) <= lag,
        })
    if advice is not None and kpis.get("availability") is not None:
        pred = getattr(advice, "serve_availability", None)
        if pred is not None:
            obs_v = kpis["availability"]
            rows.append({
                "metric": "availability",
                "predicted": pred,
                "observed": obs_v,
                # model is an expectation over the fault process; allow a
                # generous slack band rather than a point match
                "ok": obs_v >= pred - 0.25,
            })
    if predicted_downtime_s is not None and \
            kpis.get("node_loss_downtime_s") is not None:
        obs_dt = float(kpis["node_loss_downtime_s"])
        rows.append({
            "metric": "node_loss_downtime_s",
            "predicted": predicted_downtime_s,
            "observed": obs_dt,
            # the model predicts the expected transition overhead; real
            # transitions add compile + IO jitter, so check order of
            # magnitude, not a point value
            "ok": obs_dt <= 4.0 * float(predicted_downtime_s) + 5.0,
        })
    if "sdc_coverage" in kpis:
        rows.append({
            "metric": "sdc_coverage",
            "predicted": 1.0,
            "observed": kpis["sdc_coverage"],
            "ok": kpis["sdc_coverage"] >= 1.0,
        })
    return rows
