"""Structured alerts + SLO burn-rate windows (DESIGN.md §17).

:class:`AlertManager` converts anomaly-monitor firings and SLO burns into
:class:`Alert` records: deduplicated (a held-down condition re-alerts only
after ``min_interval_steps``), counted in the registry
(``sedar_alerts_total{name,severity}``) and journaled as ``alert`` lines
whose ``record`` payload reconstructs byte-for-byte via
``journal.reconcile(..., alerts=mgr.records)``.

:class:`SloTracker` implements the standard multi-window burn-rate rule:
an error budget (1 - target) is "burning" when BOTH a fast and a slow
sliding window exceed their burn-rate thresholds — the fast window makes
the alert responsive, the slow window keeps one bad sample from paging.
Targets come from `policy.advise` predictions (availability/goodput).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional


@dataclass(frozen=True)
class Alert:
    name: str                   # e.g. "step_time_drift", "slo_availability"
    severity: str               # "info" | "warning" | "critical"
    step: int
    message: str
    detail: Dict[str, Any] = field(default_factory=dict)

    def record(self) -> Dict[str, Any]:
        return {"name": self.name, "severity": self.severity,
                "step": int(self.step), "message": self.message,
                "detail": dict(self.detail)}


class AlertManager:
    """Dedup + journal + count. ``records`` mirrors every journaled alert
    payload in order, so reconcile() can verify the byte-for-byte
    round trip."""

    def __init__(self, min_interval_steps: int = 16):
        self.min_interval_steps = int(min_interval_steps)
        self.records: List[Dict[str, Any]] = []
        self._last_step: Dict[str, int] = {}

    def emit(self, alert: Alert) -> bool:
        """Returns True when the alert was actually emitted (not deduped)."""
        last = self._last_step.get(alert.name)
        if last is not None and \
                alert.step - last < self.min_interval_steps:
            return False
        self._last_step[alert.name] = alert.step
        from repro import obs
        from repro.obs.journal import _jsonable
        rec = _jsonable(alert.record())
        self.records.append(rec)
        obs.note_alert(rec)
        return True


class SloTracker:
    """Multi-window burn-rate tracking for one objective.

    ``update(step, good)`` feeds one sample of the objective (1.0 = fully
    meeting it, 0.0 = fully failing; fractional for goodput-style
    objectives) and returns an :class:`Alert` when both windows burn.
    Burn rate = (observed error rate) / (budget = 1 - target); the classic
    page rule is fast_burn ≈ 14 with a small fast window and slow_burn ≈ 2
    over a much longer one.
    """

    def __init__(self, name: str, target: float,
                 fast_window: int = 32, slow_window: int = 256,
                 fast_burn: float = 14.0, slow_burn: float = 2.0):
        self.name = name
        self.target = float(target)
        self.budget = max(1.0 - self.target, 1e-9)
        self.fast: Deque[float] = deque(maxlen=int(fast_window))
        self.slow: Deque[float] = deque(maxlen=int(slow_window))
        self.fast_burn = float(fast_burn)
        self.slow_burn = float(slow_burn)

    def _burn(self, window: Deque[float]) -> float:
        if not window:
            return 0.0
        err = sum(1.0 - g for g in window) / len(window)
        return err / self.budget

    def update(self, step: int, good: float) -> Optional[Alert]:
        good = min(max(float(good), 0.0), 1.0)
        self.fast.append(good)
        self.slow.append(good)
        fb, sb = self._burn(self.fast), self._burn(self.slow)
        if len(self.fast) == self.fast.maxlen and \
                fb >= self.fast_burn and sb >= self.slow_burn:
            return Alert(
                name=f"slo_{self.name}", severity="critical", step=step,
                message=(f"{self.name} SLO burning: fast burn {fb:.1f}x "
                         f"(>= {self.fast_burn:g}), slow burn {sb:.1f}x "
                         f"(>= {self.slow_burn:g}) against target "
                         f"{self.target:g}"),
                detail={"fast_burn": round(fb, 3), "slow_burn": round(sb, 3),
                        "target": self.target})
        return None
