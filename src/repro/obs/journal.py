"""Structured fault journal: one JSONL line per reliability event.

Every `DetectionEvent`, recovery record, tier fallback, heartbeat anomaly,
and request rejection becomes one append — monotonic timestamp, sequence
number, step, slot/request id, backend, boundary — so a completed run can
be REPLAYED: the scenario runner loads the journal and asserts
predicted-vs-observed the way the paper's Section-7 model does, and
`obs.kpi` computes MTTD/MTTR/availability from the same stream.

Canonical form: `canonical(obj)` is the byte-for-byte comparison contract
between the engine's in-memory records and their journaled copies. Both
sides pass through `_jsonable` (numpy scalars → Python scalars, dict keys →
str) before `json.dumps(sort_keys=True)`, so a record that survived a JSON
round trip compares equal to one that never left memory.

The journal is host-side only (list append + optional file write); it never
touches a device buffer — see the zero-extra-hostsync argument in
DESIGN.md §15.
"""
from __future__ import annotations

import atexit
import json
import os
import time
from typing import Any, Dict, Iterable, List, Optional

import numpy as np


def _jsonable(obj: Any) -> Any:
    """Normalize to what json.dumps emits and json.loads returns."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if obj is None or isinstance(obj, str):
        return obj
    return str(obj)


def canonical(obj: Any) -> bytes:
    """Canonical bytes of a record — the predicted-vs-observed comparator."""
    return json.dumps(_jsonable(obj), sort_keys=True).encode()


def event_to_record(event: Any) -> Dict[str, Any]:
    """Project a DetectionEvent onto its journal payload."""
    return {
        "step": event.step,
        "boundary": event.boundary,
        "effect": event.effect,
        "detail": dict(event.detail),
    }


class FaultJournal:
    """Append-only reliability event log (in-memory + optional JSONL file).

    Each record carries `kind`, a monotonic offset `t_mono` (seconds since
    the journal was opened) and a sequence number `seq`; everything else is
    caller fields. When `path` is given every append is streamed as one
    JSONL line (flushed, so a crashed run keeps its tail).

    Durability + bounded growth (DESIGN.md §17):

      * ``fsync_every=N`` forces the line to disk every N appends (0 =
        never fsync — the OS page cache decides). A kill -9 loses at most
        the last unsynced batch; ``synced_seq`` names the last sequence
        number guaranteed on disk.
      * an atexit hook flushes+fsyncs whatever is buffered on clean
        interpreter exit, so only a hard crash can drop the tail.
      * ``max_bytes=B`` rotates ``journal.jsonl`` → ``journal.jsonl.1``
        when the active file exceeds B (one generation — campaigns are
        bounded); ``load()`` reads across the rotation so ``reconcile()``
        still sees the whole stream.
    """

    def __init__(self, path: Optional[str] = None, *,
                 fsync_every: int = 0, max_bytes: int = 0):
        self.path = path
        self.entries: List[Dict[str, Any]] = []
        self.fsync_every = int(fsync_every)
        self.max_bytes = int(max_bytes)
        self.synced_seq = -1
        self._since_sync = 0
        self._t0 = time.monotonic()
        self._fh = open(path, "w") if path else None
        self._atexit = None
        if self._fh is not None:
            self._atexit = self.sync
            atexit.register(self._atexit)

    def append(self, kind: str, **fields) -> Dict[str, Any]:
        rec = {"kind": kind, "seq": len(self.entries),
               "t_mono": time.monotonic() - self._t0}
        rec.update(fields)
        rec = _jsonable(rec)
        self.entries.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
            self._fh.flush()
            self._since_sync += 1
            if self.fsync_every > 0 and self._since_sync >= self.fsync_every:
                self.sync()
            if self.max_bytes > 0 and self._fh.tell() >= self.max_bytes:
                self._rotate()
        return rec

    def sync(self) -> None:
        """Flush + fsync: everything appended so far is now on disk."""
        if self._fh is None:
            return
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self.synced_seq = len(self.entries) - 1
        self._since_sync = 0

    def _rotate(self) -> None:
        self.sync()
        self._fh.close()
        os.replace(self.path, self.path + ".1")
        self._fh = open(self.path, "w")

    def records(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        if kind is None:
            return list(self.entries)
        return [r for r in self.entries if r["kind"] == kind]

    def close(self) -> None:
        if self._fh is not None:
            self.sync()
            self._fh.close()
            self._fh = None
        if self._atexit is not None:
            atexit.unregister(self._atexit)
            self._atexit = None

    @staticmethod
    def load(path: str) -> List[Dict[str, Any]]:
        """Read a journal back, rotated generation first; a torn final
        line (crash mid-write) is skipped rather than raised."""
        out: List[Dict[str, Any]] = []
        for p in (path + ".1", path):
            if not os.path.exists(p):
                continue
            with open(p) as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        out.append(json.loads(line))
                    except json.JSONDecodeError:
                        continue
        return out


def payloads(records: Iterable[Dict[str, Any]], kind: str,
             field: str) -> List[Dict[str, Any]]:
    """Extract the embedded engine records of one kind (e.g. the
    `event`/`record` field of detection/recovery lines), journal framing
    stripped."""
    return [r[field] for r in records if r.get("kind") == kind]


def replay(records: Iterable[Dict[str, Any]]) -> Dict[str, List[Dict]]:
    """Group a loaded journal by kind — the scenario runner's view."""
    out: Dict[str, List[Dict]] = {}
    for r in records:
        out.setdefault(r.get("kind", "?"), []).append(r)
    return out


def reconcile(records: Iterable[Dict[str, Any]], detections: Iterable[Any],
              recoveries: Iterable[Dict[str, Any]],
              alerts: Optional[Iterable[Dict[str, Any]]] = None,
              reconfigs: Optional[Iterable[Dict[str, Any]]] = None,
              ) -> Dict[str, bool]:
    """Byte-for-byte check: does the journal reproduce the engine's
    detection/recovery sequences exactly? `detections` are DetectionEvents
    (projected via event_to_record); `recoveries` are the engine's record
    dicts. Passing `alerts` (AlertManager.records) and/or `reconfigs`
    (SedarEngine.reconfigs) extends the same contract to the PR-9 control
    loop — the corresponding `*_match` keys only appear when provided."""
    recs = list(records)
    j_det = [canonical(p) for p in payloads(recs, "detection", "event")]
    j_rec = [canonical(p) for p in payloads(recs, "recovery", "record")]
    e_det = [canonical(event_to_record(e)) for e in detections]
    e_rec = [canonical(r) for r in recoveries]
    out = {
        "detections_match": j_det == e_det,
        "recoveries_match": j_rec == e_rec,
    }
    if alerts is not None:
        j_al = [canonical(p) for p in payloads(recs, "alert", "record")]
        out["alerts_match"] = j_al == [canonical(a) for a in alerts]
    if reconfigs is not None:
        j_rc = [canonical(p) for p in payloads(recs, "reconfig", "record")]
        out["reconfigs_match"] = j_rc == [canonical(r) for r in reconfigs]
    return out
