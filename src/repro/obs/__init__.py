"""repro.obs — unified SEDAR telemetry (DESIGN.md §15).

Three surfaces behind one switchboard:

  * ``metrics`` — the process-wide :class:`MetricsRegistry`.
    ``enable_metrics()`` installs fan-in hooks into the three legacy
    counting shims (``hostsync._metrics_note``,
    ``prefill._metrics_note``, ``store._metrics_note``) so every
    transfer, compile and disk read lands in the registry with the same
    label the shim saw; engine/serve/checkpoint events arrive via the
    ``note_*`` functions below.
  * ``FaultJournal`` — ``set_journal()`` routes every DetectionEvent,
    recovery record, tier fallback, heartbeat anomaly and rejection into
    an append-only JSONL stream.
  * ``TraceRecorder`` — ``enable_trace()`` turns ``span(name)`` from a
    shared no-op context manager into a Chrome-trace complete event.

Contract: everything here is host-side bookkeeping on facts the engine
already read back — **telemetry never issues a device sync**, and with
everything disabled each instrumentation point costs one ``is None`` /
bool test (asserted by tests/test_observability_e2e.py via
``count_transfers`` and bounded by bench_observability.py).

This package never imports the engine/runtime modules (they import us),
so there are no cycles and `repro.obs` stays importable without jax.
"""
from __future__ import annotations

import os
import time
from contextlib import nullcontext
from typing import Any, Dict, Optional

from .alerts import Alert, AlertManager, SloTracker
from .anomaly import AnomalyMonitor, Cusum, EwmaBand, PageHinkley
from .estimator import CalibratedSnapshot, OnlineEstimator
from .journal import FaultJournal, canonical, event_to_record, payloads, \
    reconcile, replay
from .kpi import compute_kpis, reconcile_with_advice
from .registry import DEFAULT_BUCKETS, MetricsRegistry, parse_prometheus, \
    percentile
from .trace import TraceRecorder

__all__ = [
    "metrics", "percentile", "MetricsRegistry", "parse_prometheus",
    "DEFAULT_BUCKETS",
    "FaultJournal", "canonical", "event_to_record", "payloads", "replay",
    "reconcile", "compute_kpis", "reconcile_with_advice", "TraceRecorder",
    "OnlineEstimator", "CalibratedSnapshot",
    "AnomalyMonitor", "EwmaBand", "PageHinkley", "Cusum",
    "Alert", "AlertManager", "SloTracker",
    "enable_metrics", "disable_metrics", "metrics_enabled",
    "set_journal", "get_journal", "enable_trace", "disable_trace",
    "get_trace", "span", "configure", "shutdown",
    "note_detection", "note_recovery", "note_checkpoint",
    "note_tier_save", "note_tier_restore", "note_tier_event",
    "note_rejection", "note_heartbeat_anomaly", "note_tokens",
    "note_drain", "note_alert", "note_reconfig",
    "Observability",
]

metrics = MetricsRegistry()

_metrics_on = False
_journal: Optional[FaultJournal] = None
_trace: Optional[TraceRecorder] = None
_NULL_SPAN = nullcontext()


# --------------------------------------------------------------------------
# switchboard
# --------------------------------------------------------------------------

def _hostsync_hook(label: str, items: int) -> None:
    metrics.inc("hostsync_transfers_total", items, label=label)
    metrics.inc("hostsync_batches_total", 1, label=label)


def _compile_hook(key: Any) -> None:
    kind = key[0] if isinstance(key, tuple) and key else str(key)
    metrics.inc("prefill_compiles_total", 1, kind=str(kind))


def _disk_read_hook(label: str, items: int) -> None:
    metrics.inc("checkpoint_disk_reads_total", items, label=label)


def enable_metrics() -> None:
    """Turn the registry on and absorb the legacy counting shims."""
    global _metrics_on
    from repro.checkpoint import store
    from repro.core import hostsync
    from repro.runtime import prefill
    hostsync._metrics_note = _hostsync_hook
    prefill._metrics_note = _compile_hook
    store._metrics_note = _disk_read_hook
    _metrics_on = True


def disable_metrics() -> None:
    import sys
    global _metrics_on
    _metrics_on = False
    for modname in ("repro.core.hostsync", "repro.runtime.prefill",
                    "repro.checkpoint.store"):
        mod = sys.modules.get(modname)
        if mod is not None:
            mod._metrics_note = None


def metrics_enabled() -> bool:
    return _metrics_on


def set_journal(journal: Optional[FaultJournal]) -> Optional[FaultJournal]:
    global _journal
    prev, _journal = _journal, journal
    return prev


def get_journal() -> Optional[FaultJournal]:
    return _journal


def enable_trace() -> TraceRecorder:
    global _trace
    if _trace is None:
        _trace = TraceRecorder()
    return _trace


def disable_trace() -> None:
    global _trace
    _trace = None


def get_trace() -> Optional[TraceRecorder]:
    return _trace


class _MetricSpan:
    """Times the span body into the stage-duration histogram (host clock
    only — never a device sync), optionally wrapping a trace span."""

    __slots__ = ("name", "inner", "_t0")

    def __init__(self, name: str, inner=None):
        self.name = name
        self.inner = inner
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.monotonic()
        if self.inner is not None:
            self.inner.__enter__()
        return self

    def __exit__(self, *exc):
        if self.inner is not None:
            self.inner.__exit__(*exc)
        metrics.observe("sedar_stage_duration_seconds",
                        time.monotonic() - self._t0, stage=self.name)
        return False


def span(name: str, **args):
    """Stage span context manager: a Chrome-trace event when tracing is
    on, a stage-duration histogram sample when metrics are on (these are
    what the PR-9 estimator calibrates t_step/t_sync/tier costs from),
    and the shared no-op when both are off."""
    tr = _trace
    if tr is None and not _metrics_on:
        return _NULL_SPAN
    inner = tr.span(name, **args) if tr is not None else None
    if not _metrics_on:
        return inner
    return _MetricSpan(name, inner)


def shutdown() -> None:
    """Reset all global observability state (test teardown helper)."""
    global _journal, _trace
    disable_metrics()
    metrics.reset()
    if _journal is not None:
        _journal.close()
    _journal = None
    _trace = None


# --------------------------------------------------------------------------
# event intake — each guarded so the disabled path is a couple of branches
# --------------------------------------------------------------------------

def note_detection(event: Any) -> None:
    if _metrics_on:
        metrics.inc("sedar_detections_total",
                    boundary=event.boundary, effect=event.effect)
    if _journal is not None:
        _journal.append("detection", step=event.step,
                        event=event_to_record(event))


def note_recovery(record: Dict[str, Any]) -> None:
    if _metrics_on:
        kind = str(record.get("kind", "?"))
        metrics.inc("sedar_recoveries_total", kind=kind)
        rb = record.get("rollbacks", 0) or 0
        if rb:
            metrics.inc("sedar_rollbacks_total", rb)
        if kind == "retry":
            metrics.inc("sedar_retries_total")
    if _journal is not None:
        _journal.append("recovery", step=record.get("step"),
                        record=dict(record))


def note_checkpoint(step: int) -> None:
    if _metrics_on:
        metrics.inc("sedar_checkpoints_total")
    if _journal is not None:
        _journal.append("checkpoint", step=step)


def note_tier_save(tier: str, step: Optional[int] = None) -> None:
    if _metrics_on:
        metrics.inc("checkpoint_saves_total", tier=tier)


def note_tier_restore(tier: str, version: Optional[int] = None) -> None:
    if _metrics_on:
        metrics.inc("checkpoint_restores_total", tier=tier)
    if _journal is not None:
        _journal.append("tier_restore", tier=tier, version=version)


def note_tier_event(ev: Dict[str, Any]) -> None:
    """Tier fallback / corruption events from TieredCheckpointer."""
    if _metrics_on:
        metrics.inc("checkpoint_tier_fallbacks_total",
                    tier=str(ev.get("tier", "?")))
    if _journal is not None:
        fields = {k: v for k, v in ev.items() if k != "kind"}
        _journal.append("tier_fallback", **fields)


def note_rejection(step: int, rid: Any = None, slot: Optional[int] = None,
                   reason: str = "persistent_fault") -> None:
    if _metrics_on:
        metrics.inc("serve_rejections_total", reason=reason)
    if _journal is not None:
        _journal.append("rejection", step=step, rid=rid, slot=slot,
                        reason=reason)


def note_heartbeat_anomaly(host_id: int, gap_s: float,
                           kind: str = "stale") -> None:
    if _metrics_on:
        metrics.inc("cluster_heartbeat_anomalies_total", kind=kind)
    if _journal is not None:
        _journal.append("heartbeat_anomaly", host=int(host_id),
                        gap_s=float(gap_s), anomaly=kind)


def note_tokens(n: int) -> None:
    if _metrics_on and n:
        metrics.inc("serve_tokens_emitted_total", n)


def note_drain(rows: int) -> None:
    """One lag-aligned emission-ring drain batch (DESIGN.md §18)."""
    if _metrics_on:
        metrics.inc("serve_drain_batches_total")
        metrics.inc("serve_drained_rows_total", rows)


def note_alert(record: Dict[str, Any]) -> None:
    """Structured anomaly/SLO alert from the AlertManager (DESIGN.md §17)."""
    if _metrics_on:
        # label key is "alert", not "name" — the registry's positional
        # metric name would collide with a label literally called name
        metrics.inc("sedar_alerts_total",
                    alert=str(record.get("name", "?")),
                    severity=str(record.get("severity", "warning")))
    if _journal is not None:
        _journal.append("alert", step=record.get("step"),
                        record=dict(record))


def note_reconfig(record: Dict[str, Any]) -> None:
    """Autotuner knob transition applied by SedarEngine.apply_reconfig."""
    if _metrics_on:
        for knob in record.get("changes", {}):
            metrics.inc("sedar_reconfigs_total", knob=str(knob))
    if _journal is not None:
        _journal.append("reconfig", step=record.get("step"),
                        record=dict(record))


# --------------------------------------------------------------------------
# launcher-facing bundle
# --------------------------------------------------------------------------

class Observability:
    """What `--metrics-dir` / `--trace` turn on, and how it lands on disk.

    finalize() writes `metrics.prom` (Prometheus text snapshot) into the
    metrics dir and the Chrome trace to its path; the journal streamed to
    `<metrics_dir>/journal.jsonl` during the run is closed.
    """

    def __init__(self, metrics_dir: Optional[str] = None,
                 trace_path: Optional[str] = None):
        self.metrics_dir = metrics_dir
        self.trace_path = trace_path
        self.journal: Optional[FaultJournal] = None
        self._t0 = time.monotonic()
        if metrics_dir:
            os.makedirs(metrics_dir, exist_ok=True)
            enable_metrics()
            self.journal = FaultJournal(
                os.path.join(metrics_dir, "journal.jsonl"))
            set_journal(self.journal)
        if trace_path:
            enable_trace()

    def kpis(self, **kw) -> Dict[str, Any]:
        recs = self.journal.records() if self.journal else []
        return compute_kpis(recs, wall_s=time.monotonic() - self._t0, **kw)

    def finalize(self) -> Optional[str]:
        """Flush everything; returns the Prometheus snapshot text (also
        written to metrics.prom) when metrics were on."""
        snap = None
        if self.metrics_dir:
            snap = metrics.render_prometheus()
            with open(os.path.join(self.metrics_dir, "metrics.prom"),
                      "w") as fh:
                fh.write(snap)
        if self.trace_path and _trace is not None:
            _trace.write(self.trace_path)
        if self.journal is not None:
            self.journal.close()
            set_journal(None)
        return snap


def configure(metrics_dir: Optional[str] = None,
              trace: Optional[str] = None) -> Observability:
    """One-call launcher setup: returns the bundle to finalize() at exit."""
    return Observability(metrics_dir=metrics_dir, trace_path=trace)
