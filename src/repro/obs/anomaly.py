"""Streaming drift/anomaly detectors over the telemetry streams.

Three classical detectors, each O(1) state per stream (DESIGN.md §17):

  * :class:`EwmaBand` — point anomalies: flag |x - ewma| > k·std after a
    warmup. Catches step-time spikes (straggler onset) and checkpoint-cost
    outliers.
  * :class:`PageHinkley` — sustained mean shift in one direction; the
    standard change-point test for "the step time has drifted up and
    stayed there".
  * :class:`Cusum` — two-sided cumulative-sum test; catches slower drifts
    than the band and recovers automatically after reset.

:class:`AnomalyMonitor` owns one detector set per named stream and turns
raw samples into structured anomaly dicts the AlertManager converts to
journaled alerts. Pure Python, importable without jax.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional


class EwmaBand:
    """EWMA mean/variance band: anomaly when |x - mean| > k * std."""

    def __init__(self, alpha: float = 0.2, k: float = 4.0,
                 warmup: int = 8):
        self.alpha = float(alpha)
        self.k = float(k)
        self.warmup = int(warmup)
        self.mean: Optional[float] = None
        self.var = 0.0
        self.n = 0

    def update(self, x: float) -> bool:
        x = float(x)
        self.n += 1
        if self.mean is None:
            self.mean = x
            return False
        dev = x - self.mean
        std = math.sqrt(self.var)
        anomalous = (self.n > self.warmup and std > 0.0
                     and abs(dev) > self.k * std)
        if not anomalous:
            # anomalies are excluded from the estimate so a spike does not
            # widen its own band
            self.mean += self.alpha * dev
            self.var = (1 - self.alpha) * (self.var + self.alpha * dev * dev)
        return anomalous


class PageHinkley:
    """One-sided Page-Hinkley mean-shift test (upward by default)."""

    def __init__(self, delta: float = 0.005, threshold: float = 0.5,
                 direction: int = +1):
        self.delta = float(delta)
        self.threshold = float(threshold)
        self.direction = 1 if direction >= 0 else -1
        self.mean = 0.0
        self.n = 0
        self.cum = 0.0
        self.cum_min = 0.0

    def update(self, x: float) -> bool:
        x = float(x) * self.direction
        self.n += 1
        self.mean += (x - self.mean) / self.n
        self.cum += x - self.mean - self.delta
        self.cum_min = min(self.cum_min, self.cum)
        if self.cum - self.cum_min > self.threshold:
            self.reset()
            return True
        return False

    def reset(self) -> None:
        self.mean = 0.0
        self.n = 0
        self.cum = 0.0
        self.cum_min = 0.0


class Cusum:
    """Two-sided CUSUM around a reference mean (first `warmup` samples)."""

    def __init__(self, k: float = 0.5, h: float = 5.0, warmup: int = 8):
        self.k = float(k)          # slack, in reference-std units
        self.h = float(h)          # decision threshold, in std units
        self.warmup = int(warmup)
        self._ref: List[float] = []
        self.mean = 0.0
        self.std = 0.0
        self.pos = 0.0
        self.neg = 0.0

    def update(self, x: float) -> bool:
        x = float(x)
        if len(self._ref) < self.warmup:
            self._ref.append(x)
            if len(self._ref) == self.warmup:
                self.mean = sum(self._ref) / len(self._ref)
                var = (sum((v - self.mean) ** 2 for v in self._ref)
                       / max(len(self._ref) - 1, 1))
                self.std = math.sqrt(var) or abs(self.mean) * 0.05 or 1e-9
            return False
        z = (x - self.mean) / self.std
        self.pos = max(0.0, self.pos + z - self.k)
        self.neg = max(0.0, self.neg - z - self.k)
        if self.pos > self.h or self.neg > self.h:
            self.pos = self.neg = 0.0
            return True
        return False


class AnomalyMonitor:
    """Named streams, each watched by a band + a change-point detector.

    ``update(stream, value)`` returns the (possibly empty) list of anomaly
    dicts fired by this sample: ``{"stream", "detector", "value"}``.
    Streams are created lazily with shared default thresholds; tune one
    with ``configure(stream, ...)`` before its first sample.
    """

    def __init__(self):
        self._bands: Dict[str, EwmaBand] = {}
        self._cusums: Dict[str, Cusum] = {}
        self._cfg: Dict[str, dict] = {}
        self.fired: List[dict] = []

    def configure(self, stream: str, *, band_k: float = 4.0,
                  cusum_k: float = 0.5, cusum_h: float = 5.0,
                  warmup: int = 8) -> None:
        self._cfg[stream] = dict(band_k=band_k, cusum_k=cusum_k,
                                 cusum_h=cusum_h, warmup=warmup)

    def _ensure(self, stream: str) -> None:
        if stream in self._bands:
            return
        cfg = self._cfg.get(stream, {})
        self._bands[stream] = EwmaBand(
            k=cfg.get("band_k", 4.0), warmup=cfg.get("warmup", 8))
        self._cusums[stream] = Cusum(
            k=cfg.get("cusum_k", 0.5), h=cfg.get("cusum_h", 5.0),
            warmup=cfg.get("warmup", 8))

    def update(self, stream: str, value: float) -> List[dict]:
        self._ensure(stream)
        out = []
        if self._bands[stream].update(value):
            out.append({"stream": stream, "detector": "ewma_band",
                        "value": float(value)})
        if self._cusums[stream].update(value):
            out.append({"stream": stream, "detector": "cusum",
                        "value": float(value)})
        self.fired.extend(out)
        return out
