"""Tiered checkpoint hierarchy — device / host / disk / partner (DESIGN.md §12).

The paper's "different Levels of Checkpointing" (L2/L3) say WHAT a
checkpoint means; this module adds WHERE it lives. Aupy et al.
(arXiv:1310.8486) show the optimal silent-error strategy couples the
verification cadence with a *hierarchy* of checkpoint costs — so the
hierarchy is:

  Tier 0  `device`   on-device snapshot ring: pure `jnp.copy` per leaf, no
                     D2H, no serialization. Rollback is instant and performs
                     ZERO disk reads and ZERO host syncs. Survives nothing
                     but the process (an SDC in the step, the common case).
  Tier 1  `host`     host-RAM ring: ONE batched D2H per save (hostsync),
                     no serialization. Survives device-state loss.
  Tier 2  `disk`     the async atomic `CheckpointStore` (optionally
                     `DeltaCheckpointStore` / compressed). Survives process
                     death.
  Tier 3  `partner`  a second directory with independently computed
                     digests — the fallback when a Tier-2 restore raises
                     `CheckpointCorruptionError`. Survives single-store
                     corruption (bit rot, torn volumes).

`TieredCheckpointer` is the single facade: per-tier save cadences
(`TierSchedule`), one shared D2H transfer feeding every durable tier, and a
cost-aware restore planner (`plan` / `restore`) that picks the cheapest
tier holding a valid version at-or-below the caller's bound, falling back
tier-by-tier (and then version-by-version) on corruption — recorded as
events, never silently.

Ring tiers intentionally hold versions INSIDE the deferred-validation
window (they are disposable; the planner's `max_step` bound filters them),
while the durable tiers keep the §11 invariant of only being cut after a
clean flush. Ring eviction honors the same `keep_floor` anchor as
`CheckpointStore.gc_keep_last`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.store import CheckpointCorruptionError, CheckpointStore

TIER_ORDER = ("device", "host", "disk", "partner")

# Relative restore-cost weights for the planner (unitless; only ratios
# matter). A device slot is a few on-device copies; host pays one H2D
# upload; disk pays deserialization + digest verification; partner is disk
# plus being the last line of defense. `rework_weight` prices one step of
# lost progress — so a ring slot `k` steps older than a disk version wins
# until the rework gap outgrows the deserialization saving. Callers can
# override with measured costs (benchmarks/bench_checkpoint.py measures
# them; temporal_model.TierCosts models them in hours).
DEFAULT_RESTORE_COSTS = {"device": 1.0, "host": 4.0,
                         "disk": 64.0, "partner": 96.0}
DEFAULT_REWORK_WEIGHT = 1.0


@dataclass(frozen=True)
class TierSchedule:
    """Per-tier save cadence in steps; 0 disables the tier."""

    device: int = 0
    host: int = 0
    disk: int = 0
    partner: int = 0

    def interval(self, tier: str) -> int:
        return int(getattr(self, tier))

    def tier_due(self, tier: str, step: int) -> bool:
        iv = self.interval(tier)
        return iv > 0 and step > 0 and step % iv == 0

    def enabled(self) -> Tuple[str, ...]:
        return tuple(t for t in TIER_ORDER if self.interval(t) > 0)


class _Ring:
    """Bounded newest-last version ring shared by the device/host tiers.

    Eviction honors `keep_floor` exactly like `gc_keep_last`: the newest
    slot at-or-below the floor (the last version older than every
    unvalidated step) is pinned, so a deferred-window fault always finds an
    in-ring rollback target even after the ring rotates past it."""

    def __init__(self, slots: int):
        self.slots = max(int(slots), 1)
        self._ring: List[Tuple[int, Any]] = []

    def _put(self, step: int, payload, keep_floor: Optional[int]) -> None:
        self._ring = [e for e in self._ring if e[0] != step]
        self._ring.append((step, payload))
        self._ring.sort(key=lambda e: e[0])
        while len(self._ring) > self.slots:
            anchored = [s for s, _ in self._ring
                        if keep_floor is not None and s <= keep_floor]
            anchor = max(anchored) if anchored else None
            victim = next((i for i, (s, _) in enumerate(self._ring)
                           if s != anchor), None)
            if victim is None:
                break
            del self._ring[victim]

    def _get(self, step: int):
        for s, payload in self._ring:
            if s == step:
                return payload
        raise KeyError(f"version {step} not in ring")

    def versions(self) -> List[int]:
        return [s for s, _ in self._ring]

    def has(self, step: int) -> bool:
        return any(s == step for s, _ in self._ring)

    def keep_only(self, step: int) -> None:
        self._ring = [e for e in self._ring if e[0] == step]

    def clear(self) -> None:
        self._ring = []


class DeviceRing(_Ring):
    """Tier 0: on-device snapshot ring. Saves and restores are pure
    device-side copies — the snapshot must be copied both ways because the
    live state's buffers may be DONATED by the next step (and a restored
    state's buffers likewise; the ring keeps its own)."""

    name = "device"

    def save(self, step: int, state,
             keep_floor: Optional[int] = None) -> None:
        self._put(step, jax.tree.map(jnp.copy, state), keep_floor)

    def restore(self, step: int):
        return jax.tree.map(jnp.copy, self._get(step))


class SlotRing:
    """Tier-0 KEYED snapshot ring for continuous-batching serving
    (DESIGN.md §13): one bounded device-resident version ring PER SEQUENCE
    SLOT, holding that slot's {cache slice, token, position} image.

    Same storage contract as `DeviceRing` — saves and restores are pure
    `jnp.copy`, ZERO disk reads and ZERO host syncs — but keyed by slot so
    a detected fault restores ONLY the affected sequence's state while the
    other slots' rings (and live state) are untouched. Versions are decode
    ticks; `restore(slot, max_step=k)` returns the newest snapshot at or
    below the faulty step, exactly like the planner's `max_step` bound
    filters post-fault versions out of recovery. Eviction on admission
    (`evict`) drops a finished/rejected request's history so the ring never
    resurrects state across requests sharing a slot."""

    name = "device"

    def __init__(self, slots_per_key: int = 4):
        self.slots_per_key = max(int(slots_per_key), 1)
        self._rings: Dict[int, _Ring] = {}
        self.saves = 0
        self.restores = 0

    def save(self, key: int, step: int, state_slice) -> None:
        ring = self._rings.setdefault(int(key), _Ring(self.slots_per_key))
        ring._put(step, jax.tree.map(jnp.copy, state_slice), keep_floor=None)
        self.saves += 1

    def save_many(self, step: int, slices: "Dict[int, Any]") -> None:
        """Batched snapshots at one shared version: a whole prefill pack's
        slot slices at admission (DESIGN.md §14), or every live slot at a
        clean flush edge under lag-aligned drain (DESIGN.md §18 — flush
        edges are the only points where the optimistic window is fully
        validated, so drain-mode versions always land there). The copies
        are issued together before any is awaited — still pure `jnp.copy`,
        zero disk, zero host syncs."""
        for key, sl in slices.items():
            self.save(key, step, sl)

    def newest_version(self, key: int) -> Optional[int]:
        """Newest recorded version for `key` (None when the slot has no
        history) — the version restore() would pick with no `max_step`
        bound, without paying its copy. Under lag-aligned drain every
        version is a clean flush edge, so this is also the slot's newest
        fully-validated point."""
        versions = self.versions(key)
        return max(versions) if versions else None

    def restore(self, key: int, max_step: Optional[int] = None
                ) -> Tuple[int, Any]:
        """Newest version at-or-below `max_step` for `key` ->
        (version, state slice copy). KeyError when nothing qualifies."""
        ring = self._rings.get(int(key))
        if ring is None:
            raise KeyError(f"no snapshots for slot {key}")
        cands = [s for s in ring.versions()
                 if max_step is None or s <= max_step]
        if not cands:
            raise KeyError(f"no slot-{key} snapshot at or below {max_step}")
        version = max(cands)
        self.restores += 1
        return version, jax.tree.map(jnp.copy, ring._get(version))

    def versions(self, key: int) -> List[int]:
        ring = self._rings.get(int(key))
        return ring.versions() if ring is not None else []

    def evict(self, key: int) -> None:
        self._rings.pop(int(key), None)

    def clear(self) -> None:
        self._rings.clear()


class HostRing(_Ring):
    """Tier 1: host-RAM ring. One batched D2H per save (counted through
    hostsync as `tier_host_save` unless the transfer is shared with the
    durable tiers); restore re-uploads without touching disk."""

    name = "host"

    def save(self, step: int, host_leaves: List[np.ndarray], treedef,
             keep_floor: Optional[int] = None) -> None:
        self._put(step, (list(host_leaves), treedef), keep_floor)

    def restore(self, step: int, template=None):
        leaves, treedef = self._get(step)
        if template is not None:
            tleaves = jax.tree_util.tree_flatten(template)[0]
            if len(tleaves) != len(leaves):
                raise ValueError(
                    f"host ring version {step} has {len(leaves)} leaves, "
                    f"template has {len(tleaves)}")
        return jax.tree_util.tree_unflatten(treedef, leaves)


class TieredCheckpointer:
    """Facade over the tier hierarchy: cadence-routed saves, one shared D2H
    batch for all durable tiers, cost-aware restore planning with
    corruption fallback, per-tier accounting."""

    def __init__(self, schedule: TierSchedule, *,
                 device_slots: int = 4, host_slots: int = 4,
                 disk_store: Optional[CheckpointStore] = None,
                 partner_store: Optional[CheckpointStore] = None,
                 restore_costs: Optional[Dict[str, float]] = None,
                 rework_weight: float = DEFAULT_REWORK_WEIGHT,
                 notify: Optional[Callable[[dict], None]] = None):
        if schedule.interval("disk") > 0 and disk_store is None:
            raise ValueError("disk tier scheduled but no disk_store given")
        if schedule.interval("partner") > 0 and partner_store is None:
            raise ValueError("partner tier scheduled but no partner_store")
        self.schedule = schedule
        self.device = DeviceRing(device_slots) \
            if schedule.interval("device") > 0 else None
        self.host = HostRing(host_slots) \
            if schedule.interval("host") > 0 else None
        self.disk = disk_store
        self.partner = partner_store
        self.restore_costs = dict(DEFAULT_RESTORE_COSTS)
        if restore_costs:
            self.restore_costs.update(restore_costs)
        self.rework_weight = float(rework_weight)
        self.notify = notify or (lambda e: None)
        self.events: List[Dict[str, Any]] = []
        self.saves_by_tier: Dict[str, int] = {}
        self.restores_by_tier: Dict[str, int] = {}

    # -- cadence ---------------------------------------------------------------

    def due(self, step: int) -> bool:
        return any(self.schedule.tier_due(t, step)
                   for t in self.schedule.enabled())

    def sync_due(self, step: int) -> bool:
        """True when a tier that pays a D2H transfer is due (host/disk/
        partner) — the engine forces a deferred-ring flush first so every
        durable version predates every unvalidated step."""
        return any(self.schedule.tier_due(t, step)
                   for t in ("host", "disk", "partner"))

    def fp_needed(self, step: int) -> bool:
        """Whether the engine should pay the state-fingerprint readback for
        this save: only the serialized tiers record it in a manifest."""
        return any(self.schedule.tier_due(t, step)
                   for t in ("disk", "partner"))

    # -- save ------------------------------------------------------------------

    def save(self, step: int, state, *, fingerprint=None,
             valid: Optional[bool] = None, kind: str = "system",
             async_: bool = True, keep_floor: Optional[int] = None,
             force: bool = False) -> List[str]:
        """Route one version into every due tier. Returns the tiers saved.

        One batched D2H transfer feeds host + disk + partner together;
        the device tier never leaves the accelerator. `force=True` hits
        every enabled tier regardless of cadence (the L3 validated-
        checkpoint boundary replicates into all tiers at once)."""
        saved: List[str] = []

        def _due(tier: str) -> bool:
            iv = self.schedule.interval(tier)
            return iv > 0 and (force or self.schedule.tier_due(tier, step))

        if self.device is not None and _due("device"):
            with obs.span("checkpoint_tier", tier="device", step=step):
                self.device.save(step, state, keep_floor)
            saved.append("device")

        host_due = self.host is not None and _due("host")
        disk_due = self.disk is not None and _due("disk")
        partner_due = self.partner is not None and _due("partner")
        if host_due or disk_due or partner_due:
            from repro.core import hostsync   # lazy: see store.py note
            leaves, treedef = jax.tree_util.tree_flatten(state)
            host_leaves = hostsync.batched_get(leaves,
                                               label="checkpoint_save")
            if host_due:
                with obs.span("checkpoint_tier", tier="host", step=step):
                    self.host.save(step, host_leaves, treedef, keep_floor)
                saved.append("host")
            if disk_due:
                with obs.span("checkpoint_tier", tier="disk", step=step):
                    self.disk.save(step, state, kind=kind, valid=valid,
                                   fingerprint=fingerprint, async_=async_,
                                   host_leaves=host_leaves)
                saved.append("disk")
            if partner_due:
                # independent manifest + digests: partner._write recomputes
                # them from the same host buffers
                with obs.span("checkpoint_tier", tier="partner", step=step):
                    self.partner.save(step, state, kind=kind, valid=valid,
                                      fingerprint=fingerprint, async_=async_,
                                      host_leaves=host_leaves)
                saved.append("partner")
        for t in saved:
            self.saves_by_tier[t] = self.saves_by_tier.get(t, 0) + 1
            obs.note_tier_save(t, step)
        return saved

    # -- version queries -------------------------------------------------------

    def _tier_versions(self, tier: str) -> List[int]:
        obj = getattr(self, tier, None)
        if obj is None:
            return []
        if tier in ("device", "host"):
            return obj.versions()
        return obj.steps()

    def versions(self) -> List[int]:
        out = set()
        for t in TIER_ORDER:
            out.update(self._tier_versions(t))
        return sorted(out)

    def tiers_with(self, version: int) -> List[str]:
        return [t for t in TIER_ORDER if version in self._tier_versions(t)]

    def latest_valid(self) -> Optional[int]:
        """Newest validated version across tiers (L3). Ring tiers only ever
        receive validated states under L3, so their slots count; disk
        tiers consult the manifest's valid flag."""
        cands: List[int] = []
        for t in ("device", "host"):
            cands.extend(self._tier_versions(t))
        for store in (self.disk, self.partner):
            if store is not None:
                v = store.latest(valid_only=True)
                if v is not None:
                    cands.append(v)
        return max(cands) if cands else None

    # -- restore planner -------------------------------------------------------

    def plan(self, version: Optional[int] = None,
             max_step: Optional[int] = None) -> List[Tuple[str, int]]:
        """Ordered restore candidates, cheapest first.

        With `version`: every tier holding exactly that version (tier cost
        order), then — as corruption fallbacks — every (tier, older
        version) candidate ranked by `restore_cost + rework_weight *
        (version - v)`. With only `max_step`: the full cost-ranked list of
        candidates at-or-below the bound (L3 restore, generic callers)."""
        ref = version if version is not None else max_step

        def cost(tier: str, v: int) -> float:
            c = self.restore_costs.get(tier, max(self.restore_costs.values()))
            if ref is not None:
                c += self.rework_weight * max(ref - v, 0)
            return c

        exact: List[Tuple[str, int]] = []
        older: List[Tuple[str, int]] = []
        for t in TIER_ORDER:
            for v in self._tier_versions(t):
                if max_step is not None and v > max_step:
                    continue
                if version is not None:
                    if v == version:
                        exact.append((t, v))
                    elif v < version:
                        older.append((t, v))
                else:
                    older.append((t, v))
        exact.sort(key=lambda tv: cost(*tv))
        older.sort(key=lambda tv: cost(*tv))
        return exact + older

    def _restore_from(self, tier: str, version: int, template):
        if tier == "device":
            return self.device.restore(version)
        if tier == "host":
            return self.host.restore(version, template)
        store = self.disk if tier == "disk" else self.partner
        return store.restore(version, template)

    def restore(self, version: Optional[int], template, *,
                max_step: Optional[int] = None
                ) -> Tuple[Any, Dict[str, Any]]:
        """Restore `version` (or the planner's best candidate <= `max_step`
        when version is None) from the cheapest tier holding it.

        A tier that fails — `CheckpointCorruptionError` from a digest
        mismatch, or a structurally unusable payload — is recorded as a
        `tier_fallback` event and the next candidate is tried; the caller
        sees a recovery event, not an exception, unless EVERY candidate is
        exhausted. Returns (state, info) where info carries the winning
        tier/version plus any fallbacks for the engine's recovery record."""
        with obs.span("restore_plan", version=version, max_step=max_step):
            candidates = self.plan(version=version, max_step=max_step)
        if not candidates:
            raise KeyError(
                f"no restorable version (requested {version}, "
                f"max_step {max_step})")
        fallbacks: List[Dict[str, Any]] = []
        last_err: Optional[Exception] = None
        for tier, v in candidates:
            try:
                with obs.span("restore", tier=tier, version=v):
                    state = self._restore_from(tier, v, template)
            except (CheckpointCorruptionError, FileNotFoundError, KeyError,
                    ValueError, OSError) as e:
                ev = {"kind": "tier_fallback", "tier": tier, "version": v,
                      "error": f"{type(e).__name__}: {e}"}
                fallbacks.append(ev)
                self.events.append(ev)
                obs.note_tier_event(ev)
                self.notify(ev)
                last_err = e
                continue
            self.restores_by_tier[tier] = \
                self.restores_by_tier.get(tier, 0) + 1
            obs.note_tier_restore(tier, v)
            info: Dict[str, Any] = {"tier": tier, "version": v}
            if fallbacks:
                info["fallbacks"] = fallbacks
            return state, info
        raise CheckpointCorruptionError(
            f"every tier failed restoring version {version}: "
            f"{fallbacks}") from last_err

    # -- retention -------------------------------------------------------------

    def keep_only(self, step: int) -> None:
        """L3's 'exactly one valid checkpoint' — enforced PER TIER."""
        for ring in (self.device, self.host):
            if ring is not None:
                ring.keep_only(step)
        for store in (self.disk, self.partner):
            if store is not None:
                store.delete_others_than(step)

    def gc_keep_last(self, n: int, keep_floor: Optional[int] = None) -> None:
        """Bounded-chain GC for the durable tiers (rings self-bound)."""
        for store in (self.disk, self.partner):
            if store is not None:
                store.gc_keep_last(n, keep_floor=keep_floor)

    def wait(self) -> None:
        """Durability barrier across every disk-backed tier."""
        for store in (self.disk, self.partner):
            if store is not None:
                store.wait()

    def drop_volatile(self) -> None:
        """Node loss (DESIGN.md §16): the device and host rings live in the
        failed topology's memory and do not survive a remesh — drop them so
        the restore planner can only be served by the durable tiers (disk /
        partner). The durable stores are untouched."""
        for ring in (self.device, self.host):
            if ring is not None:
                ring.clear()

    def clear(self) -> None:
        for ring in (self.device, self.host):
            if ring is not None:
                ring.clear()
        for store in (self.disk, self.partner):
            if store is not None:
                store.clear()


# ---------------------------------------------------------------------------
# Config-driven construction (the make_recovery entry point)
# ---------------------------------------------------------------------------

def parse_tiers(spec: str) -> Tuple[str, ...]:
    names = tuple(t.strip() for t in str(spec).split(",") if t.strip())
    bad = [t for t in names if t not in TIER_ORDER]
    if bad:
        raise ValueError(f"unknown checkpoint tier(s) {bad}; "
                         f"valid: {TIER_ORDER}")
    return names or ("disk",)


def make_tiered(sedar_cfg, directory: str,
                disk_store: Optional[CheckpointStore] = None,
                notify: Optional[Callable[[dict], None]] = None
                ) -> Optional[TieredCheckpointer]:
    """Build a `TieredCheckpointer` from a SedarConfig, or None when the
    config names only the classic flat disk store (backward compatible).

    Cadences: device defaults to EVERY step (`device_ckpt_interval`), host
    and partner default to the disk cadence (`checkpoint_interval`); the
    partner directory sits next to the primary with its own manifests."""
    import os

    names = parse_tiers(getattr(sedar_cfg, "ckpt_tiers", "disk"))
    if names == ("disk",):
        return None
    iv = int(sedar_cfg.checkpoint_interval)
    sched = TierSchedule(
        device=(int(getattr(sedar_cfg, "device_ckpt_interval", 1)) or 1)
        if "device" in names else 0,
        host=(int(getattr(sedar_cfg, "host_ckpt_interval", 0)) or iv)
        if "host" in names else 0,
        disk=iv if "disk" in names else 0,
        partner=(int(getattr(sedar_cfg, "partner_ckpt_interval", 0)) or iv)
        if "partner" in names else 0)
    partner_store = None
    if "partner" in names:
        partner_store = CheckpointStore(
            os.path.join(directory, "checkpoints_partner"),
            compress=bool(getattr(sedar_cfg, "ckpt_compress", False)))
    return TieredCheckpointer(
        sched,
        device_slots=int(getattr(sedar_cfg, "device_ring_slots", 4)),
        host_slots=int(getattr(sedar_cfg, "host_ring_slots", 4)),
        disk_store=disk_store if "disk" in names else None,
        partner_store=partner_store, notify=notify)
