"""Multi-version atomic checkpoint store.

Layout:
    <dir>/ckpt_00001234/           one version per step
        manifest.json              step, kind, valid flag, fingerprint, leaf meta
        leaf_00000.npy ...         one npy per pytree leaf (tree_flatten order)
    <dir>/ckpt_00001234.tmp/       staging dir (renamed atomically on commit)

Properties required by the paper's recovery algorithms:
  * L2 (multiple system-level checkpoints): versions are NEVER garbage
    collected implicitly — any checkpoint may be the only clean one
    (paper Sec. 3.2: "none of the checkpoints can be erased").
  * L3 (single validated checkpoint): `save(..., valid=True)` +
    `delete_others_than(step)` implements "exactly one valid checkpoint".
  * restart scripts: the manifest is self-describing; `latest()/restore()`
    reconstruct the state against a caller-supplied pytree template.
  * async mode: the device->host copy happens synchronously (cheap, and the
    on-device buffers may be donated right after), serialization + fsync +
    rename run on a background thread — compute/checkpoint overlap.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np


@dataclass
class Manifest:
    step: int
    kind: str = "system"            # system | app
    valid: Optional[bool] = None    # None = unknown (L2); True = validated (L3)
    fingerprint: Optional[List[List[int]]] = None
    n_leaves: int = 0
    extra: Dict[str, Any] = field(default_factory=dict)
    # Per-leaf content digests of the bytes actually written, computed by the
    # store itself at save time and re-checked by restore(). (The engine's
    # `fingerprint` field above covers replica 0's params/opt at its own
    # granularity — it is NOT leaf-comparable against the stored payload,
    # which for L2 is the full dual state.)
    leaf_digests: Optional[List[List[int]]] = None

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "Manifest":
        return Manifest(**json.loads(s))


class CheckpointCorruptionError(RuntimeError):
    """A restored leaf does not match its save-time digest: the on-disk
    payload was corrupted after the atomic commit. L2/L3's 'valid
    checkpoint' guarantee requires failing loudly here — silently restoring
    a corrupted state would re-seed every replica from it."""


def _leaf_digest(arr: np.ndarray) -> List[int]:
    """Order-sensitive 64-bit digest of a leaf's raw bytes (the same mixing
    constants as core.fingerprint, numpy-only so restore verification works
    without touching a device)."""
    b = arr.tobytes()
    u = np.frombuffer(b + b"\0" * ((-len(b)) % 4), np.uint32)
    idx = np.arange(u.size, dtype=np.uint32)
    h1 = int(((u ^ (idx * np.uint32(2654435761))) *
              np.uint32(2246822519)).sum(dtype=np.uint32))
    t = (u + idx) * np.uint32(3266489917)
    h2 = int((t ^ (t >> np.uint32(15))).sum(dtype=np.uint32))
    return [h1, h2]


def _ckpt_name(step: int) -> str:
    return f"ckpt_{step:08d}"


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._pending: List[threading.Thread] = []
        self._lock = threading.Lock()

    # -- write ------------------------------------------------------------------

    def save(self, step: int, state, *, kind: str = "system",
             valid: Optional[bool] = None, fingerprint=None,
             async_: bool = False, extra: Optional[dict] = None) -> None:
        """Snapshot `state` (pytree of arrays) as version `step`.

        The device->host copy is ONE transfer batch: non-blocking
        `copy_to_host_async` starts every leaf's DMA concurrently, then a
        single batched `jax.device_get` of the whole leaf list awaits them
        (vs the old per-leaf loop: one blocking round-trip per leaf). The
        copy completes on the calling thread — before the caller's next
        step may DONATE the very buffers being snapshotted — and only
        serialization + fsync + rename run on the background writer."""
        # function-level import: repro.core.recovery imports this module, so
        # a module-level `from repro.core import hostsync` would make
        # `import repro.checkpoint` circular in a fresh interpreter
        from repro.core import hostsync
        leaves = jax.tree_util.tree_flatten(state)[0]
        host_leaves = hostsync.batched_get(leaves, label="checkpoint_save")
        man = Manifest(step=step, kind=kind, valid=valid,
                       fingerprint=None if fingerprint is None
                       else np.asarray(fingerprint).astype(np.int64).tolist(),
                       n_leaves=len(host_leaves), extra=extra or {})

        if async_:
            t = threading.Thread(target=self._write, args=(step, host_leaves, man),
                                 daemon=True)
            with self._lock:
                self._pending.append(t)
            t.start()
        else:
            self._write(step, host_leaves, man)

    def _write(self, step: int, host_leaves, man: Manifest) -> None:
        final = os.path.join(self.dir, _ckpt_name(step))
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        man.leaf_digests = [_leaf_digest(arr) for arr in host_leaves]
        for i, arr in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            f.write(man.to_json())
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic commit

    def wait(self) -> None:
        """Barrier for async writes."""
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    # -- read -------------------------------------------------------------------

    def steps(self) -> List[int]:
        # Read-path barrier: Algorithm 1 counts checkpoints
        # (ckpt_count - extern_counter), so a version whose async write is
        # still in flight MUST be visible here — otherwise a detection that
        # lands right after a checkpoint boundary rolls back one version too
        # far (and external observers undercount the chain).
        self.wait()
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def count(self) -> int:
        return len(self.steps())

    def manifest(self, step: int) -> Manifest:
        with open(os.path.join(self.dir, _ckpt_name(step), "manifest.json")) as f:
            return Manifest.from_json(f.read())

    def latest(self, valid_only: bool = False) -> Optional[int]:
        for s in reversed(self.steps()):
            if not valid_only or self.manifest(s).valid:
                return s
        return None

    def restore(self, step: int, template) -> Any:
        """Rebuild the state pytree from version `step` using `template`'s
        structure (template leaves are only used for structure/dtype checks).

        Every leaf is cross-checked against the manifest's save-time digest:
        the recovery algorithms assume a restored checkpoint IS the state
        that was committed, so on-disk corruption (bit rot, torn writes
        outside the atomic rename) raises `CheckpointCorruptionError`
        instead of silently re-seeding the replicas from garbage."""
        self.wait()
        path = os.path.join(self.dir, _ckpt_name(step))
        man = self.manifest(step)
        tleaves, treedef = jax.tree_util.tree_flatten(template)
        if man.n_leaves != len(tleaves):
            raise ValueError(
                f"checkpoint {step} has {man.n_leaves} leaves, template has "
                f"{len(tleaves)}")
        leaves = []
        for i, t in enumerate(tleaves):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            if tuple(arr.shape) != tuple(np.shape(t)):
                raise ValueError(f"leaf {i} shape {arr.shape} != {np.shape(t)}")
            if man.leaf_digests is not None and \
                    _leaf_digest(arr) != man.leaf_digests[i]:
                raise CheckpointCorruptionError(
                    f"checkpoint {step} leaf {i}: content digest mismatch "
                    f"(on-disk payload corrupted since save)")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- delete / GC ---------------------------------------------------------------

    def delete(self, step: int) -> None:
        self.wait()
        path = os.path.join(self.dir, _ckpt_name(step))
        if os.path.exists(path):
            shutil.rmtree(path)

    def delete_others_than(self, keep_step: int) -> None:
        for s in self.steps():
            if s != keep_step:
                self.delete(s)

    def gc_keep_last(self, n: int, keep_floor: Optional[int] = None) -> None:
        """Bounded-chain mode (SedarConfig.max_checkpoints > 0).

        `keep_floor` implements the deferred-validation retention rule
        (DESIGN.md §11): the newest version with step <= keep_floor — the
        last checkpoint older than every unvalidated step — is exempt from
        pruning, so a fault anywhere inside the deferred window always has
        a rollback target that predates it."""
        if n <= 0:
            return
        steps = self.steps()
        keep = set(steps[-n:])
        if keep_floor is not None:
            anchored = [s for s in steps if s <= keep_floor]
            if anchored and not any(s <= keep_floor for s in keep):
                keep.add(anchored[-1])
        for s in steps:
            if s not in keep:
                self.delete(s)

    def clear(self) -> None:
        self.wait()
        for s in self.steps():
            self.delete(s)
