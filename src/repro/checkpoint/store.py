"""Multi-version atomic checkpoint store.

Layout:
    <dir>/ckpt_00001234/           one version per step
        manifest.json              step, kind, valid flag, fingerprint, leaf meta
        leaf_00000.npy ...         one npy per pytree leaf (tree_flatten order)
        leaf_00000.npz ...         compressed form (save(..., compress=True))
    <dir>/ckpt_00001234.tmp/       staging dir (renamed atomically on commit)

Properties required by the paper's recovery algorithms:
  * L2 (multiple system-level checkpoints): versions are NEVER garbage
    collected implicitly — any checkpoint may be the only clean one
    (paper Sec. 3.2: "none of the checkpoints can be erased").
  * L3 (single validated checkpoint): `save(..., valid=True)` +
    `delete_others_than(step)` implements "exactly one valid checkpoint".
  * restart scripts: the manifest is self-describing; `latest()/restore()`
    reconstruct the state against a caller-supplied pytree template.
  * async mode: the device->host copy happens synchronously (cheap, and the
    on-device buffers may be donated right after), serialization + fsync +
    rename run on a background thread — compute/checkpoint overlap.

Every byte read back from disk on the restore path flows through
`count_disk_reads()` — the Tier-0/1 "zero disk reads" property of the
tiered hierarchy (DESIGN.md §12) is asserted through this hook, exactly
like the zero-sync property is asserted through `hostsync.count_transfers`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import shutil
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

import jax
import numpy as np


@dataclass
class Manifest:
    step: int
    kind: str = "system"            # system | app
    valid: Optional[bool] = None    # None = unknown (L2); True = validated (L3)
    fingerprint: Optional[List[List[int]]] = None
    n_leaves: int = 0
    extra: Dict[str, Any] = field(default_factory=dict)
    # Per-leaf content digests of the bytes actually written, computed by the
    # store itself at save time and re-checked by restore(). (The engine's
    # `fingerprint` field above covers replica 0's params/opt at its own
    # granularity — it is NOT leaf-comparable against the stored payload,
    # which for L2 is the full dual state.)
    leaf_digests: Optional[List[List[int]]] = None
    # Delta checkpoints (delta.py): leaves whose content is unchanged since a
    # previous version are not rewritten — `leaf_refs[str(i)]` names the step
    # that physically holds leaf i's bytes (always resolved to the ROOT
    # holder at save time, so restore is one hop, never a chain walk).
    leaf_refs: Optional[Dict[str, int]] = None
    # Payload accounting: bytes of leaf data this version wrote to disk
    # (delta versions only count the changed leaves) and whether the leaf
    # files are np.savez_compressed.
    bytes_on_disk: Optional[int] = None
    compressed: bool = False

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "Manifest":
        return Manifest(**json.loads(s))


class CheckpointCorruptionError(RuntimeError):
    """A restored leaf does not match its save-time digest: the on-disk
    payload was corrupted after the atomic commit. L2/L3's 'valid
    checkpoint' guarantee requires failing loudly here — silently restoring
    a corrupted state would re-seed every replica from it. (The tiered
    hierarchy catches this and falls back to the partner/host tiers —
    checkpoint/tiers.py.)"""


# ---------------------------------------------------------------------------
# Disk-read accounting (the Tier-0/1 zero-disk-read property hook)
# ---------------------------------------------------------------------------

@dataclass
class DiskReadStats:
    """Counts of restore-path disk reads inside a `count_disk_reads` region."""

    reads: int = 0
    by_label: Dict[str, int] = field(default_factory=dict)

    def note(self, label: str, items: int = 1) -> None:
        self.reads += items
        self.by_label[label] = self.by_label.get(label, 0) + items


_read_active: List[DiskReadStats] = []


@contextlib.contextmanager
def count_disk_reads() -> Iterator[DiskReadStats]:
    """Count every checkpoint-payload disk read issued inside the block
    (leaf loads and manifest loads on the restore path)."""
    st = DiskReadStats()
    _read_active.append(st)
    try:
        yield st
    finally:
        _read_active.remove(st)


# Process-wide metrics fan-in, installed by `repro.obs.enable_metrics()`
# (None when metrics are off).
_metrics_note = None


def _note_disk_read(label: str, items: int = 1) -> None:
    for st in _read_active:
        st.note(label, items)
    if _metrics_note is not None:
        _metrics_note(label, items)


def _leaf_digest(arr: np.ndarray) -> List[int]:
    """Order-sensitive 64-bit digest of a leaf's raw bytes (the same mixing
    constants as core.fingerprint, numpy-only so restore verification works
    without touching a device)."""
    b = arr.tobytes()
    u = np.frombuffer(b + b"\0" * ((-len(b)) % 4), np.uint32)
    idx = np.arange(u.size, dtype=np.uint32)
    h1 = int(((u ^ (idx * np.uint32(2654435761))) *
              np.uint32(2246822519)).sum(dtype=np.uint32))
    t = (u + idx) * np.uint32(3266489917)
    h2 = int((t ^ (t >> np.uint32(15))).sum(dtype=np.uint32))
    return [h1, h2]


def _ckpt_name(step: int) -> str:
    return f"ckpt_{step:08d}"


def _write_leaf(dirpath: str, i: int, arr: np.ndarray, compress: bool) -> int:
    """Write one leaf payload; returns bytes written."""
    stem = os.path.join(dirpath, f"leaf_{i:05d}")
    if compress:
        np.savez_compressed(stem + ".npz", arr=arr)
        return os.path.getsize(stem + ".npz")
    np.save(stem + ".npy", arr)
    return os.path.getsize(stem + ".npy")


def _load_leaf(dirpath: str, i: int) -> np.ndarray:
    """Load one leaf payload (either serialization), counting the read."""
    stem = os.path.join(dirpath, f"leaf_{i:05d}")
    _note_disk_read("leaf")
    if os.path.exists(stem + ".npy"):
        return np.load(stem + ".npy")
    with np.load(stem + ".npz") as z:
        return z["arr"]


def _gc_keep_set(steps: List[int], n: int,
                 keep_floor: Optional[int]) -> set:
    """Keep-last-n plus the deferred-validation anchor (DESIGN.md §11): the
    newest version with step <= keep_floor is exempt from pruning."""
    keep = set(steps[-n:])
    if keep_floor is not None:
        anchored = [s for s in steps if s <= keep_floor]
        if anchored and not any(s <= keep_floor for s in keep):
            keep.add(anchored[-1])
    return keep


class CheckpointStore:
    def __init__(self, directory: str, compress: bool = False):
        self.dir = directory
        self.compress = compress
        os.makedirs(directory, exist_ok=True)
        self._pending: List[threading.Thread] = []
        self._lock = threading.Lock()

    # -- write ------------------------------------------------------------------

    def save(self, step: int, state, *, kind: str = "system",
             valid: Optional[bool] = None, fingerprint=None,
             async_: bool = False, extra: Optional[dict] = None,
             compress: Optional[bool] = None,
             host_leaves: Optional[List[np.ndarray]] = None) -> None:
        """Snapshot `state` (pytree of arrays) as version `step`.

        The device->host copy is ONE transfer batch: non-blocking
        `copy_to_host_async` starts every leaf's DMA concurrently, then a
        single batched `jax.device_get` of the whole leaf list awaits them
        (vs the old per-leaf loop: one blocking round-trip per leaf). The
        copy completes on the calling thread — before the caller's next
        step may DONATE the very buffers being snapshotted — and only
        serialization + fsync + rename run on the background writer.

        `host_leaves` lets the tiered checkpointer share ONE batched D2H
        transfer between the host ring and the disk/partner tiers instead
        of each tier paying its own; when given, `state` is not touched.
        `compress=True` stores each leaf via np.savez_compressed (digests
        are computed on the array CONTENT, so compressed and plain versions
        of the same state carry identical leaf digests)."""
        host_leaves = self._host_leaves(state, host_leaves)
        man = Manifest(step=step, kind=kind, valid=valid,
                       fingerprint=None if fingerprint is None
                       else np.asarray(fingerprint).astype(np.int64).tolist(),
                       n_leaves=len(host_leaves), extra=extra or {})
        self._enqueue(step, host_leaves, man,
                      self.compress if compress is None else bool(compress),
                      async_)

    @staticmethod
    def _host_leaves(state, host_leaves):
        if host_leaves is not None:
            return list(host_leaves)
        # function-level import: repro.core.recovery imports this module, so
        # a module-level `from repro.core import hostsync` would make
        # `import repro.checkpoint` circular in a fresh interpreter
        from repro.core import hostsync
        leaves = jax.tree_util.tree_flatten(state)[0]
        return hostsync.batched_get(leaves, label="checkpoint_save")

    def _enqueue(self, step: int, host_leaves, man: Manifest,
                 compress: bool, async_: bool) -> None:
        if async_:
            t = threading.Thread(target=self._write,
                                 args=(step, host_leaves, man, compress),
                                 daemon=True)
            with self._lock:
                self._pending.append(t)
            t.start()
        else:
            self._write(step, host_leaves, man, compress)

    def _write(self, step: int, host_leaves, man: Manifest,
               compress: bool = False) -> None:
        final = os.path.join(self.dir, _ckpt_name(step))
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        if man.leaf_digests is None:
            man.leaf_digests = [_leaf_digest(arr) for arr in host_leaves]
        refs = man.leaf_refs or {}
        written = 0
        for i, arr in enumerate(host_leaves):
            if str(i) in refs:
                continue                    # delta: bytes live in the base
            written += _write_leaf(tmp, i, arr, compress)
        man.compressed = bool(compress)
        man.bytes_on_disk = written
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            f.write(man.to_json())
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic commit

    def wait(self) -> None:
        """Barrier for async writes.

        Re-checks until the pending list is empty: the naive
        pop-then-join version returned EARLY on a second concurrent caller
        (caller A pops the list and is still joining; caller B sees an
        empty list and proceeds while `_write` is mid-rename) — which let
        GC scan `steps()` against a half-committed directory. Threads are
        only removed AFTER they are joined, so every caller blocks until
        every write issued before its call has committed."""
        while True:
            with self._lock:
                pending = list(self._pending)
            if not pending:
                return
            for t in pending:
                t.join()
            with self._lock:
                self._pending = [t for t in self._pending if t.is_alive()]

    # -- read -------------------------------------------------------------------

    def steps(self) -> List[int]:
        # Read-path barrier: Algorithm 1 counts checkpoints
        # (ckpt_count - extern_counter), so a version whose async write is
        # still in flight MUST be visible here — otherwise a detection that
        # lands right after a checkpoint boundary rolls back one version too
        # far (and external observers undercount the chain).
        self.wait()
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def count(self) -> int:
        return len(self.steps())

    def manifest(self, step: int) -> Manifest:
        _note_disk_read("manifest")
        with open(os.path.join(self.dir, _ckpt_name(step), "manifest.json")) as f:
            return Manifest.from_json(f.read())

    def latest(self, valid_only: bool = False) -> Optional[int]:
        for s in reversed(self.steps()):
            if not valid_only or self.manifest(s).valid:
                return s
        return None

    def restore(self, step: int, template) -> Any:
        """Rebuild the state pytree from version `step` using `template`'s
        structure (template leaves are only used for structure/dtype checks).

        Every leaf is cross-checked against the manifest's save-time digest:
        the recovery algorithms assume a restored checkpoint IS the state
        that was committed, so on-disk corruption (bit rot, torn writes
        outside the atomic rename) raises `CheckpointCorruptionError`
        instead of silently re-seeding the replicas from garbage. Leaves a
        delta version references are loaded from their root holder and
        digest-checked against THIS version's manifest — a base overwritten
        with different bytes after the delta was cut is detected, not
        silently stitched in."""
        self.wait()
        man = self.manifest(step)
        tleaves, treedef = jax.tree_util.tree_flatten(template)
        if man.n_leaves != len(tleaves):
            raise ValueError(
                f"checkpoint {step} has {man.n_leaves} leaves, template has "
                f"{len(tleaves)}")
        refs = man.leaf_refs or {}
        leaves = []
        for i, t in enumerate(tleaves):
            src = os.path.join(self.dir, _ckpt_name(refs.get(str(i), step)))
            arr = _load_leaf(src, i)
            if tuple(arr.shape) != tuple(np.shape(t)):
                raise ValueError(f"leaf {i} shape {arr.shape} != {np.shape(t)}")
            if man.leaf_digests is not None and \
                    _leaf_digest(arr) != man.leaf_digests[i]:
                raise CheckpointCorruptionError(
                    f"checkpoint {step} leaf {i}: content digest mismatch "
                    f"(on-disk payload corrupted since save)")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- delete / GC ---------------------------------------------------------------

    def delete(self, step: int) -> None:
        self.wait()
        path = os.path.join(self.dir, _ckpt_name(step))
        if os.path.exists(path):
            shutil.rmtree(path)

    def delete_others_than(self, keep_step: int) -> None:
        for s in self.steps():
            if s != keep_step:
                self.delete(s)

    def gc_keep_last(self, n: int, keep_floor: Optional[int] = None) -> None:
        """Bounded-chain mode (SedarConfig.max_checkpoints > 0).

        `keep_floor` implements the deferred-validation retention rule
        (DESIGN.md §11): the newest version with step <= keep_floor — the
        last checkpoint older than every unvalidated step — is exempt from
        pruning, so a fault anywhere inside the deferred window always has
        a rollback target that predates it."""
        if n <= 0:
            return
        steps = self.steps()
        keep = _gc_keep_set(steps, n, keep_floor)
        for s in steps:
            if s not in keep:
                self.delete(s)

    def clear(self) -> None:
        self.wait()
        for s in self.steps():
            self.delete(s)
