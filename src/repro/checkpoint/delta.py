"""Delta checkpoints: per-leaf digest dedup against the previous version.

The L2 chain re-serializes the FULL dual state every interval even when the
step only touched a fraction of it (frozen towers, sparse expert updates,
optimizer states on a slower cadence). `DeltaCheckpointStore` compares each
leaf's content digest against the newest prior version at save time:

  * changed leaves are written as usual;
  * unchanged leaves become manifest REFERENCES (`Manifest.leaf_refs`):
    `refs[str(i)] = base_step`, where `base_step` is the version that
    physically holds the bytes. References are resolved transitively at
    SAVE time (a ref always points at the root holder), so restore is a
    one-hop lookup per leaf — never a chain walk — and the dependency
    graph stays flat: version v references only physical leaves.

Restore digest-checks every leaf (referenced or local) against THIS
version's manifest, so a base that was overwritten with different bytes
after the delta was cut raises `CheckpointCorruptionError` instead of
silently stitching stale data in (the tiered planner then falls back to
the partner/host tiers).

GC must never strand a reference: `gc_keep_last` / `delete_others_than`
extend their keep-set with every step referenced by a surviving manifest.
The L2 "none of the checkpoints can be erased" default (max_checkpoints=0)
never GCs anyway; bounded chains retain the bases as extra pinned versions
(recorded as such — the chain is still `steps()`-complete).
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.store import (CheckpointStore, Manifest, _gc_keep_set,
                                    _leaf_digest)


class DeltaCheckpointStore(CheckpointStore):
    """Drop-in `CheckpointStore` whose versions share unchanged leaves."""

    def __init__(self, directory: str, compress: bool = False):
        super().__init__(directory, compress=compress)
        # (step, digests, refs) of the newest version saved by THIS process;
        # saves are issued from the single driver thread, so a plain
        # attribute is race-free. Rollback replays (re-cutting a version <=
        # the cache) and fresh processes re-derive the base from disk.
        self._last: Optional[Tuple[int, List[List[int]], Dict[str, int]]] = None

    # -- write ------------------------------------------------------------------

    def _base_for(self, step: int):
        """Newest version strictly older than `step` to delta against, as
        (base_step, base_digests, base_refs); None -> full checkpoint."""
        if self._last is not None and self._last[0] < step:
            return self._last
        prior = [s for s in self.steps() if s < step]
        if not prior:
            return None
        man = self.manifest(prior[-1])
        if man.leaf_digests is None:
            return None                    # pre-digest base: cannot dedup
        return prior[-1], man.leaf_digests, man.leaf_refs or {}

    def save(self, step: int, state, *, kind: str = "system",
             valid: Optional[bool] = None, fingerprint=None,
             async_: bool = False, extra: Optional[dict] = None,
             compress: Optional[bool] = None,
             host_leaves: Optional[List[np.ndarray]] = None) -> None:
        host_leaves = self._host_leaves(state, host_leaves)
        # digests are computed on the CALLING thread (the delta plan needs
        # them before the write is enqueued); _write sees them pre-filled
        digests = [_leaf_digest(np.asarray(a)) for a in host_leaves]
        refs: Dict[str, int] = {}
        base = self._base_for(step)
        if base is not None:
            base_step, base_digests, base_refs = base
            for i, d in enumerate(digests):
                if i < len(base_digests) and d == base_digests[i]:
                    # transitive resolution: point at the ROOT holder
                    refs[str(i)] = int(base_refs.get(str(i), base_step))
        man = Manifest(step=step, kind=kind, valid=valid,
                       fingerprint=None if fingerprint is None
                       else np.asarray(fingerprint).astype(np.int64).tolist(),
                       n_leaves=len(host_leaves), extra=extra or {},
                       leaf_digests=digests, leaf_refs=refs or None)
        self._last = (step, digests, refs)
        self._enqueue(step, host_leaves, man,
                      self.compress if compress is None else bool(compress),
                      async_)

    # -- delete / GC ------------------------------------------------------------

    def delete(self, step: int) -> None:
        """Deleting the cached delta base must invalidate the cache, or the
        next save would emit manifest refs to a nonexistent version (every
        deletion path — delete_others_than, gc_keep_last, clear — funnels
        through here)."""
        super().delete(step)
        if self._last is not None and self._last[0] == step:
            self._last = None

    def _bases_of(self, keep: set) -> set:
        """Every step physically holding a leaf some kept version refs."""
        out = set()
        for s in keep:
            try:
                man = self.manifest(s)
            except FileNotFoundError:
                continue
            for ref in (man.leaf_refs or {}).values():
                out.add(int(ref))
        return out

    def delete_others_than(self, keep_step: int) -> None:
        keep = {keep_step} | self._bases_of({keep_step})
        for s in self.steps():
            if s not in keep:
                self.delete(s)

    def gc_keep_last(self, n: int, keep_floor: Optional[int] = None) -> None:
        if n <= 0:
            return
        steps = self.steps()
        keep = _gc_keep_set(steps, n, keep_floor)
        keep |= self._bases_of(keep)
        for s in steps:
            if s not in keep:
                self.delete(s)
