from repro.checkpoint.store import CheckpointStore, Manifest

__all__ = ["CheckpointStore", "Manifest"]
