from repro.checkpoint.delta import DeltaCheckpointStore
from repro.checkpoint.store import (CheckpointCorruptionError, CheckpointStore,
                                    DiskReadStats, Manifest, count_disk_reads)
from repro.checkpoint.tiers import (DeviceRing, HostRing, SlotRing,
                                    TieredCheckpointer, TierSchedule,
                                    make_tiered, parse_tiers)

__all__ = ["CheckpointCorruptionError", "CheckpointStore",
           "DeltaCheckpointStore", "DeviceRing", "DiskReadStats", "HostRing",
           "Manifest", "SlotRing", "TierSchedule", "TieredCheckpointer",
           "count_disk_reads", "make_tiered", "parse_tiers"]
