from repro.checkpoint.store import (CheckpointCorruptionError, CheckpointStore,
                                    Manifest)

__all__ = ["CheckpointCorruptionError", "CheckpointStore", "Manifest"]
