"""Pallas TPU kernel: fused state fingerprint (hash + sum + absmax).

SEDAR's hot spot is the comparison/validation pass over every byte of
gradient/parameter state (DESIGN.md §5). This kernel computes, in a single
HBM pass with (block_rows, 128) VMEM tiles:

    h1 = sum_i ((u_i XOR (i*C1)) * C2)        mod 2^32
    h2 = sum_i (t XOR (t >> 15)), t=(u_i+i)*C3
    s  = sum(x)       (f32)
    a  = max(|x|)     (f32)

identical bit-for-bit to the pure-jnp oracle `repro.core.fingerprint.
tensor_fingerprint` (= kernels/ref.py::fingerprint_ref). The reduction terms
are associative/commutative, so the grid accumulates into 4 scalar output
refs; padding lanes contribute the identity (0 for sum/xor, -inf for max).

The tensor is viewed as (rows, 128) u32 lanes — the native f32 VREG tile is
(8, 128), so block_rows is a multiple of 8 and the last dim is exactly the
128-lane width. Arithmetic intensity is O(1) FLOP/byte: the kernel is
memory-bound by design and its roofline cost is one read of the state.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

C1 = np.uint32(2654435761)
C2 = np.uint32(2246822519)
C3 = np.uint32(3266489917)

LANES = 128
DEFAULT_BLOCK_ROWS = 256      # (256, 128) u32 = 128 KiB per VMEM tile


def default_interpret() -> bool:
    """Interpret-mode auto-detection: compile the kernel for real on TPU,
    fall back to the Python interpreter elsewhere (CPU test containers).
    REPRO_PALLAS_INTERPRET=0/1 overrides the backend check."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _fingerprint_kernel(n_valid, u_ref, h1_ref, h2_ref, s_ref, a_ref):
    i = pl.program_id(0)
    rows = u_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        h1_ref[0] = jnp.uint32(0)
        h2_ref[0] = jnp.uint32(0)
        s_ref[0] = jnp.float32(0)
        a_ref[0] = jnp.float32(0)

    u = u_ref[...]                                   # (rows, 128) u32
    # program_id is int32 — keep everything uint32 or the h2 mix's right
    # shift turns arithmetic (sign-extending) instead of logical
    base = jnp.uint32(i) * jnp.uint32(rows * LANES)
    idx = (base
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 0)
           * jnp.uint32(LANES)
           + jax.lax.broadcasted_iota(jnp.uint32, (rows, LANES), 1))
    idx = idx.astype(jnp.uint32)
    valid = idx < jnp.uint32(int(n_valid))   # n_valid is static (x.size)

    t1 = jnp.where(valid, (u ^ (idx * C1)) * C2, jnp.uint32(0))
    h1_ref[0] = h1_ref[0] + jnp.sum(t1, dtype=jnp.uint32)

    t2 = (u + idx) * C3
    t2 = jnp.where(valid, t2 ^ (t2 >> jnp.uint32(15)), jnp.uint32(0))
    h2_ref[0] = h2_ref[0] + jnp.sum(t2, dtype=jnp.uint32)

    xf = jax.lax.bitcast_convert_type(u, jnp.float32)
    xv = jnp.where(valid, xf, 0.0)
    s_ref[0] = s_ref[0] + jnp.sum(xv, dtype=jnp.float32)
    a_ref[0] = jnp.maximum(a_ref[0], jnp.max(jnp.where(valid, jnp.abs(xf), 0.0)))


def fingerprint_pallas(x, block_rows: int = DEFAULT_BLOCK_ROWS,
                       interpret: Optional[bool] = None):
    """-> (4,) uint32, bit-identical to fingerprint_ref. Accepts any floating
    dtype (exact upcast to f32 first, matching the oracle) or an
    already-packed uint32 buffer (the fused whole-state path — hashed as-is,
    no bitcast). `interpret=None` auto-detects from the JAX backend."""
    if interpret is None:
        interpret = default_interpret()
    x = jnp.asarray(x)
    if x.dtype == jnp.uint32:
        u = x.reshape(-1)
    else:
        if x.dtype != jnp.float32:
            x = x.astype(jnp.float32)
        u = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint32)
    n = u.size

    per_block = block_rows * LANES
    nblocks = max((n + per_block - 1) // per_block, 1)
    padded = nblocks * per_block
    u = jnp.pad(u, (0, padded - n))
    u = u.reshape(nblocks * block_rows, LANES)

    kern = functools.partial(_fingerprint_kernel, int(n))
    h1, h2, s, a = pl.pallas_call(
        kern,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(u)
    sb = jax.lax.bitcast_convert_type(s[0], jnp.uint32)
    ab = jax.lax.bitcast_convert_type(a[0], jnp.uint32)
    return jnp.stack([h1[0], h2[0], sb, ab])
