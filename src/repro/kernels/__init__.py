from repro.kernels import ops, ref
from repro.kernels.fingerprint import fingerprint_pallas
from repro.kernels.flash_attention import flash_attention_pallas

__all__ = ["ops", "ref", "fingerprint_pallas", "flash_attention_pallas"]
