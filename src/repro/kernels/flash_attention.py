"""Pallas TPU kernel: tiled online-softmax (flash) attention, forward.

Grid (B, H, nQ, nK) with the KV axis innermost — TPU grids execute
sequentially per core, so the (m, l, acc) running state lives in VMEM
scratch and is carried across the nK steps of one (b, h, iq) tile.

Tiles: q (1,1,bq,hd), k/v (1,1,bk,hd) with bq=bk=128 in production
(MXU-aligned: the two matmuls are (bq,hd)x(hd,bk) and (bq,bk)x(bk,hd),
all dims multiples of 128 when hd in {64,128,256} — hd=64 still fills half
the MXU and is the hardware minimum lane packing). f32 accumulation.

GQA: the kernel receives per-q-head indices and maps kv loads through
h // group_size in the BlockSpec index map — no kv replication in HBM.

Masks: causal and/or sliding window, applied from absolute tile offsets.
Fully-masked tiles still run (grid has no control flow) — skipping them via
a cost model is a documented TPU-side optimization; correctness is
mask-exact. Validated in interpret mode against ref.py::mha_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(scale, causal, window, bq, bk, seq_k,
                  q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.einsum("qd,kd->qk", q, k,
                   preferred_element_type=jnp.float32) * scale   # (bq, bk)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < seq_k
    if causal:
        mask = mask & (qpos >= kpos)
    if window:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.einsum("qk,kd->qd", p, v,
                                 preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) — GQA when KV < H.

    Returns (B, H, Sq, hd) in q.dtype."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # pad sequences to tile multiples (masked out via seq_k / qpos bounds;
    # padded q rows produce garbage that the wrapper slices away)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nQ = q.shape[2] // bq
    nK = k.shape[2] // bk

    kern = functools.partial(_flash_kernel, 1.0 / math.sqrt(hd), causal,
                             window, bq, bk, Sk)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nQ, nK),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, q.shape[2], hd), q.dtype),
        scratch_shapes=[
            _vmem((bq, hd), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
