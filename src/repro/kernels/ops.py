"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute with interpret=True (Python
emulation of the kernel body); on TPU set REPRO_PALLAS_INTERPRET=0 (or rely
on the backend check) to compile them for real. Block shapes stay identical
either way, so VMEM footprints claimed by the BlockSpecs are what a TPU
would see.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.fingerprint import fingerprint_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def fingerprint(x, block_rows: int = 256) -> jnp.ndarray:
    """Fused fingerprint of one tensor -> (4,) uint32."""
    return fingerprint_pallas(x, block_rows=block_rows,
                              interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Flash attention in model layout. q: (B,S,H,hd); k/v: (B,S,KV,hd).

    Returns (B,S,H,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=_interpret())
    return out.transpose(0, 2, 1, 3)
