"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute with interpret=True (Python
emulation of the kernel body); on TPU set REPRO_PALLAS_INTERPRET=0 (or rely
on the backend check) to compile them for real. Block shapes stay identical
either way, so VMEM footprints claimed by the BlockSpecs are what a TPU
would see.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fingerprint import default_interpret, fingerprint_pallas
from repro.kernels.flash_attention import flash_attention_pallas

_interpret = default_interpret   # back-compat alias


def fingerprint(x, block_rows: int = 256) -> jnp.ndarray:
    """Fused fingerprint of one tensor -> (4,) uint32."""
    return fingerprint_pallas(x, block_rows=block_rows,
                              interpret=default_interpret())


def fingerprint_packed(u, block_rows: int = 256) -> jnp.ndarray:
    """Fingerprint of an already-packed u32 buffer (the fused whole-state
    path: core.fingerprint.pack_tree_u32 -> one kernel pass) -> (4,).

    Float input is bit-reinterpreted by the kernel, never value-cast."""
    u = jnp.asarray(u)
    if u.dtype != jnp.uint32 and not jnp.issubdtype(u.dtype, jnp.floating):
        raise TypeError(f"fingerprint_packed expects a packed uint32 buffer "
                        f"(or a float tensor to bitcast), got {u.dtype}")
    return fingerprint_pallas(u, block_rows=block_rows,
                              interpret=default_interpret())


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Flash attention in model layout. q: (B,S,H,hd); k/v: (B,S,KV,hd).

    Returns (B,S,H,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=_interpret())
    return out.transpose(0, 2, 1, 3)
