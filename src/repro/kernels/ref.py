"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.fingerprint import tensor_fingerprint


def fingerprint_ref(x) -> jnp.ndarray:
    """Oracle for kernels/fingerprint.py — the SEDAR core implementation."""
    x = jnp.asarray(x)
    if x.dtype != jnp.float32:
        x = x.astype(jnp.float32)
    return tensor_fingerprint(x)


def mha_ref(q, k, v, *, causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Exact attention. q: (B,H,Sq,hd); k/v: (B,KV,Sk,hd); GQA when KV<H."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window:
        mask = mask & (qpos - kpos < window)
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vx.astype(jnp.float32)).astype(q.dtype)
