"""SEDAR temporal-behavior model — paper Eqs. (1)-(14), Sec. 3.4 and Sec. 4.4.

All times in HOURS unless suffixed _s. Parameter names follow paper Table 1:

    T_prog  : execution time of two instances of the original app in parallel
    T_comp  : semi-automatic result comparison time
    T_rest  : restart time
    f_d     : detection-mechanism overhead factor (0 < f_d < 1)
    X       : fault-detection instant as a fraction of progress (0 < X < 1)
    n       : number of checkpoints in the whole execution
    t_cs    : system-level checkpoint store time
    t_i     : checkpoint interval
    k       : extra checkpoints to rewind when the last one is dirty
    t_ca    : application-level checkpoint store time (t_ca < t_cs)
    T_compA : application-level checkpoint validation time

Beyond-paper ABFT terms (DESIGN.md §10 — detection by checksum-carrying
kernels instead of duplicated execution):

    f_a     : ABFT checksum overhead factor (encode + verify, a few percent)
    abft_correct_frac : fraction of detected faults the checksums localize
              and forward-correct in place (single-element corruptions)
    redundancy_wall   : wall-clock ratio of the duplicated execution to ONE
              instance. T_prog is defined as two instances IN PARALLEL
              (space redundancy: same wall as one instance, 2x resources),
              so the default is 1.0 — ABFT's fault-free WALL matches
              duplication's, its win there is halved resources plus forward
              correction on the faulty path. Set 2.0 explicitly when
              modeling the time-redundant sequential backend (duplication
              doubles the wall and ABFT's single instance halves it back).

Deferred-validation terms (DESIGN.md §11 — the engine's `validate_lag=D`
window; cf. Aupy et al., "On the Combination of Silent Error Detection and
Checkpointing": the validation interval is a tunable independent of the
checkpoint interval):

    t_step  : duration of ONE protected step (hours)
    t_sync  : host-sync cost the per-step predicate readback adds to a step
              (hours) — a device->host round-trip plus the pipeline bubble
              it forces; 0 disables the deferred model
    D       : validate_lag. Fault-free runs save t_sync*(1 - 1/D) per step;
              a fault detected up to D steps late discards D/2 steps of
              work in expectation (uniform fault instant inside the window)

Validated against the paper's published Tables 4 and 5 in
tests/test_temporal_model.py.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class SedarParams:
    T_prog: float            # hours
    T_comp: float            # hours
    T_rest: float            # hours
    f_d: float
    t_cs: float              # hours
    t_ca: float              # hours
    T_compA: float           # hours
    t_i: float = 1.0         # hours
    n: Optional[int] = None  # checkpoints; default derived from Eq. 3 / t_i
    f_a: float = 0.03        # ABFT checksum overhead factor (beyond paper)
    abft_correct_frac: float = 0.8   # detected faults corrected in place
    redundancy_wall: float = 1.0     # duplicated wall / single-instance wall
    t_step: float = 0.0      # hours per protected step (deferred model)
    t_sync: float = 0.0      # hours of host-sync cost per per-step readback

    def n_ckpts(self) -> int:
        """Paper: n = time of the detection-only strategy (Eq. 3) / t_i."""
        if self.n is not None:
            return self.n
        return int(detection_fa(self) / self.t_i)


# ---------------------------------------------------------------------------
# Baseline (manual two-instance + vote) — Eqs. (1), (2)
# ---------------------------------------------------------------------------

def baseline_fa(p: SedarParams) -> float:
    return p.T_prog + p.T_comp                                   # Eq. (1)


def baseline_fp(p: SedarParams) -> float:
    return 2.0 * (p.T_prog + p.T_comp) + p.T_rest                # Eq. (2)


# ---------------------------------------------------------------------------
# L1: detection + notification — Eqs. (3), (4)
# ---------------------------------------------------------------------------

def detection_fa(p: SedarParams) -> float:
    return p.T_prog * (1.0 + p.f_d) + p.T_comp                   # Eq. (3)


def detection_fp(p: SedarParams, X: float) -> float:
    return (p.T_prog * (1.0 + p.f_d) * (X + 1.0)
            + p.T_rest + p.T_comp)                               # Eq. (4)


# ---------------------------------------------------------------------------
# L2: multiple system-level checkpoints — Eqs. (5), (6) == (14) via (13)
# ---------------------------------------------------------------------------

def multi_ckpt_fa(p: SedarParams) -> float:
    return detection_fa(p) + p.n_ckpts() * p.t_cs                # Eq. (5)


def multi_ckpt_fp(p: SedarParams, k: int) -> float:
    """Eq. (6)/(14): sum_{m=0}^{k}(k - m + 1/2) t_i == ((k+1)^2 / 2) t_i."""
    n = p.n_ckpts()
    rework = ((k + 1) ** 2) / 2.0 * p.t_i                        # Eq. (13)
    return (p.T_prog * (1.0 + p.f_d) + p.T_comp
            + (n + k) * p.t_cs + rework + (k + 1) * p.T_rest)    # Eq. (14)


# ---------------------------------------------------------------------------
# L3: single validated application-level checkpoint — Eqs. (7), (8)
# ---------------------------------------------------------------------------

def single_ckpt_fa(p: SedarParams) -> float:
    n = p.n_ckpts()
    return detection_fa(p) + n * (p.t_ca + p.T_compA)            # Eq. (7)


def single_ckpt_fp(p: SedarParams) -> float:
    return (single_ckpt_fa(p) + 0.5 * p.t_i + p.T_rest)          # Eq. (8)


# ---------------------------------------------------------------------------
# ABFT: replica-free checksum detection (beyond paper, DESIGN.md §10)
# ---------------------------------------------------------------------------

def abft_fa(p: SedarParams) -> float:
    """Fault-free time of the ABFT-protected SINGLE instance: one execution
    carrying checksums (f_a analogue of f_d) plus the residual-verification
    pass (bounded by T_comp — both are one pass over the results)."""
    return (p.T_prog / p.redundancy_wall) * (1.0 + p.f_a) + p.T_comp


def abft_fp(p: SedarParams, X: float) -> float:
    """Time with one fault at progress X. Detected-corrected faults (frac
    abft_correct_frac) are repaired FORWARD at negligible cost; the
    uncorrectable remainder relaunches, mirroring Eq. (4) with the
    single-instance progression time."""
    t = (p.T_prog / p.redundancy_wall) * (1.0 + p.f_a)
    relaunch = t * (X + 1.0) + p.T_rest + p.T_comp
    return p.abft_correct_frac * abft_fa(p) \
        + (1.0 - p.abft_correct_frac) * relaunch


def hybrid_fa(p: SedarParams, validations: int = 0) -> float:
    """ABFT + periodic fingerprint validation (the escaped-fault backstop):
    each validation is one T_comp-class pass over the state."""
    return abft_fa(p) + validations * p.T_comp


# ---------------------------------------------------------------------------
# Deferred validation window (DESIGN.md §11, beyond paper)
# ---------------------------------------------------------------------------

def n_steps(p: SedarParams) -> float:
    """Protected steps in the detection-only run (Eq.-3 time / t_step)."""
    if p.t_step <= 0:
        return 0.0
    return detection_fa(p) / p.t_step


def deferred_sync_savings(p: SedarParams, D: int) -> float:
    """Hours removed from the fault-free run by deferring the per-step
    predicate readback to every D-th step: each of the n_steps steps keeps
    1/D of the sync cost (the flush still reads the ring once per window)."""
    if D <= 1 or p.t_sync <= 0 or p.t_step <= 0:
        return 0.0
    return n_steps(p) * p.t_sync * (1.0 - 1.0 / D)


def deferred_waste(p: SedarParams, D: int) -> float:
    """Expected work discarded per fault: detection lags the faulty step by
    U[0, D) steps (uniform fault instant inside the window), so D/2 steps
    of optimistic progress roll back and re-execute in expectation."""
    if D <= 1 or p.t_step <= 0:
        return 0.0
    return (D / 2.0) * p.t_step


def deferred_fa(p: SedarParams, D: int) -> float:
    """Fault-free time of detection+deferral: Eq. (3) minus the sync wins."""
    return detection_fa(p) - deferred_sync_savings(p, D)


def deferred_fp(p: SedarParams, D: int, X: float) -> float:
    """Faulty time: Eq. (4) keeps the sync wins but pays the D/2 discard."""
    return detection_fp(p, X) - deferred_sync_savings(p, D) \
        + deferred_waste(p, D)


def aet_deferred(p: SedarParams, D: int, mtbe: float, X: float = 0.5) -> float:
    """Eq. (11) with the deferred-window fa/fp pair.

    Short-MTBE correction: Eq. (11)'s alpha saturates at ONE fault per
    execution, but a faulty run at mtbe << T_prog contains ~T_prog/mtbe
    faults and pays the D/2-step discard for EACH of them. Without the
    extra term the model would always prefer the longest window under
    fault storms — exactly when long windows are most expensive (pinned
    against a measured-cost simulation in bench_autotune)."""
    extra = max(p.T_prog / mtbe - 1.0, 0.0) * deferred_waste(p, D)
    return aet(deferred_fp(p, D, X) + extra, deferred_fa(p, D),
               p.T_prog, mtbe)


LAG_CANDIDATES = (1, 2, 4, 8, 16, 32, 64, 128)


def optimal_validate_lag(p: SedarParams, mtbe: float, X: float = 0.5,
                         candidates=LAG_CANDIDATES) -> int:
    """argmin_D of the deferred AET. The tension: sync savings saturate as
    (1 - 1/D) while the per-fault discard grows as D/2, so the optimum
    rises with t_sync/t_step and falls as MTBE shrinks. Returns 1 when the
    deferred terms are unparameterized (t_step or t_sync unset)."""
    if p.t_step <= 0 or p.t_sync <= 0:
        return 1
    return min(candidates, key=lambda D: aet_deferred(p, int(D), mtbe, X))


# ---------------------------------------------------------------------------
# Tiered checkpoint hierarchy (DESIGN.md §12, beyond paper; cf. Aupy et al.
# arXiv:1310.8486 — verification cadence coupled with a hierarchy of
# checkpoint costs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TierCosts:
    """Per-tier generalization of the paper's single t_cs/t_r pair.

    t_save    : hours to store one version in this tier (the tier's t_cs)
    t_restore : hours to restore one version from this tier (its t_r —
                includes the restart-class costs that tier actually pays:
                a device-ring restore is a few on-device copies, a disk
                restore pays deserialization + digest verification)
    slots     : ring capacity in versions (0 = unbounded, disk-backed)
    """

    t_save: float
    t_restore: float
    slots: int = 0


def default_tier_costs(p: SedarParams) -> dict:
    """Tier costs derived from the measured flat-store numbers: the device
    ring is ~2 orders of magnitude cheaper than serialization (pure HBM
    copies), the host ring ~1 order (one batched D2H, no serialization),
    the partner tier doubles the disk cost (second independent copy).
    Replace with bench_checkpoint.py measurements when available."""
    return {
        "device": TierCosts(t_save=p.t_cs / 256.0, t_restore=p.t_cs / 256.0,
                            slots=4),
        "host": TierCosts(t_save=p.t_cs / 16.0, t_restore=p.t_cs / 16.0,
                          slots=4),
        "disk": TierCosts(t_save=p.t_cs, t_restore=p.T_rest),
        "partner": TierCosts(t_save=2.0 * p.t_cs, t_restore=2.0 * p.T_rest),
    }


TIER_NAMES = ("device", "host", "disk", "partner")


def tiered_fa(p: SedarParams, schedule: dict, costs: dict) -> float:
    """Eq. (5) generalized to the hierarchy: fault-free time = detection
    time + Σ_tier (saves in that tier) · t_save(tier). `schedule` maps tier
    name -> save interval in steps (0/absent = tier disabled)."""
    steps = n_steps(p)
    if steps <= 0:
        return detection_fa(p)
    extra = sum((steps / iv) * costs[t].t_save
                for t, iv in schedule.items() if iv > 0 and t in costs)
    return detection_fa(p) + extra


def restore_tier(schedule: dict, costs: dict, lag_steps: int = 1) -> str:
    """The planner's expected source tier for a fault detected `lag_steps`
    after it happened: the cheapest tier whose retention window (slots ·
    interval, unbounded for disk tiers) still spans a version predating the
    fault. Mirrors TieredCheckpointer.plan's cost order."""
    enabled = [t for t in TIER_NAMES if schedule.get(t, 0) > 0]
    for t in enabled:
        c = costs[t]
        if c.slots == 0 or c.slots * schedule[t] > lag_steps:
            return t
    return enabled[-1] if enabled else "disk"


def tiered_fp(p: SedarParams, schedule: dict, costs: dict, X: float = 0.5,
              lag_steps: int = 1) -> float:
    """Time with one fault: the planner restores from `restore_tier`, so
    the penalty is that tier's t_restore plus the rework back to its newest
    version predating the fault — detection lag + half the tier's interval
    in expectation (uniform fault instant inside the interval)."""
    t = restore_tier(schedule, costs, lag_steps)
    rework = (lag_steps + schedule.get(t, 1) / 2.0) * p.t_step
    return tiered_fa(p, schedule, costs) + costs[t].t_restore + rework


def aet_tiered(p: SedarParams, schedule: dict, costs: dict, mtbe: float,
               X: float = 0.5, lag_steps: int = 1) -> float:
    """Eq. (11) with the tiered fa/fp pair."""
    return aet(tiered_fp(p, schedule, costs, X, lag_steps),
               tiered_fa(p, schedule, costs), p.T_prog, mtbe)


def optimal_tier_schedule(p: SedarParams, costs: Optional[dict] = None,
                          mtbe: float = 5.0, lag_steps: int = 1) -> dict:
    """Cost-aware cadence per tier (steps between saves).

    * device: every step — a ring snapshot costs ~nothing next to t_step,
      and it is the tier that makes rollback-to-k free;
    * host / disk: Daly's optimum interval computed against EACH tier's own
      t_save (the whole point of the hierarchy: a cheap tier affords a
      short interval), floored at one step and kept monotonically
      non-decreasing down the hierarchy;
    * partner: the disk cadence ×2 — it exists to survive store corruption,
      not to shorten rollback distance, so it only needs to bound the
      re-protection window.

    Empty dict when the deferred terms are unparameterized (t_step unset)."""
    if p.t_step <= 0:
        return {}
    costs = costs or default_tier_costs(p)

    def steps_for(tier: str, floor: int) -> int:
        iv_h = daly_interval(costs[tier].t_save, mtbe)
        return max(int(round(iv_h / p.t_step)), floor, 1)

    out = {"device": 1}
    out["host"] = steps_for("host", out["device"])
    out["disk"] = steps_for("disk", out["host"])
    out["partner"] = max(2 * out["disk"], 1)
    return out


# ---------------------------------------------------------------------------
# Serving under faults (DESIGN.md §13, beyond paper): goodput & availability
# of continuous-batching protected decode with per-request recovery
# ---------------------------------------------------------------------------
#
# One decode step emits one token per active slot, so t_step doubles as the
# per-token machine time. Faults arrive at rate 1/MTBE; per fault the
# recovery cost depends on the rework SCOPE:
#   whole-batch (the synchronous generate() loop): every one of n_slots
#     sequences re-executes the detection window -> n_slots * D/2 slot-steps
#     discarded in expectation (uniform fault instant inside the window);
#   per-request (the slotted loop): ONE slot rolls back from its Tier-0
#     ring while the others stream on -> D/2 slot-steps discarded.
# Goodput is the delivered fraction of slot-step capacity; availability is
# the probability a random sequence is NOT replaying rolled-back work at a
# random instant (whole-batch recovery stalls everyone, per-request only
# the affected sequence).


def serve_goodput(p: SedarParams, mtbe: float, n_slots: int, D: int = 1,
                  per_request: bool = True) -> float:
    """Delivered fraction of decode capacity under faults: 1 minus the
    expected slot-steps discarded per fault over the slot-steps produced
    between faults."""
    if p.t_step <= 0 or n_slots <= 0:
        return 1.0
    steps_between_faults = mtbe / p.t_step          # decode ticks per fault
    discarded = (max(D, 1) / 2.0) * (1.0 if per_request else n_slots)
    frac = discarded / max(steps_between_faults * n_slots, 1e-12)
    return max(0.0, 1.0 - frac)


def serve_availability(p: SedarParams, mtbe: float, n_slots: int,
                       D: int = 1, per_request: bool = True) -> float:
    """Probability a given sequence is streaming (not replaying) at a
    random instant: replay occupies D/2 of its slot's ticks per fault, and
    whole-batch recovery replays EVERY sequence while per-request recovery
    replays only the affected one (probability 1/n_slots per fault)."""
    if p.t_step <= 0 or n_slots <= 0:
        return 1.0
    steps_between_faults = mtbe / p.t_step
    replay = (max(D, 1) / 2.0) * \
        ((1.0 / n_slots) if per_request else 1.0)
    return max(0.0, 1.0 - replay / max(steps_between_faults, 1e-12))


def serve_token_cost(p: SedarParams, mtbe: float, n_slots: int,
                     D: int = 1) -> float:
    """Expected machine-hours per DELIVERED token at validate_lag D with
    per-request recovery: the step itself, the amortized once-per-D
    predicate readback, and the per-fault slot rework spread over the
    tokens between faults. The serving analogue of Eq. (11)'s integrand."""
    if p.t_step <= 0:
        return 0.0
    sync = p.t_sync / max(D, 1)
    tokens_between_faults = (mtbe / p.t_step) * max(n_slots, 1)
    rework = (max(D, 1) / 2.0) * p.t_step / max(tokens_between_faults, 1e-12)
    return p.t_step + sync + rework


SERVE_LAG_CANDIDATES = (1, 2, 4, 8, 16, 32, 64)


def optimal_serve_lag(p: SedarParams, mtbe: float, n_slots: int,
                      candidates=SERVE_LAG_CANDIDATES) -> int:
    """argmin_D of the per-token cost. Same tension as
    `optimal_validate_lag`, but the per-fault discard is divided by
    n_slots (only one sequence replays), so serving tolerates LONGER
    windows than training at the same MTBE — at the price of up to D
    steps of emission-rollback latency on the faulty request."""
    if p.t_step <= 0 or p.t_sync <= 0:
        return 1
    return min(candidates,
               key=lambda D: serve_token_cost(p, mtbe, n_slots, int(D)))


# ---------------------------------------------------------------------------
# Average execution time — Eqs. (9)-(11)
# ---------------------------------------------------------------------------

def fault_probability(T_prog: float, mtbe: float) -> float:
    """Eq. (10): P = 1 - exp(-T_prog / MTBE), exponential error model."""
    return 1.0 - math.exp(-T_prog / mtbe)


def aet(t_fp: float, t_fa: float, T_prog: float, mtbe: float) -> float:
    """Eq. (11)."""
    alpha = fault_probability(T_prog, mtbe)
    return t_fp * alpha + t_fa * (1.0 - alpha)


def aet_strategy(p: SedarParams, strategy: str, mtbe: float,
                 X: float = 0.5, k: int = 0) -> float:
    """AET for one of: baseline | detection | multi_ckpt | single_ckpt | abft."""
    table = {
        "baseline": (baseline_fa(p), baseline_fp(p)),
        "detection": (detection_fa(p), detection_fp(p, X)),
        "multi_ckpt": (multi_ckpt_fa(p), multi_ckpt_fp(p, k)),
        "single_ckpt": (single_ckpt_fa(p), single_ckpt_fp(p)),
        "abft": (abft_fa(p), abft_fp(p, X)),
    }
    fa, fp = table[strategy]
    return aet(fp, fa, p.T_prog, mtbe)


def system_mtbe(mtbe_individual: float, n_processors: int) -> float:
    """MTBE = MTBE_ind / N (paper Sec. 3.4)."""
    return mtbe_individual / n_processors


# ---------------------------------------------------------------------------
# Checkpoint-interval selection (Daly's higher-order estimate, paper Sec. 4.3)
# ---------------------------------------------------------------------------

def daly_interval(t_cs: float, mtbe: float) -> float:
    """Daly (2006) higher-order optimum checkpoint interval (hours)."""
    if t_cs >= 2.0 * mtbe:
        return mtbe
    x = math.sqrt(2.0 * t_cs * mtbe)
    return x * (1.0 + math.sqrt(t_cs / (2.0 * mtbe)) / 3.0
                + (t_cs / (2.0 * mtbe)) / 9.0) - t_cs


# ---------------------------------------------------------------------------
# Sec. 4.4 — convenience of saving multiple checkpoints
# ---------------------------------------------------------------------------

def admissible_k(p: SedarParams, X: float) -> int:
    """Largest admissible k at detection instant X: the rollback target
    checkpoint must already exist (ckpts are cut every t_i of Eq.-3 time)."""
    stored = int((X * detection_fa(p)) / p.t_i)   # checkpoints stored so far
    return max(stored - 1, -1)                    # k in {0..stored-1}


def rollback_beats_restart(p: SedarParams, X: float, k: int) -> bool:
    """True if k+1 rollbacks (Eq. 14) beat detect+relaunch (Eq. 4) at X."""
    if k > admissible_k(p, X):
        return False
    return multi_ckpt_fp(p, k) <= detection_fp(p, X)


def min_progress_for_checkpointing(p: SedarParams) -> float:
    """X* below which storing checkpoints is NOT worth it (Eq.4 <= Eq.14, k=0).

    Paper Sec 4.4: X <= ~5.88% for the Jacobi parameters."""
    # T(1+fd)(X+1) + Trest + Tcomp <= T(1+fd) + Tcomp + n tcs + ti/2 + Trest
    n = p.n_ckpts()
    return (n * p.t_cs + 0.5 * p.t_i) / (p.T_prog * (1.0 + p.f_d))


def min_progress_for_k(p: SedarParams, k: int) -> float:
    """X* above which rolling back k+1 checkpoints beats detect+relaunch."""
    n = p.n_ckpts()
    lhs = ((n + k) * p.t_cs + ((k + 1) ** 2) / 2.0 * p.t_i
           + k * p.T_rest)
    return lhs / (p.T_prog * (1.0 + p.f_d))


def convenience_table(p: SedarParams, Xs=(0.3, 0.5, 0.8), ks=(0, 1, 2, 3, 4)):
    """Paper Table 5: detection-only time vs k+1-rollback times, with NA for
    non-admissible (checkpoint not yet stored) combinations."""
    rows = []
    for X in Xs:
        adm = admissible_k(p, X)
        row = {"X": X, "detection": detection_fp(p, X), "k": {}}
        for k in ks:
            row["k"][k] = multi_ckpt_fp(p, k) if k <= adm else None  # None = NA
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# Node loss: fail-in-place vs full restart (DESIGN.md §16, beyond paper —
# the spatial analogue of Sec. 4.4's rollback-vs-restart convenience rule)
# ---------------------------------------------------------------------------

def remesh_overhead(p: SedarParams, costs: Optional[dict] = None) -> float:
    """Hours one elastic remesh transition costs: restore the anchor state
    from the durable partner tier onto the (new) mesh plus re-plumbing the
    survivors. The process, data pipeline, and compiled executables all
    survive, so the restore pays only the partner copy's data movement
    (~its save cost, NOT its restart-class t_restore) plus a small
    fraction of a relaunch for the mesh rebuild."""
    c = costs or default_tier_costs(p)
    return c["partner"].t_save + 0.1 * p.T_rest


def fail_in_place_cost(p: SedarParams, outage_hours: float,
                       costs: Optional[dict] = None,
                       keep_degraded: bool = False) -> float:
    """Hours a node outage costs under fail-in-place: shrink + regrow
    transitions (2× remesh), and — because the authoritative full-width
    trajectory re-anchors at the pre-shrink checkpoint — the degraded
    segment is replayed at full width unless `keep_degraded` (a workload
    that accepts the reduced-batch trajectory as-is). The replayed segment
    is the outage span plus half a checkpoint interval of pre-outage work
    in expectation."""
    transitions = 2.0 * remesh_overhead(p, costs)
    if keep_degraded:
        return transitions
    return transitions + 0.5 * p.t_i + outage_hours


def node_restart_cost(p: SedarParams, outage_hours: float) -> float:
    """Hours the same outage costs under stop-and-relaunch (the Eq.-4
    restart path applied to a node loss): the job idles for the outage,
    pays a full relaunch, and redoes half a checkpoint interval."""
    return outage_hours + p.T_rest + 0.5 * p.t_i


def fail_in_place_beats_restart(p: SedarParams, outage_hours: float,
                                costs: Optional[dict] = None,
                                keep_degraded: bool = False) -> bool:
    """The §16 decision direction: with the degraded trajectory replayed,
    both options pay the outage span + t_i/2, so fail-in-place wins exactly
    when two remesh transitions undercut one full relaunch (2·remesh <
    T_rest) — and always wins when the degraded progress is kept."""
    return fail_in_place_cost(p, outage_hours, costs, keep_degraded) <= \
        node_restart_cost(p, outage_hours)


# ---------------------------------------------------------------------------
# Paper Table 3 parameter sets (for validation + benchmarks)
# ---------------------------------------------------------------------------

PAPER_TABLE3 = {
    "MATMUL": SedarParams(T_prog=10.21, T_comp=42 / 3600, T_rest=14.10 / 3600,
                          f_d=0.0001, t_cs=14.10 / 3600, t_ca=10.58 / 3600,
                          T_compA=42 / 3600, t_i=1.0, n=10),
    "JACOBI": SedarParams(T_prog=8.92, T_comp=1 / 3600, T_rest=9.62 / 3600,
                          f_d=0.006, t_cs=9.62 / 3600, t_ca=9.11 / 3600,
                          T_compA=1 / 3600, t_i=1.0, n=8),
    "SW":     SedarParams(T_prog=11.15, T_comp=0.5 / 3600, T_rest=2.55 / 3600,
                          f_d=0.0005, t_cs=2.55 / 3600, t_ca=1.92 / 3600,
                          T_compA=0.5 / 3600, t_i=1.0, n=11),
}

# Paper Table 4 published values (hours) for regression-testing our model.
PAPER_TABLE4 = {
    # row: (MATMUL, JACOBI, SW)
    "baseline_fa":        (10.22, 8.92, 11.15),
    "baseline_fp":        (20.45, 17.85, 22.35),
    "detection_fa":       (10.23, 8.97, 11.16),
    "detection_fp_30":    (13.29, 11.67, 14.50),
    "detection_fp_50":    (15.33, 13.46, 16.73),
    "detection_fp_80":    (18.39, 16.16, 20.08),
    "multi_fa":           (10.26, 9.00, 11.17),
    "multi_fp_k0":        (10.77, 9.50, 11.66),
    "multi_fp_k1":        (12.27, 11.01, 13.17),
    "multi_fp_k4":        (22.79, 21.53, 23.67),
    "single_fa":          (10.37, 8.99, 11.16),
    "single_fp":          (10.87, 9.50, 11.66),
}


def table4_ours() -> dict:
    """Recompute paper Table 4 from Table 3 parameters with our model."""
    out = {}
    apps = ["MATMUL", "JACOBI", "SW"]
    P = [PAPER_TABLE3[a] for a in apps]
    out["baseline_fa"] = tuple(baseline_fa(p) for p in P)
    out["baseline_fp"] = tuple(baseline_fp(p) for p in P)
    out["detection_fa"] = tuple(detection_fa(p) for p in P)
    for x in (30, 50, 80):
        out[f"detection_fp_{x}"] = tuple(detection_fp(p, x / 100) for p in P)
    out["multi_fa"] = tuple(multi_ckpt_fa(p) for p in P)
    for k in (0, 1, 4):
        out[f"multi_fp_k{k}"] = tuple(multi_ckpt_fp(p, k) for p in P)
    out["single_fa"] = tuple(single_ckpt_fa(p) for p in P)
    out["single_fp"] = tuple(single_ckpt_fp(p) for p in P)
    return out
