"""Protection-strategy advisor (paper Secs. 3.4 + 4.4).

Given measured execution parameters (f_d, t_cs, t_ca, ...) and the system
MTBE, pick the SEDAR level + checkpoint interval that minimizes the Average
Execution Time (Eq. 11), and compute the dynamic-protection schedule from the
Sec.-4.4 analysis ("when to start checkpointing").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core import temporal_model as tm


@dataclass
class Advice:
    strategy: str                  # detection | multi_ckpt | single_ckpt
    level: int
    t_i: float                     # recommended checkpoint interval (hours)
    aet_hours: Dict[str, float]    # AET per strategy at the chosen t_i
    start_checkpointing_at: float  # progress fraction X* (Sec. 4.4)
    keep_two_checkpoints_at: float # X* above which >=2 rollbacks pay off
    notes: str = ""


def advise(p: tm.SedarParams, mtbe_hours: float,
           X_expected: float = 0.5, k_expected: int = 0) -> Advice:
    """Pick the minimum-AET strategy.

    X_expected: where faults are typically detected (0.5 if unknown —
    uniform detection instant, the paper's average-case assumption).
    k_expected: typical extra rollbacks for L2 (0 when the detection latency
    is usually inside one interval)."""
    # tune t_i by Daly for the two checkpointing strategies
    ti_sys = max(tm.daly_interval(p.t_cs, mtbe_hours), p.t_cs * 4)
    ti_app = max(tm.daly_interval(p.t_ca + p.T_compA, mtbe_hours),
                 (p.t_ca + p.T_compA) * 4)

    p_sys = dataclasses.replace(p, t_i=ti_sys, n=None)
    p_app = dataclasses.replace(p, t_i=ti_app, n=None)

    aets = {
        "detection": tm.aet_strategy(p, "detection", mtbe_hours, X=X_expected),
        "multi_ckpt": tm.aet_strategy(p_sys, "multi_ckpt", mtbe_hours,
                                      k=k_expected),
        "single_ckpt": tm.aet_strategy(p_app, "single_ckpt", mtbe_hours),
    }
    best = min(aets, key=aets.get)
    level = {"detection": 1, "multi_ckpt": 2, "single_ckpt": 3}[best]
    t_i = {"detection": 0.0, "multi_ckpt": ti_sys, "single_ckpt": ti_app}[best]

    notes = []
    if p.T_prog < 4 * max(p.t_cs, p.t_ca):
        notes.append("short run: checkpointing overhead may dominate "
                     "(paper: 'if the execution is too short, checkpoints "
                     "become worthless')")
    return Advice(
        strategy=best,
        level=level,
        t_i=t_i,
        aet_hours={k: round(v, 4) for k, v in aets.items()},
        start_checkpointing_at=tm.min_progress_for_checkpointing(p_sys),
        keep_two_checkpoints_at=tm.min_progress_for_k(p_sys, 1),
        notes="; ".join(notes),
    )
