"""Protection policy: strategy advisor, engine factory, and the autotuner.

Three parts:
  * `advise()` (paper Secs. 3.4 + 4.4): given measured execution parameters
    (f_d, t_cs, t_ca, ...) and the system MTBE, pick the SEDAR level +
    checkpoint interval that minimizes the Average Execution Time (Eq. 11).
  * `make_engine()` / `make_trainer()` / `make_server()`: the single
    composition point that turns a SedarConfig + workload step functions
    into a `SedarEngine` (executor × schedule × recovery × watchdog ×
    injection). Every launcher and runtime constructs engines here, so the
    detection/recovery protocol is configured in exactly one place.
  * `Autotuner` / `autotune()` (DESIGN.md §17): the closed loop — the
    obs estimator calibrates the temporal model online, drift detectors
    and SLO burn windows raise alerts, and safe knob changes (validate_lag,
    tier cadences) are applied via `SedarEngine.apply_reconfig()` at clean
    deferred-flush boundaries with hysteresis; backend changes are
    advisory alerts only (they would require a re-trace mid-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.core import temporal_model as tm


@dataclass
class Advice:
    strategy: str                  # detection | multi_ckpt | single_ckpt
    level: int
    t_i: float                     # recommended checkpoint interval (hours)
    aet_hours: Dict[str, float]    # AET per strategy at the chosen t_i
    start_checkpointing_at: float  # progress fraction X* (Sec. 4.4)
    keep_two_checkpoints_at: float # X* above which >=2 rollbacks pay off
    notes: str = ""
    # detection-mechanism axis (DESIGN.md §10): "duplication" (the paper's
    # replicated execution) vs "abft" (replica-free checksummed kernels).
    detection_mechanism: str = "duplication"
    abft_aet_hours: float = 0.0    # AET of the ABFT backend at the same MTBE
    # deferred-validation axis (DESIGN.md §11): recommended validate_lag D
    # (1 = classic sync-per-compare) and its AET at the chosen MTBE
    validate_lag: int = 1
    deferred_aet_hours: float = 0.0
    # tiered-checkpoint axis (DESIGN.md §12): recommended per-tier save
    # cadence in steps (device/host/disk/partner; empty when t_step is
    # unparameterized) and the hierarchy's AET at the chosen MTBE
    tier_schedule: Dict[str, int] = field(default_factory=dict)
    tiered_aet_hours: float = 0.0
    # serving axis (DESIGN.md §13): recommended deferred window for the
    # continuous-batching decode loop, plus the goodput/availability of
    # per-request recovery vs whole-batch recovery at that window
    serve_validate_lag: int = 1
    serve_goodput: float = 1.0          # per-request recovery, at the lag
    serve_goodput_whole_batch: float = 1.0
    serve_availability: float = 1.0


def advise(p: tm.SedarParams, mtbe_hours: float,
           X_expected: float = 0.5, k_expected: int = 0,
           serve_slots: int = 8) -> Advice:
    """Pick the minimum-AET strategy.

    X_expected: where faults are typically detected (0.5 if unknown —
    uniform detection instant, the paper's average-case assumption).
    k_expected: typical extra rollbacks for L2 (0 when the detection latency
    is usually inside one interval).
    serve_slots: continuous-batching slot count used for the serving
    goodput/lag guidance (only meaningful when t_step/t_sync are set)."""
    # tune t_i by Daly for the two checkpointing strategies
    ti_sys = max(tm.daly_interval(p.t_cs, mtbe_hours), p.t_cs * 4)
    ti_app = max(tm.daly_interval(p.t_ca + p.T_compA, mtbe_hours),
                 (p.t_ca + p.T_compA) * 4)

    p_sys = dataclasses.replace(p, t_i=ti_sys, n=None)
    p_app = dataclasses.replace(p, t_i=ti_app, n=None)

    aets = {
        "detection": tm.aet_strategy(p, "detection", mtbe_hours, X=X_expected),
        "multi_ckpt": tm.aet_strategy(p_sys, "multi_ckpt", mtbe_hours,
                                      k=k_expected),
        "single_ckpt": tm.aet_strategy(p_app, "single_ckpt", mtbe_hours),
    }
    best = min(aets, key=aets.get)
    level = {"detection": 1, "multi_ckpt": 2, "single_ckpt": 3}[best]
    t_i = {"detection": 0.0, "multi_ckpt": ti_sys, "single_ckpt": ti_app}[best]

    notes = []
    if p.T_prog < 4 * max(p.t_cs, p.t_ca):
        notes.append("short run: checkpointing overhead may dominate "
                     "(paper: 'if the execution is too short, checkpoints "
                     "become worthless')")

    # duplication-vs-ABFT guidance (orthogonal to the checkpoint level: the
    # abft/hybrid backends compose with L0-L3 recovery unchanged)
    abft = tm.aet_strategy(p, "abft", mtbe_hours, X=X_expected)
    mech = "abft" if abft < aets[best] else "duplication"
    if mech == "abft":
        notes.append(
            f"ABFT detection beats duplicated execution here "
            f"({abft:.2f}h vs {aets[best]:.2f}h AET): replica-free "
            f"checksummed kernels with forward correction of "
            f"{p.abft_correct_frac:.0%} of detected faults; pair with the "
            f"'hybrid' backend so escaped faults still hit the fingerprint "
            f"boundary")
    else:
        notes.append(
            "duplicated execution wins: coverage is total (any divergence) "
            "while ABFT only sees checksummed kernels; keep replication")

    # deferred-validation guidance (DESIGN.md §11): how far the per-step
    # predicate readback should lag execution. Needs the measured per-step
    # duration and host-sync cost; D=1 (classic) when unparameterized.
    lag = tm.optimal_validate_lag(p, mtbe_hours, X=X_expected)
    deferred_aet = tm.aet_deferred(p, lag, mtbe_hours, X=X_expected) \
        if lag > 1 else aets["detection"]
    if lag > 1:
        notes.append(
            f"defer validation by D={lag} steps (validate_lag): saves "
            f"{tm.deferred_sync_savings(p, lag):.3f}h of per-step syncs vs "
            f"an expected {tm.deferred_waste(p, lag):.3f}h re-executed per "
            f"fault; requires a checkpointing level (L2/L3) so rollback can "
            f"reach inside the window")

    # tiered-checkpoint guidance (DESIGN.md §12): per-tier save cadence
    # from each tier's own store cost (Daly per tier), and the hierarchy's
    # AET — rollback is served by the cheapest tier covering the detection
    # lag, so the flat-store t_r term mostly disappears
    tier_costs = tm.default_tier_costs(p)
    tier_sched = tm.optimal_tier_schedule(p, tier_costs, mtbe_hours,
                                          lag_steps=max(lag, 1))
    tiered_aet = 0.0
    if tier_sched:
        tiered_aet = tm.aet_tiered(p, tier_sched, tier_costs, mtbe_hours,
                                   X=X_expected, lag_steps=max(lag, 1))
        src = tm.restore_tier(tier_sched, tier_costs, max(lag, 1))
        notes.append(
            f"tier schedule (ckpt_tiers): device every "
            f"{tier_sched['device']} step(s), host every "
            f"{tier_sched['host']}, disk every {tier_sched['disk']}, "
            f"partner every {tier_sched['partner']} — expected restores "
            f"from the {src!r} tier, AET {tiered_aet:.2f}h vs flat-disk "
            f"{aets['multi_ckpt']:.2f}h")

    # serving guidance (DESIGN.md §13): deferred window + per-request
    # recovery scope for the continuous-batching decode loop. The per-fault
    # discard is one SLOT's window instead of the whole batch's, so the
    # optimal serving lag is at least the training one and the goodput gap
    # vs whole-batch recovery widens with the slot count.
    serve_lag = tm.optimal_serve_lag(p, mtbe_hours, serve_slots)
    serve_good = tm.serve_goodput(p, mtbe_hours, serve_slots, serve_lag,
                                  per_request=True)
    serve_good_wb = tm.serve_goodput(p, mtbe_hours, serve_slots, serve_lag,
                                     per_request=False)
    serve_avail = tm.serve_availability(p, mtbe_hours, serve_slots,
                                        serve_lag, per_request=True)
    if p.t_step > 0 and p.t_sync > 0:
        notes.append(
            f"serving ({serve_slots} slots): validate_lag D={serve_lag}, "
            f"per-request recovery goodput {serve_good:.4f} vs whole-batch "
            f"{serve_good_wb:.4f}; availability {serve_avail:.4f}")
    return Advice(
        strategy=best,
        level=level,
        t_i=t_i,
        aet_hours={k: round(v, 4) for k, v in aets.items()},
        start_checkpointing_at=tm.min_progress_for_checkpointing(p_sys),
        keep_two_checkpoints_at=tm.min_progress_for_k(p_sys, 1),
        notes="; ".join(notes),
        detection_mechanism=mech,
        abft_aet_hours=round(abft, 4),
        validate_lag=lag,
        deferred_aet_hours=round(deferred_aet, 4),
        tier_schedule=tier_sched,
        tiered_aet_hours=round(tiered_aet, 4),
        serve_validate_lag=serve_lag,
        serve_goodput=round(serve_good, 6),
        serve_goodput_whole_batch=round(serve_good_wb, 6),
        serve_availability=round(serve_avail, 6),
    )


# ---------------------------------------------------------------------------
# Degraded-mode policy — what to do with the survivors after a node loss
# (DESIGN.md §16; the spatial analogue of Sec. 4.4's rollback-vs-restart)
# ---------------------------------------------------------------------------

@dataclass
class DegradedModeDecision:
    """Outcome of `choose_degraded_mode` for one node-loss incident.

    mode: "fail_in_place" — keep running on the survivors (shrunken data
    axis, or unprotected-but-checkpointed when the lost node was the
    replica pod) and regrow when the host returns; "safe_stop" — park the
    job on its last validated checkpoint and wait for a relaunch."""

    mode: str                         # fail_in_place | safe_stop
    protection_lost: bool             # did the outage take the replica pod?
    fail_in_place_hours: float        # modeled cost of riding it out
    restart_hours: float              # modeled cost of stop-and-relaunch
    expected_faults_during_outage: float
    notes: str = ""


def choose_degraded_mode(p: tm.SedarParams, mtbe_hours: float,
                         outage_hours: float, *,
                         protection_lost: bool = False,
                         sdc_risk_budget: float = 1.0,
                         keep_degraded: bool = False) -> DegradedModeDecision:
    """Fail-in-place vs safe-stop for a node outage of `outage_hours`.

    Two gates, in order:
      1. SDC risk — when the lost node removes the replica pod, the
         survivors run WITHOUT detection; the expected number of soft
         errors during the outage (outage/MTBE) must stay under
         `sdc_risk_budget` or the only safe answer is to stop (an
         undetected fault would silently corrupt every later checkpoint).
      2. Cost — fail-in-place pays two remesh transitions (shrink+regrow)
         and, because the authoritative trajectory re-anchors at the last
         full-width checkpoint, replays the degraded span; stop-and-
         relaunch pays the outage plus a full T_rest. The cheaper side
         wins (`tm.fail_in_place_beats_restart`) — the same convenience
         rule as `rollback_beats_restart` (Eq. 14 vs Eq. 4), applied to
         space instead of time."""
    exp_faults = (outage_hours / mtbe_hours) if mtbe_hours > 0 else \
        float("inf")
    fip = tm.fail_in_place_cost(p, outage_hours, keep_degraded=keep_degraded)
    rst = tm.node_restart_cost(p, outage_hours)
    notes = []
    if protection_lost and exp_faults > sdc_risk_budget:
        notes.append(
            f"replica pod lost and expected faults during the outage "
            f"({exp_faults:.2f}) exceed the SDC risk budget "
            f"({sdc_risk_budget:.2f}): unprotected survivors would risk "
            f"silent corruption of every checkpoint cut while degraded — "
            f"safe-stop on the last validated checkpoint")
        return DegradedModeDecision(
            mode="safe_stop", protection_lost=True,
            fail_in_place_hours=fip, restart_hours=rst,
            expected_faults_during_outage=exp_faults,
            notes="; ".join(notes))
    if protection_lost:
        notes.append(
            f"replica pod lost but expected faults {exp_faults:.2f} <= "
            f"budget {sdc_risk_budget:.2f}: survivors run unprotected-but-"
            f"checkpointed; the regrown full-width replay re-validates")
    mode = "fail_in_place" if fip <= rst else "safe_stop"
    notes.append(
        f"fail-in-place {fip:.3f}h vs stop-and-relaunch {rst:.3f}h "
        f"(2×remesh vs T_rest — cf. rollback_beats_restart, Eq.14 vs Eq.4)")
    return DegradedModeDecision(
        mode=mode, protection_lost=protection_lost,
        fail_in_place_hours=fip, restart_hours=rst,
        expected_faults_during_outage=exp_faults,
        notes="; ".join(notes))


# ---------------------------------------------------------------------------
# Engine factory — the one place engines are assembled
# ---------------------------------------------------------------------------

def make_engine(sedar_cfg, *, backend: Optional[str] = None,
                step_fn: Optional[Callable] = None,
                state_fp_fn: Optional[Callable] = None,
                fast_state_fp_fn: Optional[Callable] = None,
                pod_step: Optional[Callable] = None,
                pod_validate: Optional[Callable] = None,
                pod_broadcaster: Optional[Callable] = None,
                n_replicas: int = 2,
                lane_hosts: Optional[Callable] = None,
                recovery: Any = None, workdir: Optional[str] = None,
                schedule: Any = None, watchdog: Any = None,
                inj_spec: Any = None, inj_flag: Any = None,
                init_fn: Optional[Callable] = None,
                notify: Optional[Callable] = None,
                delay_source: Optional[Callable[[], dict]] = None,
                donate: bool = True, slots: Optional[int] = None):
    """Assemble a `SedarEngine` for one workload.

    backend: "none" | "sequential" | "fused" | "pod" | "vote" | "abft" |
    "hybrid" (defaults to sedar_cfg.replication). Sequential/fused/plain/
    abft/hybrid backends need `step_fn` + `state_fp_fn`; pod/vote need the
    prebuilt shard_map'd `pod_step` / `pod_validate` (+ `pod_broadcaster`
    for vote). "fused" runs both time-redundant replicas in ONE vmapped jit
    with the compare predicate on device (the zero-sync hot path, DESIGN.md
    §11; `donate` controls stacked-state buffer donation); step_fn must be
    vmappable over (state, replica_id). `slots=N` selects the SLOT-GRANULAR
    variants of the sequential/fused backends (continuous-batching serving,
    DESIGN.md §13): step_fn then returns a PER-SLOT fingerprint (N, 4) and
    commit mismatches are localized to sequence slots and partially
    committed. abft/hybrid run replica-free:
    step_fn may return a 4th element (an `abft.ref.AbftReport` from
    checksummed kernels) and hybrid additionally validates the commit-time
    state fingerprint at the FSC boundary. `recovery`/`schedule`/`watchdog`
    default from the config (recovery needs `workdir`)."""
    from repro.core.engine import (BoundarySchedule, FusedSequentialExecutor,
                                   PlainExecutor, PodExecutor, SedarEngine,
                                   SequentialExecutor,
                                   SlottedFusedExecutor,
                                   SlottedSequentialExecutor, VoteExecutor)
    from repro.core.detection import Watchdog
    from repro.core.recovery import make_recovery

    backend = backend or getattr(sedar_cfg, "replication", "sequential")
    schedule = schedule or BoundarySchedule.from_config(sedar_cfg)
    watchdog = watchdog or Watchdog(schedule.toe_timeout_s)
    if recovery is None:
        recovery = make_recovery(sedar_cfg, workdir)

    if backend in ("pod", "vote"):
        if pod_step is None or pod_validate is None:
            raise ValueError(f"backend {backend!r} needs pod_step and "
                             "pod_validate")
        if backend == "vote":
            if pod_broadcaster is None:
                raise ValueError("vote backend needs pod_broadcaster")
            executor = VoteExecutor(pod_step, pod_validate, state_fp_fn,
                                    pod_broadcaster,
                                    n_replicas=max(n_replicas, 3))
        else:
            executor = PodExecutor(pod_step, pod_validate, state_fp_fn,
                                   lane_hosts=lane_hosts)
    elif backend in ("abft", "hybrid"):
        if step_fn is None or state_fp_fn is None:
            raise ValueError(f"backend {backend!r} needs step_fn and "
                             "state_fp_fn")
        from repro.abft.executor import AbftExecutor
        executor = AbftExecutor(step_fn, state_fp_fn,
                                fast_state_fp_fn=fast_state_fp_fn,
                                hybrid=(backend == "hybrid"),
                                validate_interval=schedule.validate_interval)
    elif backend == "fused":
        if step_fn is None or state_fp_fn is None:
            raise ValueError("backend 'fused' needs step_fn and state_fp_fn")
        if slots:
            executor = SlottedFusedExecutor(
                step_fn, state_fp_fn, fast_state_fp_fn=fast_state_fp_fn,
                watchdog=watchdog, donate=donate, n_slots=slots)
        else:
            executor = FusedSequentialExecutor(
                step_fn, state_fp_fn, fast_state_fp_fn=fast_state_fp_fn,
                watchdog=watchdog, donate=donate)
    elif backend == "none":
        executor = PlainExecutor(step_fn, state_fp_fn)
    elif slots:
        executor = SlottedSequentialExecutor(
            step_fn, state_fp_fn, fast_state_fp_fn=fast_state_fp_fn,
            watchdog=watchdog, toe_timeout_s=schedule.toe_timeout_s,
            delay_source=delay_source, n_slots=slots)
    else:
        executor = SequentialExecutor(
            step_fn, state_fp_fn, fast_state_fp_fn=fast_state_fp_fn,
            watchdog=watchdog, toe_timeout_s=schedule.toe_timeout_s,
            delay_source=delay_source)

    return SedarEngine(executor, schedule, recovery, watchdog=watchdog,
                       inj_spec=inj_spec, inj_flag=inj_flag, init_fn=init_fn,
                       notify=notify)


def make_trainer(run_cfg, workdir: str, **kw):
    """Construct a SEDAR-protected trainer (engine assembled internally via
    `make_engine`)."""
    from repro.runtime.train import SedarTrainer
    return SedarTrainer(run_cfg, workdir, **kw)


def make_server(run_cfg, *, dual: bool = False, inj_spec: Any = None, **kw):
    """Construct a SEDAR-protected server (engine assembled internally via
    `make_engine`)."""
    from repro.runtime.serve import SedarServer
    return SedarServer(run_cfg, dual=dual, inj_spec=inj_spec, **kw)


# ---------------------------------------------------------------------------
# Closed-loop autotuning (DESIGN.md §17)
# ---------------------------------------------------------------------------

@dataclass
class AutotuneConfig:
    """Knobs of the control loop itself (the meta-knobs)."""

    interval_steps: int = 16        # evaluate every N protected steps
    persistence: int = 2            # consecutive evals agreeing on a target
                                    # before it is applied (anti-flap)
    mode: str = "train"             # "train" | "serve" (which optimum)
    serve_slots: int = 8
    X_expected: float = 0.5
    min_confidence: float = 0.25    # below this the estimator stays advisory
    prior_mtbe_hours: float = 24.0
    backend: str = "sequential"     # current detection backend (for advice)
    slo_availability: Optional[float] = None   # e.g. 0.999
    slo_goodput: Optional[float] = None


def autotune(engine, snapshot, *, mode: str = "train", serve_slots: int = 8,
             X: float = 0.5, lag: Optional[int] = None,
             reason: Optional[str] = None) -> Optional[Dict[str, Any]]:
    """One-shot re-plan: recompute the optimal knobs from a calibrated
    snapshot (`obs.OnlineEstimator.calibrated_params()`) and apply them via
    `engine.apply_reconfig()`. Returns the reconfig record, or None when
    nothing changed / the engine is mid-window (caller retries at the next
    flush boundary)."""
    p, mtbe = snapshot.params, snapshot.mtbe_hours
    if lag is None:
        lag = (tm.optimal_serve_lag(p, mtbe, serve_slots)
               if mode == "serve"
               else tm.optimal_validate_lag(p, mtbe, X=X))
    tier_schedule = None
    tiers = getattr(engine.recovery, "tiers", None)
    if tiers is not None:
        sched = tm.optimal_tier_schedule(p, snapshot.tier_costs, mtbe,
                                         lag_steps=max(lag, 1))
        if sched:
            from repro.checkpoint.tiers import TierSchedule
            cur = tiers.schedule
            # only retune cadences of tiers the run enabled — the tuner
            # must not conjure a partner store the launcher never set up
            tier_schedule = TierSchedule(**{
                t: (int(sched.get(t, 0)) if cur.interval(t) > 0 else 0)
                for t in ("device", "host", "disk", "partner")})
    if reason is None:
        reason = (f"autotune[{mode}]: mtbe={mtbe:.4g}h "
                  f"t_step={p.t_step:.4g}h t_sync={p.t_sync:.4g}h "
                  f"confidence={snapshot.confidence:.2f}")
    return engine.apply_reconfig(validate_lag=lag,
                                 tier_schedule=tier_schedule, reason=reason)


class Autotuner:
    """Periodic estimate → detect → re-advise → reconfigure loop.

    Call `maybe_tune(engine, step)` after every protected step; it is a
    no-op except every `interval_steps`, and even then it only reads
    host-side aggregates (registry histograms, journal records) — never a
    device buffer — so the §11/§15 zero-extra-hostsync contract is
    untouched (asserted in tests via `count_transfers`).

    Safety: knob changes go through `engine.apply_reconfig()` (clean
    deferred-flush boundaries only, engine clamps re-applied) and are
    double-gated here by an estimator-confidence floor and a persistence
    count — the tuner must see the SAME target on `persistence`
    consecutive evaluations before acting, so estimation noise cannot
    flap the window. One exception: when the fault-rate change-point
    detector fires, the environment shift is CONFIRMED (not noise — the
    exact case persistence exists to filter), so the next retarget skips
    the persistence wait and lands at the first clean boundary. Backend
    advice (duplication vs ABFT) is surfaced as an advisory alert only:
    swapping executors mid-run would re-trace.
    """

    def __init__(self, base_params: tm.SedarParams,
                 cfg: Optional[AutotuneConfig] = None):
        from repro.obs.alerts import AlertManager, SloTracker
        from repro.obs.anomaly import AnomalyMonitor
        from repro.obs.estimator import OnlineEstimator
        self.cfg = cfg or AutotuneConfig()
        self.estimator = OnlineEstimator(
            base_params, prior_mtbe_hours=self.cfg.prior_mtbe_hours)
        self.monitor = AnomalyMonitor()
        self.alerts = AlertManager()
        self.slos = []
        if self.cfg.slo_availability:
            self.slos.append(SloTracker("availability",
                                        self.cfg.slo_availability))
        if self.cfg.slo_goodput:
            self.slos.append(SloTracker("goodput", self.cfg.slo_goodput))
        self.evaluations = 0
        self._pending_target: Optional[int] = None
        self._pending_count = 0
        self._last_det_count = 0
        self._burst = False     # fault-rate change-point fired: the next
                                # retarget skips the persistence wait

    # -- the periodic tick ---------------------------------------------------

    def maybe_tune(self, engine, step: int) -> Optional[Dict[str, Any]]:
        cfg = self.cfg
        if step <= 0 or step % cfg.interval_steps != 0:
            return None
        from repro import obs
        self.evaluations += 1
        self.estimator.ingest(
            obs.metrics if obs.metrics_enabled() else None,
            obs.get_journal())
        snap = self.estimator.calibrated_params()
        self._watch(engine, step, snap)
        if snap.confidence < cfg.min_confidence:
            return None
        return self._retune(engine, step, snap)

    # -- drift / SLO surveillance -------------------------------------------

    def _watch(self, engine, step: int, snap) -> None:
        from repro.obs.alerts import Alert
        cfg, p = self.cfg, snap.params
        fired = []
        if p.t_step > 0:
            fired += self.monitor.update("step_time", p.t_step)
        if p.t_sync > 0:
            fired += self.monitor.update("sync_time", p.t_sync)
        disk = snap.tier_costs.get("disk")
        if disk is not None and snap.sample_counts.get("tier_save_disk"):
            fired += self.monitor.update("checkpoint_cost", disk.t_save)
        # fault-rate bursts: detections per evaluation window
        ndet = snap.sample_counts.get("detections", 0)
        new_det = ndet - self._last_det_count
        self._last_det_count = ndet
        fired += self.monitor.update("fault_rate", float(new_det))
        if any(a["stream"] == "fault_rate" for a in fired):
            self._burst = True
        # SLO burn: the replay proxy — a fault discards up to lag/2 of the
        # window's steps, so delivered fraction over this interval is
        # 1 - faults*(lag/2)/interval (floored at 0)
        lag = max(engine.validate_lag, 1)
        good = max(0.0, 1.0 - new_det * (lag / 2.0) / cfg.interval_steps)
        for slo in self.slos:
            alert = slo.update(step, good)
            if alert is not None:
                self.alerts.emit(alert)
        # journal-vs-prediction divergence: observed delivered fraction
        # against what the calibrated model predicts at this lag
        if p.t_step > 0 and p.t_sync > 0:
            pred = tm.serve_availability(p, snap.mtbe_hours,
                                         max(cfg.serve_slots, 1), lag)
            fired += self.monitor.update("kpi_divergence", good - pred)
        for a in fired:
            self.alerts.emit(Alert(
                name=f"{a['stream']}_drift", severity="warning", step=step,
                message=(f"{a['stream']} drift flagged by {a['detector']} "
                         f"at value {a['value']:.6g}"),
                detail=dict(a)))

    # -- re-advise + apply ---------------------------------------------------

    def _retune(self, engine, step: int, snap) -> Optional[Dict[str, Any]]:
        cfg = self.cfg
        self._advise_backend(step, snap)
        p, mtbe = snap.params, snap.mtbe_hours
        target = (tm.optimal_serve_lag(p, mtbe, cfg.serve_slots)
                  if cfg.mode == "serve"
                  else tm.optimal_validate_lag(p, mtbe, X=cfg.X_expected))
        if target == engine.validate_lag:
            self._pending_target, self._pending_count = None, 0
            self._burst = False
            return None
        if target == self._pending_target:
            self._pending_count += 1
        else:
            self._pending_target, self._pending_count = target, 1
        if self._pending_count < cfg.persistence and not self._burst:
            return None
        if engine.pending_validation:
            # mid-window: keep the pending vote, retry at the next eval
            # (the engine would refuse anyway; this keeps hysteresis state)
            return None
        rec = autotune(engine, snap, mode=cfg.mode,
                       serve_slots=cfg.serve_slots, X=cfg.X_expected,
                       lag=target)
        if rec is not None:
            self._pending_target, self._pending_count = None, 0
            self._burst = False
        return rec

    def _advise_backend(self, step: int, snap) -> None:
        from repro.obs.alerts import Alert
        cfg, p = self.cfg, snap.params
        dup = tm.aet_strategy(p, "detection", snap.mtbe_hours,
                              X=cfg.X_expected)
        abft = tm.aet_strategy(p, "abft", snap.mtbe_hours, X=cfg.X_expected)
        abft_wins = abft < dup
        using_abft = cfg.backend in ("abft", "hybrid")
        if abft_wins != using_abft:
            better, worse = ("abft", dup) if abft_wins else ("duplication",
                                                             abft)
            self.alerts.emit(Alert(
                name="backend_advice", severity="info", step=step,
                message=(f"calibrated model prefers {better} detection "
                         f"(AET {min(dup, abft):.4g}h vs {worse:.4g}h) — "
                         f"advisory only; restart with the recommended "
                         f"backend to apply"),
                detail={"current": cfg.backend,
                        "recommended": better,
                        "aet_duplication_h": round(dup, 6),
                        "aet_abft_h": round(abft, 6)}))
