"""SEDAR recovery strategies (paper Secs. 3.1-3.3, Algorithms 1 and 2).

  L1  SafeStop                    detection + notification + safe stop
  L2  MultiCheckpointRecovery     chain of system-level checkpoints, rollback
                                  until the fault stops re-manifesting (Alg. 1)
  L3  ValidatedCheckpointRecovery single replica-validated app-level
                                  checkpoint, at most one rollback (Alg. 2)

System-level (L2) checkpoints snapshot the FULL dual state (both replicas'
params/opt/step) — exactly like DMTCP snapshotting both threads — so a
checkpoint taken after a silent corruption still contains the replica
divergence, and the fault re-manifests after restore (the paper's "dirty
checkpoint" case, forcing extern_counter to advance). Application-level (L3)
checkpoints store ONE replica's state, which is safe because it is committed
only after the replica fingerprints were proven equal.

The rollback counter lives OUTSIDE the checkpoint payload
(`rollbacks.json`, the paper's failures.txt) so it survives restores.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.core.detection import DetectionEvent, SedarSafeStop


class ExternalCounter:
    """paper Sec. 4.2: failures.txt — external to the checkpoint storage."""

    def __init__(self, path: str):
        self.path = path
        if not os.path.exists(path):
            self._write(0)

    def _write(self, v: int) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"count": v}, f)

    def value(self) -> int:
        with open(self.path) as f:
            return json.load(f)["count"]

    def increment(self) -> int:
        v = self.value() + 1
        self._write(v)
        return v

    def reset(self) -> None:
        self._write(0)


@dataclass
class RecoveryAction:
    kind: str                      # stop | restore | restart_scratch
    step: Optional[int] = None     # checkpoint version to restore
    rollbacks: int = 0             # extern_counter value after this detection
    event: Optional[DetectionEvent] = None


# ---------------------------------------------------------------------------
# L1
# ---------------------------------------------------------------------------

class SafeStop:
    """Detection with notification: lead the system to a safe stop, never
    deliver defective results (paper Sec. 3.1)."""

    level = 1

    def __init__(self, notify: Optional[Callable[[DetectionEvent], None]] = None):
        self.notify = notify or (lambda e: print(str(e), flush=True))

    def maybe_checkpoint(self, step, dual_state, fingerprints=None) -> bool:
        return False   # L1 stores no checkpoints

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        self.notify(event)
        return RecoveryAction(kind="stop", event=event)


# ---------------------------------------------------------------------------
# L2 — Algorithm 1
# ---------------------------------------------------------------------------

class MultiCheckpointRecovery:
    """Recovery from a chain of system-level checkpoints (paper Alg. 1).

        extern_counter++                      # on each detection
        ckpt_no = ckpt_count - extern_counter # 1-based from the end
        restore(ckpt_no)                      # or restart from scratch

    The chain is never pruned (any checkpoint may be dirty); an optional
    bounded-chain mode (`max_checkpoints`) exists for storage-limited runs and
    is recorded as a deviation when used.
    """

    level = 2

    def __init__(self, store: CheckpointStore, counter_path: str,
                 checkpoint_interval: int, max_checkpoints: int = 0,
                 async_: bool = True):
        self.store = store
        self.counter = ExternalCounter(counter_path)
        self.interval = checkpoint_interval
        self.max_checkpoints = max_checkpoints
        self.async_ = async_

    def maybe_checkpoint(self, step: int, dual_state, fingerprints=None,
                         validated_floor: Optional[int] = None) -> bool:
        """Cut a system-level checkpoint right after a validated commit
        (paper: 'the best moments to take them are when the communications
        have just been validated').

        `validated_floor` is the engine's validation frontier (first step
        not yet proven fault-free). Deferred validation (DESIGN.md §11)
        requires the bounded-chain GC to RETAIN at least one checkpoint no
        newer than that frontier — i.e. older than every unvalidated step —
        or a fault inside the window could outlive every rollback target."""
        if step == 0 or step % self.interval != 0:
            return False
        self.store.save(step, dual_state, kind="system", valid=None,
                        fingerprint=fingerprints, async_=self.async_)
        if self.max_checkpoints:
            self.store.gc_keep_last(self.max_checkpoints,
                                    keep_floor=validated_floor)
        return True

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        """Paper Alg. 1 mapping, audited against the 1-based pseudo-code:

            extern_counter ∈ {1..}         (incremented before the lookup)
            ckpt_no  = ckpt_count - extern_counter + 1     (1-based from start)
            restore ckpt_no                 -> 0-based steps[ckpt_count - counter]
            ckpt_no < 1  (counter > count)  -> relaunch from the beginning

        First detection restores the NEWEST checkpoint (possibly dirty);
        each re-detection walks one version further back. `store.steps()`
        barriers pending async writes, so ckpt_count is exact even when the
        detection lands right after an async checkpoint boundary. Versions
        re-cut during re-execution overwrite their step slot, keeping the
        counter↔version mapping stable across rollbacks."""
        rollbacks = self.counter.increment()
        steps = self.store.steps()
        idx = len(steps) - rollbacks          # ckpt_count - extern_counter
        if idx < 0:
            # extern_counter exceeded the chain: the fault predates the first
            # remaining checkpoint — relaunch from the beginning (paper
            # Fig. 2a, particular case). idx == 0 still restores steps[0].
            return RecoveryAction(kind="restart_scratch", rollbacks=rollbacks,
                                  event=event)
        return RecoveryAction(kind="restore", step=steps[idx],
                              rollbacks=rollbacks, event=event)

    def restore(self, action: RecoveryAction, template):
        return self.store.restore(action.step, template)


# ---------------------------------------------------------------------------
# L3 — Algorithm 2
# ---------------------------------------------------------------------------

class ValidatedCheckpointRecovery:
    """Single safe application-level checkpoint (paper Alg. 2).

    At each boundary both replicas' state fingerprints are compared (the same
    machinery that validates messages). Equal -> the checkpoint is VALID: it
    is committed and the previous one deleted (exactly one valid checkpoint
    exists). Different -> the would-be checkpoint is corrupted: nothing is
    stored and recovery rolls back (at most once) to the previous valid one.
    """

    level = 3

    def __init__(self, store: CheckpointStore, checkpoint_interval: int,
                 async_: bool = False):
        # NB async_=False by default: the validity protocol commits the
        # previous-version delete only after the new version is durable.
        self.store = store
        self.interval = checkpoint_interval
        self.async_ = async_

    def maybe_checkpoint(self, step: int, dual_state, fingerprints=None,
                         fp_equal: Optional[bool] = None) -> Optional[DetectionEvent]:
        """Returns None if no boundary; a DetectionEvent if the checkpoint
        validation FAILED (corrupted state, paper line 16); otherwise commits.

        `fp_equal` is the replica state-fingerprint comparison computed by the
        runtime (in-jit); `dual_state` must carry replica 0's state under
        'r0'. Only r0 is stored (provably equal to r1 when fp_equal)."""
        if step == 0 or step % self.interval != 0:
            return None
        if fp_equal is None:
            raise ValueError("L3 checkpointing requires the replica "
                             "state-fingerprint comparison")
        if not bool(fp_equal):
            return DetectionEvent(step=step, boundary="ckpt_validate",
                                  effect="FSC",
                                  detail={"reason": "app-level checkpoint "
                                          "hash mismatch (corrupted)"})
        prev = self.store.latest(valid_only=True)
        self.store.save(step, dual_state["r0"], kind="app", valid=True,
                        fingerprint=fingerprints, async_=self.async_)
        self.store.wait()
        if prev is not None and prev != step:
            self.store.delete(prev)   # "the previous can be discarded"
        return None

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        target = self.store.latest(valid_only=True)
        if target is None:
            return RecoveryAction(kind="restart_scratch", rollbacks=1,
                                  event=event)
        return RecoveryAction(kind="restore", step=target, rollbacks=1,
                              event=event)

    def restore(self, action: RecoveryAction, template_single):
        """Returns the single validated state (callers re-duplicate it into
        both replicas — valid by construction)."""
        return self.store.restore(action.step, template_single)


# ---------------------------------------------------------------------------
# L0-style re-execution (serving / transient-only workloads)
# ---------------------------------------------------------------------------

class RetryRecovery:
    """Pure re-execution recovery for workloads whose step is cheap to redo
    (the serving decode path: 'recovery is trivial — recompute the step').

    No checkpoints are stored; every detection yields a `retry` action,
    recorded through the same external-counter accounting machinery as
    L2/L3 (the optional `counter_path` persists the cumulative retry count;
    `rollbacks` carries the CONSECUTIVE retry count for this step), so
    drivers get retry budgeting and reporting for free instead of a bespoke
    guard loop. The budget is consecutive-failure based: a committed step
    resets it (`note_success`, called by the engine), so sporadic
    transients over a long stream never exhaust it. Only `max_retries`
    consecutive failures — a persistent divergence, not a transient fault —
    degrade to the L1 safe stop."""

    level = 0

    def __init__(self, max_retries: int = 8,
                 counter_path: Optional[str] = None):
        self.max_retries = max_retries
        self.counter = ExternalCounter(counter_path) if counter_path else None
        self._consecutive = 0

    def maybe_checkpoint(self, step, dual_state, fingerprints=None) -> bool:
        return False   # nothing to store: re-execution needs no state

    def reset(self) -> None:
        self._consecutive = 0
        if self.counter is not None:
            self.counter.reset()

    def note_success(self) -> None:
        """A step committed: whatever failed before was transient."""
        self._consecutive = 0

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        self._consecutive += 1
        if self.counter is not None:
            self.counter.increment()        # cumulative record (failures.txt)
        if self.max_retries and self._consecutive > self.max_retries:
            return RecoveryAction(kind="stop", rollbacks=self._consecutive,
                                  event=event)
        return RecoveryAction(kind="retry", rollbacks=self._consecutive,
                              event=event)


def make_recovery(sedar_cfg, workdir: Optional[str] = None):
    d = workdir or sedar_cfg.checkpoint_dir
    store = CheckpointStore(os.path.join(d, "checkpoints"))
    if sedar_cfg.level <= 1:
        return SafeStop()
    if sedar_cfg.level == 2:
        return MultiCheckpointRecovery(
            store, os.path.join(d, "rollbacks.json"),
            sedar_cfg.checkpoint_interval, sedar_cfg.max_checkpoints,
            async_=sedar_cfg.async_checkpoint)
    return ValidatedCheckpointRecovery(store, sedar_cfg.checkpoint_interval)
