"""SEDAR recovery strategies (paper Secs. 3.1-3.3, Algorithms 1 and 2).

  L1  SafeStop                    detection + notification + safe stop
  L2  MultiCheckpointRecovery     chain of system-level checkpoints, rollback
                                  until the fault stops re-manifesting (Alg. 1)
  L3  ValidatedCheckpointRecovery single replica-validated app-level
                                  checkpoint, at most one rollback (Alg. 2)

System-level (L2) checkpoints snapshot the FULL dual state (both replicas'
params/opt/step) — exactly like DMTCP snapshotting both threads — so a
checkpoint taken after a silent corruption still contains the replica
divergence, and the fault re-manifests after restore (the paper's "dirty
checkpoint" case, forcing extern_counter to advance). Application-level (L3)
checkpoints store ONE replica's state, which is safe because it is committed
only after the replica fingerprints were proven equal.

The rollback counter lives OUTSIDE the checkpoint payload
(`rollbacks.json`, the paper's failures.txt) so it survives restores.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import numpy as np

from repro.checkpoint.delta import DeltaCheckpointStore
from repro.checkpoint.store import CheckpointStore
from repro.checkpoint.tiers import TieredCheckpointer, make_tiered
from repro.core import hostsync
from repro.core.detection import DetectionEvent, SedarSafeStop


class ExternalCounter:
    """paper Sec. 4.2: failures.txt — external to the checkpoint storage."""

    def __init__(self, path: str):
        self.path = path
        if not os.path.exists(path):
            self._write(0)

    def _write(self, v: int) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"count": v}, f)

    def value(self) -> int:
        with open(self.path) as f:
            return json.load(f)["count"]

    def increment(self) -> int:
        v = self.value() + 1
        self._write(v)
        return v

    def reset(self) -> None:
        self._write(0)


@dataclass
class RecoveryAction:
    kind: str                      # stop | restore | restart_scratch
    step: Optional[int] = None     # checkpoint version to restore
    rollbacks: int = 0             # extern_counter value after this detection
    event: Optional[DetectionEvent] = None


# ---------------------------------------------------------------------------
# L1
# ---------------------------------------------------------------------------

class SafeStop:
    """Detection with notification: lead the system to a safe stop, never
    deliver defective results (paper Sec. 3.1)."""

    level = 1

    def __init__(self, notify: Optional[Callable[[DetectionEvent], None]] = None):
        self.notify = notify or (lambda e: print(str(e), flush=True))

    def maybe_checkpoint(self, step, dual_state, fingerprints=None) -> bool:
        return False   # L1 stores no checkpoints

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        self.notify(event)
        return RecoveryAction(kind="stop", event=event)


# ---------------------------------------------------------------------------
# L2 — Algorithm 1
# ---------------------------------------------------------------------------

class MultiCheckpointRecovery:
    """Recovery from a chain of system-level checkpoints (paper Alg. 1).

        extern_counter++                      # on each detection
        ckpt_no = ckpt_count - extern_counter # 1-based from the end
        restore(ckpt_no)                      # or restart from scratch

    The chain is never pruned (any checkpoint may be dirty); an optional
    bounded-chain mode (`max_checkpoints`) exists for storage-limited runs and
    is recorded as a deviation when used.

    With `tiers` (a `TieredCheckpointer`, DESIGN.md §12) the chain spans the
    whole hierarchy: the device/host rings hold dense recent versions, the
    disk/partner stores the sparse durable ones. Algorithm 1's counter then
    walks the UNION of versions that predate the detected fault, newest
    first, and each restore routes through the cost-aware planner (cheapest
    tier holding the target version, with corruption fallback).
    """

    level = 2

    def __init__(self, store: CheckpointStore, counter_path: str,
                 checkpoint_interval: int, max_checkpoints: int = 0,
                 async_: bool = True,
                 tiers: Optional[TieredCheckpointer] = None):
        self.store = store
        self.counter = ExternalCounter(counter_path)
        self.interval = checkpoint_interval
        self.max_checkpoints = max_checkpoints
        self.async_ = async_
        self.tiers = tiers
        # planner outcome of the most recent restore() — the engine merges
        # this into its recovery record (tier, version, fallbacks)
        self.last_restore_info: Optional[dict] = None

    # -- cadence hooks (the engine gates fingerprint readbacks on these) -----

    def due(self, step: int) -> bool:
        if self.tiers is not None:
            return self.tiers.due(step)
        return self.interval > 0 and step % self.interval == 0

    def fp_needed(self, step: int) -> bool:
        """Whether this save step needs the state-fingerprint readback (a
        host sync): only manifest-writing tiers record it. Pure ring saves
        (Tier 0/1) never pay it — the zero-sync hot path extends through
        device-tier checkpointing."""
        if self.tiers is not None:
            return self.tiers.fp_needed(step)
        return self.due(step)

    def sync_due(self, step: int) -> bool:
        """Whether a DURABLE tier is due at `step` — the engine flushes the
        deferred window first so every host/disk/partner version predates
        every unvalidated step (§11 retention rule). Device-ring saves do
        NOT force a flush: their slots may hold unvalidated state by
        design, and the restore planner's max_step bound excludes them."""
        if self.tiers is not None:
            return self.tiers.sync_due(step)
        return self.due(step)

    def maybe_checkpoint(self, step: int, dual_state, fingerprints=None,
                         validated_floor: Optional[int] = None) -> bool:
        """Cut a system-level checkpoint right after a validated commit
        (paper: 'the best moments to take them are when the communications
        have just been validated').

        `validated_floor` is the engine's validation frontier (first step
        not yet proven fault-free). Deferred validation (DESIGN.md §11)
        requires the bounded-chain GC to RETAIN at least one checkpoint no
        newer than that frontier — i.e. older than every unvalidated step —
        or a fault inside the window could outlive every rollback target.
        Ring tiers are exempt from that rule (they snapshot optimistically
        every cadence, unvalidated steps included — the planner's
        `max_step` bound keeps post-fault slots out of recovery); the
        returned bool reports whether a DURABLE version was cut (what the
        engine logs as a checkpoint)."""
        if step == 0 or not self.due(step):
            return False
        if self.tiers is not None:
            saved = self.tiers.save(step, dual_state,
                                    fingerprint=fingerprints, kind="system",
                                    async_=self.async_,
                                    keep_floor=validated_floor)
            # GC only when a DURABLE store actually grew: gc_keep_last
            # scans steps() (a wait() barrier + listdir) — running it on
            # every device-ring step would re-serialize the async writer
            # into the Tier-0 hot path
            if self.max_checkpoints and \
                    any(t in ("disk", "partner") for t in saved):
                self.tiers.gc_keep_last(self.max_checkpoints,
                                        keep_floor=validated_floor)
            return any(t != "device" for t in saved)
        self.store.save(step, dual_state, kind="system", valid=None,
                        fingerprint=fingerprints, async_=self.async_)
        if self.max_checkpoints:
            self.store.gc_keep_last(self.max_checkpoints,
                                    keep_floor=validated_floor)
        return True

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        """Paper Alg. 1 mapping, audited against the 1-based pseudo-code:

            extern_counter ∈ {1..}         (incremented before the lookup)
            ckpt_no  = ckpt_count - extern_counter + 1     (1-based from start)
            restore ckpt_no                 -> 0-based steps[ckpt_count - counter]
            ckpt_no < 1  (counter > count)  -> relaunch from the beginning

        First detection restores the NEWEST checkpoint (possibly dirty);
        each re-detection walks one version further back. `store.steps()`
        barriers pending async writes, so ckpt_count is exact even when the
        detection lands right after an async checkpoint boundary. Versions
        re-cut during re-execution overwrite their step slot, keeping the
        counter↔version mapping stable across rollbacks.

        Tiered chains additionally bound the walk at the event's faulty
        step: ring tiers snapshot optimistically inside the deferred
        window, so versions NEWER than the fault exist and are corrupt by
        construction — the planner never offers them (versions <= the
        faulty step are exactly the legal Alg.-1 targets; the flat-store
        path needs no bound because durable versions are only cut after a
        clean flush)."""
        rollbacks = self.counter.increment()
        if self.tiers is not None:
            steps = [v for v in self.tiers.versions()
                     if event.step is None or v <= event.step]
        else:
            steps = self.store.steps()
        idx = len(steps) - rollbacks          # ckpt_count - extern_counter
        if idx < 0:
            # extern_counter exceeded the chain: the fault predates the first
            # remaining checkpoint — relaunch from the beginning (paper
            # Fig. 2a, particular case). idx == 0 still restores steps[0].
            return RecoveryAction(kind="restart_scratch", rollbacks=rollbacks,
                                  event=event)
        return RecoveryAction(kind="restore", step=steps[idx],
                              rollbacks=rollbacks, event=event)

    def restore(self, action: RecoveryAction, template):
        if self.tiers is not None:
            # explicit durability barrier even when a RING serves the state:
            # the flat path barriered implicitly (store.restore -> wait),
            # and a replay must never re-cut a version whose original
            # async _write is still in flight (two writers on one .tmp)
            self.tiers.wait()
            state, info = self.tiers.restore(action.step, template)
            self.last_restore_info = info
            return state
        self.last_restore_info = {"tier": "disk", "version": action.step}
        return self.store.restore(action.step, template)


# ---------------------------------------------------------------------------
# L3 — Algorithm 2
# ---------------------------------------------------------------------------

class ValidatedCheckpointRecovery:
    """Single safe application-level checkpoint (paper Alg. 2).

    At each boundary both replicas' state fingerprints are compared (the same
    machinery that validates messages). Equal -> the checkpoint is VALID: it
    is committed and the previous one deleted (exactly one valid checkpoint
    exists). Different -> the would-be checkpoint is corrupted: nothing is
    stored and recovery rolls back (at most once) to the previous valid one.

    With `tiers` the validated state is replicated into EVERY enabled tier
    at the boundary and "exactly one valid checkpoint" holds PER TIER
    (`keep_only`): restore comes from the cheapest tier (normally the
    device ring — instant, zero disk reads), with the partner store as the
    corruption fallback of last resort.
    """

    level = 3

    def __init__(self, store: CheckpointStore, checkpoint_interval: int,
                 async_: bool = False,
                 tiers: Optional[TieredCheckpointer] = None):
        # NB async_=False by default: the validity protocol commits the
        # previous-version delete only after the new version is durable.
        self.store = store
        self.interval = checkpoint_interval
        self.async_ = async_
        self.tiers = tiers
        self.last_restore_info: Optional[dict] = None

    def maybe_checkpoint(self, step: int, dual_state, fingerprints=None,
                         fp_equal: Optional[bool] = None) -> Optional[DetectionEvent]:
        """Returns None if no boundary; a DetectionEvent if the checkpoint
        validation FAILED (corrupted state, paper line 16); otherwise commits.

        `fp_equal` is the replica state-fingerprint comparison computed by the
        runtime (in-jit); `dual_state` must carry replica 0's state under
        'r0'. Only r0 is stored (provably equal to r1 when fp_equal)."""
        if step == 0 or step % self.interval != 0:
            return None
        if fp_equal is None:
            raise ValueError("L3 checkpointing requires the replica "
                             "state-fingerprint comparison")
        if not bool(fp_equal):
            return DetectionEvent(step=step, boundary="ckpt_validate",
                                  effect="FSC",
                                  detail={"reason": "app-level checkpoint "
                                          "hash mismatch (corrupted)"})
        if self.tiers is not None:
            # replicate the validated state into every tier SYNCHRONOUSLY
            # (the per-tier previous version is only discarded once the new
            # one is durable everywhere), then enforce one-valid-per-tier
            self.tiers.save(step, dual_state["r0"], kind="app", valid=True,
                            fingerprint=fingerprints, async_=False,
                            force=True)
            self.tiers.wait()
            self.tiers.keep_only(step)
            return None
        prev = self.store.latest(valid_only=True)
        self.store.save(step, dual_state["r0"], kind="app", valid=True,
                        fingerprint=fingerprints, async_=self.async_)
        self.store.wait()
        if prev is not None and prev != step:
            self.store.delete(prev)   # "the previous can be discarded"
        return None

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        target = self.tiers.latest_valid() if self.tiers is not None \
            else self.store.latest(valid_only=True)
        if target is None:
            return RecoveryAction(kind="restart_scratch", rollbacks=1,
                                  event=event)
        return RecoveryAction(kind="restore", step=target, rollbacks=1,
                              event=event)

    def restore(self, action: RecoveryAction, template_single):
        """Returns the single validated state (callers re-duplicate it into
        both replicas — valid by construction)."""
        if self.tiers is not None:
            state, info = self.tiers.restore(action.step, template_single)
            self.last_restore_info = info
            return state
        self.last_restore_info = {"tier": "disk", "version": action.step}
        return self.store.restore(action.step, template_single)


# ---------------------------------------------------------------------------
# L0-style re-execution (serving / transient-only workloads)
# ---------------------------------------------------------------------------

class RetryRecovery:
    """Pure re-execution recovery for workloads whose step is cheap to redo
    (the serving decode path: 'recovery is trivial — recompute the step').

    No checkpoints are stored; every detection yields a `retry` action,
    recorded through the same external-counter accounting machinery as
    L2/L3 (the optional `counter_path` persists the cumulative retry count;
    `rollbacks` carries the CONSECUTIVE retry count for this step), so
    drivers get retry budgeting and reporting for free instead of a bespoke
    guard loop. The budget is consecutive-failure based: a committed step
    resets it (`note_success`, called by the engine), so sporadic
    transients over a long stream never exhaust it. Only `max_retries`
    consecutive failures — a persistent divergence, not a transient fault —
    degrade to the L1 safe stop."""

    level = 0

    def __init__(self, max_retries: int = 8,
                 counter_path: Optional[str] = None):
        self.max_retries = max_retries
        self.counter = ExternalCounter(counter_path) if counter_path else None
        self._consecutive = 0

    def maybe_checkpoint(self, step, dual_state, fingerprints=None) -> bool:
        return False   # nothing to store: re-execution needs no state

    def reset(self) -> None:
        self._consecutive = 0
        if self.counter is not None:
            self.counter.reset()

    def note_success(self) -> None:
        """A step committed: whatever failed before was transient."""
        self._consecutive = 0

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        self._consecutive += 1
        if self.counter is not None:
            self.counter.increment()        # cumulative record (failures.txt)
        if self.max_retries and self._consecutive > self.max_retries:
            return RecoveryAction(kind="stop", rollbacks=self._consecutive,
                                  event=event)
        return RecoveryAction(kind="retry", rollbacks=self._consecutive,
                              event=event)


class SlotRecovery:
    """Per-REQUEST recovery for continuous-batching serving (DESIGN.md §13).

    The paper's levels, re-scoped from "the run" to "the sequence slot":

      * commit-gated slot mismatch (partial commit, `detail['partial']`):
        the faulty slots kept their pre-step image, so the action is a
        per-slot L0 retry — the next protected step re-decodes exactly
        those slots while the committed slots stream on.
      * deferred-window slot fault (`boundary='deferred'`): the corruption
        was committed optimistically up to D steps ago. The action restores
        ONLY the affected slots from the Tier-0 `SlotRing` (pure device
        copies — zero disk reads, zero host syncs beyond the fault-path
        position read) to each slot's newest snapshot predating its first
        bad step, the per-slot analogue of the L2/L3 rollback with the
        planner's max_step bound.
      * exhausted per-slot consecutive budget: the REQUEST is rejected with
        notification — the paper's L1 safe stop scoped to one sequence,
        instead of killing the server. The driver drains
        `take_rejections()` and evicts those slots.

    The driver binds `merge` (executor-aware: writes one slot slice into
    every replica image via `map_state`) before serving; restores performed
    here are surfaced through `take_restores()` so the driver can truncate
    the affected requests' token streams to the restored position."""

    level = 0

    def __init__(self, ring, max_retries: int = 8):
        self.ring = ring
        self.max_retries = max_retries
        self.merge: Optional[Callable[[Any, int, Any], Any]] = None
        self._consecutive: dict = {}
        self._pending_restores: dict = {}
        self._pending_rejects: list = []
        self.last_restore_info: Optional[dict] = None

    def maybe_checkpoint(self, step, dual_state, fingerprints=None) -> bool:
        return False   # snapshots are driver-cut into the SlotRing

    def reset(self) -> None:
        self._consecutive.clear()
        self._pending_restores.clear()
        self._pending_rejects.clear()
        self.ring.clear()

    def note_success(self) -> None:
        """A fully-clean step committed: every slot's failure was transient."""
        self._consecutive.clear()

    def take_restores(self) -> dict:
        out, self._pending_restores = self._pending_restores, {}
        return out

    def take_rejections(self) -> list:
        out, self._pending_rejects = self._pending_rejects, []
        for slot in out:
            # the budget is per REQUEST: the next tenant admitted into this
            # slot must start with a clean consecutive count (the counter
            # analogue of ring.evict on admission)
            self._consecutive.pop(slot, None)
        return out

    def on_detection(self, event: DetectionEvent) -> RecoveryAction:
        slots = [int(s) for s in event.detail.get("slots", [])]
        for s in slots:
            self._consecutive[s] = self._consecutive.get(s, 0) + 1
        over = [s for s in slots
                if self.max_retries
                and self._consecutive[s] > self.max_retries]
        self._pending_rejects.extend(over)
        worst = max((self._consecutive[s] for s in slots), default=1)
        if event.boundary == "deferred":
            return RecoveryAction(kind="slot_restore", step=event.step,
                                  rollbacks=worst, event=event)
        # commit/toe/validate without localized optimistic damage: the
        # faulty slots are pre-step (partial commit) or the whole batch is
        # un-committed — re-execution recovers, like RetryRecovery but the
        # budget is per slot and exhaustion rejects the request, not the run
        return RecoveryAction(kind="retry", rollbacks=worst, event=event)

    def restore(self, action: RecoveryAction, dual):
        if self.merge is None:
            raise RuntimeError("SlotRecovery.merge not bound by the driver")
        ev = action.event
        first_bad = ev.detail.get("slot_first_bad", {})
        rejected = set(self._pending_rejects)
        restored: dict = {}
        for slot in [int(s) for s in ev.detail.get("slots", [])]:
            if slot in rejected:
                continue   # driver evicts it; no point repairing
            bound = int(first_bad.get(slot, ev.step))
            try:
                version, sl = self.ring.restore(slot, max_step=bound)
            except KeyError:
                # no snapshot predates the fault (ring rotated past it, or
                # the slot was never snapshotted): degrade to per-request
                # rejection rather than re-emitting an unvalidated stream
                self._pending_rejects.append(slot)
                continue
            dual = self.merge(dual, slot, sl)
            restored[slot] = {
                "version": version,
                "pos": hostsync.read_int(sl["pos"], label="slot_restore")}
        self._pending_restores.update(restored)
        self.last_restore_info = {"tier": "device", "slots": restored}
        return dual


def make_recovery(sedar_cfg, workdir: Optional[str] = None,
                  notify: Optional[Callable[[dict], None]] = None):
    """Build the recovery policy for a SedarConfig.

    Tier hierarchy (DESIGN.md §12): `ckpt_tiers` beyond the flat "disk"
    default routes L2/L3 through a `TieredCheckpointer`; `ckpt_delta`
    swaps the primary disk store for `DeltaCheckpointStore` (L2 only —
    L3 keeps exactly one version, so there is nothing to delta against)
    and `ckpt_compress` enables per-leaf compressed serialization."""
    d = workdir or sedar_cfg.checkpoint_dir
    if sedar_cfg.level <= 1:
        return SafeStop()
    compress = bool(getattr(sedar_cfg, "ckpt_compress", False))
    delta = bool(getattr(sedar_cfg, "ckpt_delta", False)) \
        and sedar_cfg.level == 2
    store_cls = DeltaCheckpointStore if delta else CheckpointStore
    store = store_cls(os.path.join(d, "checkpoints"), compress=compress)
    tiers = make_tiered(sedar_cfg, d, disk_store=store, notify=notify)
    if sedar_cfg.level == 2:
        return MultiCheckpointRecovery(
            store, os.path.join(d, "rollbacks.json"),
            sedar_cfg.checkpoint_interval, sedar_cfg.max_checkpoints,
            async_=sedar_cfg.async_checkpoint, tiers=tiers)
    return ValidatedCheckpointRecovery(store, sedar_cfg.checkpoint_interval,
                                       tiers=tiers)
