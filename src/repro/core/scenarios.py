"""The paper's 64-scenario injection campaign (Sec. 4.1, Table 2).

Test application: MPI Master/Worker matrix multiplication C = A x B with a
checkpoint after every communication:

    CK0 -> SCATTER(A) -> CK1 -> BCAST(B) -> CK2 -> MATMUL -> GATHER(C)
        -> CK3 -> VALIDATE

We reproduce it literally as a deterministic phase machine in which every
process is replicated (two replicas, each owning a full copy of its memory),
messages are fingerprint-validated before being sent (only replica 0's buffer
is transmitted, and only when both replicas agree), checkpoints snapshot the
dual memory of all processes (system-level semantics), and recovery follows
Algorithm 1 with the external rollback counter.

The workfault: 64 scenarios = 8 injection windows (after each of CK0,
SCATTER, CK1, BCAST, CK2[=during MATMUL], MATMUL, GATHER, CK3) x 2 processes
(Master, Worker-0) x 4 data (A, B, C, loop index i). For every scenario the
*predictor* derives (effect, P_det, P_rec, N_roll) from first principles
(liveness + transmission schedule + checkpoint dirtiness) and the machine
must observe exactly that — the paper's Table 2 methodology. The paper's
published scenarios 2, 29, 50, 59 appear verbatim (see tests).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.fingerprint import pytree_fingerprint

EVENTS = ["CK0", "SCATTER", "CK1", "BCAST", "CK2", "MATMUL", "GATHER",
          "CK3", "VALIDATE"]
CKPT_EVENTS = {"CK0": 0, "CK1": 2, "CK2": 4, "CK3": 7}
WINDOWS = EVENTS[:-1]          # injection happens right AFTER this event
DATA = ["A", "B", "C", "i"]
PROCESSES = ["M", "W"]


@dataclass(frozen=True)
class Scenario:
    sid: int
    window: str            # event after which the flip lands
    process: str           # M | W (worker 0)
    datum: str             # A | B | C | i


@dataclass
class Prediction:
    effect: str            # TDC | FSC | LE | TOE
    p_det: Optional[str]   # event at which detection fires (None for LE)
    p_rec: Optional[str]   # checkpoint that finally enables recovery
    n_roll: int


@dataclass
class Observation:
    effect: str
    p_det: Optional[str]
    p_rec: Optional[str]
    n_roll: int
    correct_result: bool


def all_scenarios() -> List[Scenario]:
    out = []
    sid = 1
    for window, proc, datum in itertools.product(WINDOWS, PROCESSES, DATA):
        out.append(Scenario(sid, window, proc, datum))
        sid += 1
    assert len(out) == 64
    return out


# ---------------------------------------------------------------------------
# Predictor (paper Sec. 4.1: every fault's consequence is derivable from the
# application's communication/liveness structure)
# ---------------------------------------------------------------------------

def _ckpts_before(event: str) -> List[str]:
    idx = EVENTS.index(event)
    return [ck for ck, e in CKPT_EVENTS.items() if e <= idx]


def predict(s: Scenario) -> Prediction:
    w = EVENTS.index(s.window)

    def rolls(det_event: str) -> Tuple[str, int]:
        """Checkpoints taken in (injection, detection] are dirty; Algorithm 1
        walks back through them, then one more rollback to a clean one
        (unless the corrupted datum is overwritten during re-execution before
        its detector -- handled per-case below)."""
        det = EVENTS.index(det_event)
        stored = [ck for ck, e in CKPT_EVENTS.items() if e <= det]
        dirty = [ck for ck in stored if CKPT_EVENTS[ck] > w]
        clean = [ck for ck in stored if CKPT_EVENTS[ck] <= w]
        n = len(dirty) + 1
        target = clean[-1] if clean else None     # None -> restart from scratch
        return target, n

    # --- loop index ------------------------------------------------------------
    if s.datum == "i":
        if s.window == "CK2":        # during MATMUL: replica recomputes -> delay
            return Prediction("TOE", "GATHER", "CK2", 1)
        return Prediction("LE", None, None, 0)   # index dead outside MATMUL

    # --- master ------------------------------------------------------------------
    if s.process == "M":
        if s.datum == "A":
            if w < EVENTS.index("SCATTER"):
                tgt, n = rolls("SCATTER")
                return Prediction("TDC", "SCATTER", tgt, n)
            return Prediction("LE", None, None, 0)    # A(M) dead after send
        if s.datum == "B":
            if w < EVENTS.index("BCAST"):
                tgt, n = rolls("BCAST")
                return Prediction("TDC", "BCAST", tgt, n)
            return Prediction("LE", None, None, 0)
        if s.datum == "C":
            if w < EVENTS.index("GATHER"):
                return Prediction("LE", None, None, 0)  # overwritten by GATHER
            # after GATHER: local-only corruption -> final validation
            tgt, n = rolls("VALIDATE")
            return Prediction("FSC", "VALIDATE", tgt, n)

    # --- worker -------------------------------------------------------------------
    if s.datum == "A":
        # worker A block lives from SCATTER (receipt) to MATMUL (last use)
        if w < EVENTS.index("SCATTER"):
            return Prediction("LE", None, None, 0)    # overwritten at receipt
        if w < EVENTS.index("MATMUL"):
            # corrupts C(W) -> caught when C block is sent at GATHER
            tgt, n = rolls("GATHER")
            return Prediction("TDC", "GATHER", tgt, n)
        return Prediction("LE", None, None, 0)        # dead after MATMUL
    if s.datum == "B":
        if w < EVENTS.index("BCAST"):
            return Prediction("LE", None, None, 0)
        if w < EVENTS.index("MATMUL"):
            tgt, n = rolls("GATHER")
            return Prediction("TDC", "GATHER", tgt, n)
        return Prediction("LE", None, None, 0)
    # C(W): written by MATMUL, sent at GATHER, dead afterwards
    if w < EVENTS.index("MATMUL"):
        return Prediction("LE", None, None, 0)        # overwritten by MATMUL
    if w < EVENTS.index("GATHER"):
        tgt, n = rolls("GATHER")
        return Prediction("TDC", "GATHER", tgt, n)
    return Prediction("LE", None, None, 0)            # dead after GATHER


# ---------------------------------------------------------------------------
# Phase machine with the real SEDAR mechanics
# ---------------------------------------------------------------------------

def _fp(x) -> tuple:
    import jax.numpy as jnp
    return tuple(np.asarray(pytree_fingerprint(jnp.asarray(x)))[0, :2].tolist())


class MatmulTestApp:
    """Deterministic dual-replica Master/Worker matmul (paper Alg. 3)."""

    def __init__(self, n: int = 8, workers: int = 2, seed: int = 0):
        assert n % workers == 0
        self.n = n
        self.workers = workers
        rng = np.random.RandomState(seed)
        self.A0 = rng.randn(n, n).astype(np.float32)
        self.B0 = rng.randn(n, n).astype(np.float32)
        self.truth = self.A0 @ self.B0

    # memory layout: mem[replica]["M.A"], mem[replica][f"W{w}.A"], ...
    def _fresh_memory(self) -> List[Dict[str, np.ndarray]]:
        mem = []
        for _ in range(2):
            m = {"M.A": self.A0.copy(), "M.B": self.B0.copy(),
                 "M.C": np.zeros((self.n, self.n), np.float32),
                 "M.i": np.zeros((), np.int32)}
            rows = self.n // self.workers
            for w in range(self.workers):
                m[f"W{w}.A"] = np.zeros((rows, self.n), np.float32)
                m[f"W{w}.B"] = np.zeros((self.n, self.n), np.float32)
                m[f"W{w}.C"] = np.zeros((rows, self.n), np.float32)
                m[f"W{w}.i"] = np.zeros((), np.int32)
            mem.append(m)
        return mem

    def run(self, scenario: Optional[Scenario] = None,
            max_restarts: int = 12) -> Observation:
        mem = self._fresh_memory()
        pc = 0
        injected = False            # the paper's injected.txt
        rollbacks = 0               # extern_counter (failures.txt)
        ckpts: List[Tuple[str, int, list]] = []   # (name, pc_after, dual mem)
        first_det: Optional[str] = None
        final_rec: Optional[str] = None
        toe_delayed = False
        effect_seen = None
        guard = 0

        def snapshot(name: str):
            ckpts.append((name, pc + 1,
                          [{k: v.copy() for k, v in m.items()} for m in mem]))

        def detect(event_name: str, effect: str):
            nonlocal pc, rollbacks, first_det, final_rec, mem, toe_delayed, \
                effect_seen
            if first_det is None:
                first_det = event_name
                effect_seen = effect
            rollbacks += 1
            idx = len(ckpts) - rollbacks
            toe_delayed = False
            if idx < 0:                       # relaunch from the beginning
                mem = self._fresh_memory()
                pc = 0
                final_rec = None
                return
            name, saved_pc, saved = ckpts[idx]
            mem = [{k: v.copy() for k, v in m.items()} for m in saved]
            del ckpts[idx + 1:]               # re-stored during re-execution
            pc = saved_pc
            final_rec = name

        def validate_send(key: str, event_name: str, effect: str) -> bool:
            if _fp(mem[0][key]) != _fp(mem[1][key]):
                detect(event_name, effect)
                return False
            return True

        rows = self.n // self.workers
        while pc < len(EVENTS):
            guard += 1
            if guard > 600:
                raise RuntimeError("scenario did not converge")
            ev = EVENTS[pc]

            if ev in CKPT_EVENTS:
                snapshot(ev)

            elif ev == "SCATTER":
                if not validate_send("M.A", "SCATTER", "TDC"):
                    continue
                for w in range(self.workers):
                    blk = mem[0]["M.A"][w * rows:(w + 1) * rows].copy()
                    for r in range(2):
                        mem[r][f"W{w}.A"] = blk.copy()

            elif ev == "BCAST":
                if not validate_send("M.B", "BCAST", "TDC"):
                    continue
                for w in range(self.workers):
                    for r in range(2):
                        mem[r][f"W{w}.B"] = mem[0]["M.B"].copy()

            elif ev == "MATMUL":
                for w in range(self.workers):
                    for r in range(2):
                        mem[r][f"W{w}.C"] = mem[r][f"W{w}.A"] @ mem[r][f"W{w}.B"]

            elif ev == "GATHER":
                if toe_delayed:
                    detect("GATHER", "TOE")
                    continue
                failed = False
                for w in range(self.workers):
                    if not validate_send(f"W{w}.C", "GATHER", "TDC"):
                        failed = True
                        break
                if failed:
                    continue
                for w in range(self.workers):
                    blk = mem[0][f"W{w}.C"]
                    for r in range(2):
                        mem[r]["M.C"][w * rows:(w + 1) * rows] = blk.copy()

            elif ev == "VALIDATE":
                if _fp(mem[0]["M.C"]) != _fp(mem[1]["M.C"]):
                    detect("VALIDATE", "FSC")
                    continue

            # -- injection: right after event `ev` ------------------------------
            if (scenario is not None and not injected
                    and ev == scenario.window):
                injected = True
                key = f"{'M' if scenario.process == 'M' else 'W0'}.{scenario.datum}"
                if scenario.datum == "i":
                    if scenario.window == "CK2":
                        toe_delayed = True      # replica 1 restarts its loop
                    # else: dead index, no memory effect
                else:
                    # single bit-flip in replica 1's copy (paper Sec. 4.2)
                    flat = mem[1][key].reshape(-1)
                    target_idx = min(3, flat.size - 1)
                    uu = flat[target_idx:target_idx + 1].view(np.uint32).copy()
                    uu ^= np.uint32(1 << 22)
                    flat[target_idx:target_idx + 1] = uu.view(np.float32)

            pc += 1

        ok = np.allclose(mem[0]["M.C"], self.truth, atol=1e-4) and \
            np.allclose(mem[1]["M.C"], self.truth, atol=1e-4)
        return Observation(
            effect=effect_seen or "LE",
            p_det=first_det,
            p_rec=final_rec,
            n_roll=rollbacks,
            correct_result=ok)


def run_campaign(n: int = 8, workers: int = 2):
    """Run all 64 scenarios; returns list of dicts with predicted vs observed."""
    app = MatmulTestApp(n=n, workers=workers)
    rows = []
    for s in all_scenarios():
        pred = predict(s)
        obs = app.run(s)
        rows.append({
            "sid": s.sid, "window": s.window, "process": s.process,
            "datum": s.datum,
            "pred": dataclasses.asdict(pred),
            "obs": dataclasses.asdict(obs),
            "match": (pred.effect == obs.effect
                      and pred.p_det == obs.p_det
                      and pred.p_rec == obs.p_rec
                      and pred.n_roll == obs.n_roll
                      and obs.correct_result),
        })
    return rows


# ---------------------------------------------------------------------------
# ABFT scenario classes (DESIGN.md §10): in-kernel corruption vs checksums
# ---------------------------------------------------------------------------
#
# The replica campaign above corrupts MEMORY between phases; the ABFT
# campaign corrupts the KERNEL's accumulated output (injection target
# "kernel") and classifies what the checksums see:
#
#   corrected     -- single element, delta above the roundoff floor: the
#                    row+column residual pair localizes it; forward repair.
#   uncorrectable -- multiple elements: residual violations do not localize;
#                    the output is untrusted and recovery must act.
#   escaped_fsc   -- delta below the residual noise floor (low-order mantissa
#                    bit): numerically harmless for the result, invisible to
#                    ABFT — exactly the class the hybrid backend's FSC
#                    fingerprint boundary (or replication) exists for.

ABFT_CLASSES = ("corrected", "uncorrectable", "escaped_fsc")


@dataclass(frozen=True)
class AbftScenario:
    sid: int
    bit: int              # flipped bit of the f32 pattern
    n_elems: int          # corrupted output elements
    predicted: str        # one of ABFT_CLASSES


def abft_scenarios() -> List[AbftScenario]:
    """12 scenarios x 3 classes: high-mantissa single flips (corrected),
    multi-element flips (uncorrectable), low-order mantissa flips (escaped).

    The flip lands on the LARGEST-magnitude output element (plus diagonal
    neighbours for multi-element), so a bit >= 21 moves the value by
    >= |c_max|/4 — far above the residual noise floor — while bits <= 3
    move it by a few ulps — far below it. The class boundary is therefore
    derivable from (bit, n_elems) alone, like the paper's Table-2 predictor
    derives effects from liveness alone."""
    out, sid = [], 1
    for bit in (21, 22, 23, 21):
        out.append(AbftScenario(sid, bit, 1, "corrected"))
        sid += 1
    for bit, n_elems in ((21, 2), (22, 3), (23, 4), (21, 3)):
        out.append(AbftScenario(sid, bit, n_elems, "uncorrectable"))
        sid += 1
    for bit in (0, 1, 2, 3):
        out.append(AbftScenario(sid, bit, 1, "escaped_fsc"))
        sid += 1
    return out


def classify_abft(report, c, clean) -> str:
    """Observed class from a kernel report + output vs the clean product."""
    if bool(np.asarray(report.uncorrectable)):
        return "uncorrectable"
    if bool(np.asarray(report.corrected)):
        return "corrected"
    if not np.array_equal(np.asarray(c), np.asarray(clean)):
        return "escaped_fsc"
    return "clean"


def run_abft_campaign(m: int = 24, n: int = 16, k: int = 20, seed: int = 0):
    """Run every ABFT scenario through the checksummed matmul (jnp reference
    lowering — the Pallas path is bit-compatible, see tests/test_abft.py);
    returns predicted-vs-observed rows like `run_campaign`."""
    import jax.numpy as jnp

    from repro.abft.ref import abft_matmul_ref
    from repro.core.injection import InjectionSpec, make_kernel_fault

    rng = np.random.RandomState(seed)
    a = jnp.asarray(rng.randn(m, n).astype(np.float32))
    b = jnp.asarray(rng.randn(n, k).astype(np.float32))
    clean, _ = abft_matmul_ref(a, b)
    # anchor every flip at the largest data element whose diagonal spread
    # (n_elems - 1 steps of (+1 row, +1 col)) stays INSIDE the data block —
    # otherwise a multi-element fault could land in the checksum row/column
    # (or wrap), breaking the uncorrectable prediction near the matrix edge
    spread = max(s.n_elems for s in abft_scenarios()) - 1
    assert m > spread and k > spread, (m, k, spread)
    interior = np.abs(np.asarray(clean))[:m - spread, :k - spread]
    i0, j0 = np.unravel_index(int(np.argmax(interior)), interior.shape)
    target = i0 * (k + 1) + j0                             # data -> full idx
    rows = []
    for s in abft_scenarios():
        spec = InjectionSpec(leaf_idx=0, flat_idx=target, bit=s.bit,
                             step=0, target="kernel", n_elems=s.n_elems,
                             dtype="float32")
        inject = make_kernel_fault(spec, step=0, armed=True)
        c, report = abft_matmul_ref(a, b, inject=inject)
        obs = classify_abft(report, c, clean)
        correct = bool(np.allclose(np.asarray(c), np.asarray(clean),
                                   atol=1e-3))
        rows.append({
            "sid": s.sid, "bit": s.bit, "n_elems": s.n_elems,
            "pred": s.predicted, "obs": obs,
            # corrected/clean outputs must match the clean product; an
            # uncorrectable output is untrusted (no claim either way)
            "match": (obs == s.predicted
                      and (correct if obs != "uncorrectable" else True)),
        })
    return rows
