"""SEDAR core — the paper's contribution as composable JAX modules."""
from repro.core import hostsync
from repro.core.detection import (DetectionEvent, SedarSafeStop, Watchdog,
                                  make_pod_comparator, make_pod_injector)
from repro.core.engine import (BoundarySchedule, FusedSequentialExecutor,
                               PlainExecutor, PodExecutor,
                               ReplicaExecutor, SedarEngine,
                               SequentialExecutor, StepOutcome, VoteExecutor)
from repro.core.fingerprint import (fingerprints_equal, mismatch_report,
                                    pack_tree_u32, packed_fingerprint,
                                    pytree_fingerprint,
                                    pytree_fingerprint_fused,
                                    tensor_fingerprint)
from repro.core.injection import (InjectionFlag, InjectionSpec,
                                  MemoryInjectionFlag, flip_bit, inject_tree,
                                  make_kernel_fault)
from repro.core.policy import Advice, advise, make_engine, make_server, \
    make_trainer
from repro.core.recovery import (ExternalCounter, MultiCheckpointRecovery,
                                 RecoveryAction, RetryRecovery, SafeStop,
                                 ValidatedCheckpointRecovery, make_recovery)
from repro.core import scenarios, temporal_model

__all__ = [
    "hostsync",
    "DetectionEvent", "SedarSafeStop", "Watchdog", "make_pod_comparator",
    "make_pod_injector", "BoundarySchedule", "FusedSequentialExecutor",
    "PlainExecutor", "PodExecutor",
    "ReplicaExecutor", "SedarEngine", "SequentialExecutor", "StepOutcome",
    "VoteExecutor", "fingerprints_equal", "mismatch_report", "pack_tree_u32",
    "packed_fingerprint", "pytree_fingerprint", "pytree_fingerprint_fused",
    "tensor_fingerprint", "InjectionFlag", "InjectionSpec",
    "MemoryInjectionFlag", "flip_bit", "inject_tree", "make_kernel_fault",
    "Advice", "advise",
    "make_engine", "make_server", "make_trainer", "ExternalCounter",
    "MultiCheckpointRecovery", "RecoveryAction", "RetryRecovery", "SafeStop",
    "ValidatedCheckpointRecovery", "make_recovery",
    "scenarios", "temporal_model",
]
