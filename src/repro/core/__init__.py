"""SEDAR core — the paper's contribution as composable JAX modules."""
from repro.core.detection import (DetectionEvent, SedarSafeStop, Watchdog,
                                  make_pod_comparator, make_pod_injector)
from repro.core.fingerprint import (fingerprints_equal, mismatch_report,
                                    pytree_fingerprint, tensor_fingerprint)
from repro.core.injection import InjectionFlag, InjectionSpec, flip_bit, inject_tree
from repro.core.policy import Advice, advise
from repro.core.recovery import (ExternalCounter, MultiCheckpointRecovery,
                                 RecoveryAction, SafeStop,
                                 ValidatedCheckpointRecovery, make_recovery)
from repro.core import scenarios, temporal_model

__all__ = [
    "DetectionEvent", "SedarSafeStop", "Watchdog", "make_pod_comparator",
    "make_pod_injector", "fingerprints_equal", "mismatch_report",
    "pytree_fingerprint", "tensor_fingerprint", "InjectionFlag",
    "InjectionSpec", "flip_bit", "inject_tree", "Advice", "advise",
    "ExternalCounter", "MultiCheckpointRecovery", "RecoveryAction",
    "SafeStop", "ValidatedCheckpointRecovery", "make_recovery",
    "scenarios", "temporal_model",
]
