"""Detection machinery: replica comparison at propagation boundaries + TOE
watchdog (paper Sec. 3.1).

Boundaries (DESIGN.md §2):
  * commit   -- gradient/update fingerprints compared every
                `validate_interval` steps BEFORE the optimizer commit
                (paper: message buffers compared before MPI_Send). TDC class.
  * validate -- full-state fingerprints compared every
                `param_validate_interval` steps and at end of run
                (paper: final-result comparison). FSC class.
  * toe      -- replica heartbeat timeout (paper: flow separation of the two
                replicas in a homogeneous dedicated system).

Two replica backends:
  * sequential: both replicas execute on the same devices one after the other
    (CPU tests, single-pod operation). Comparison is plain array equality.
  * pod: replicas live on different pods of the production mesh; fingerprints
    are exchanged with an all-gather over the replica axis inside shard_map
    (a few hundred bytes over ICI/DCN).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.fingerprint import fingerprints_equal


@dataclass
class DetectionEvent:
    step: int
    boundary: str            # commit | validate | toe | final
    effect: str = ""         # TDC | FSC | TOE (classification, best effort)
    detail: Dict[str, Any] = field(default_factory=dict)

    def __str__(self):
        return (f"[SEDAR] fault detected at step {self.step} "
                f"(boundary={self.boundary}{', ' + self.effect if self.effect else ''})")


class SedarSafeStop(RuntimeError):
    """L1: notification + safe stop (paper Sec. 3.1)."""

    def __init__(self, event: DetectionEvent):
        super().__init__(str(event))
        self.event = event


# ---------------------------------------------------------------------------
# Pod-axis comparison (shard_map over the replica axis)
# ---------------------------------------------------------------------------

def _shard_map(fn, mesh, in_specs, out_specs):
    from jax.experimental.shard_map import shard_map
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_vma=False)
    except TypeError:   # older kwarg name
        return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=False)


def make_pod_comparator(mesh, axis: str = "pod"):
    """Returns fn(fp) -> (all_equal: bool[], fp_all: (n_replicas, ...))

    `fp` is logically replicated but physically per-pod (it diverges only
    under a fault). The all-gather is explicit so XLA cannot fold it away."""

    def inner(fp):
        fp_all = jax.lax.all_gather(fp, axis)          # (n_pods, L, 4)
        eq = jnp.all(fp_all[..., :2] == fp_all[:1, ..., :2])
        return eq, fp_all

    return _shard_map(inner, mesh, in_specs=P(), out_specs=(P(), P()))


def make_lane_comparator(mesh, axis: str = "pod"):
    """Per-lane replica agreement via pure reductions (DESIGN.md §16).

    Takes lane fingerprints ``(L, 4) u32`` (logically replicated, physically
    per-pod) and returns ``eq_lanes: bool (L,)`` — lane i True iff every
    replica agrees on lane i's hash words. Implemented as pmax/pmin over the
    replica axis instead of an all-gather: the hot path moves O(L) words and
    never materializes the (n_replicas, L, 4) matrix; replicas agree exactly
    when max == min elementwise. No host readback — the caller parks or
    reduces the vector on device (§11 zero-sync contract)."""

    def inner(fp_lanes):
        h = fp_lanes[..., :2].astype(jnp.uint32)       # hash words only
        mx = jax.lax.pmax(h, axis)
        mn = jax.lax.pmin(h, axis)
        return jnp.all(mx == mn, axis=-1)              # (L,)

    return _shard_map(inner, mesh, in_specs=P(), out_specs=P())


def make_pod_broadcaster(mesh, axis: str = "pod"):
    """Beyond-paper N-modular redundancy: returns fn(state, src) that copies
    pod `src`'s physical state to every pod (collective-permute, memory-light)
    — forward correction after a majority vote, no rollback needed.
    `src` must be a static Python int (the runtime learns it from fp_all)."""
    n = mesh.shape[axis]

    def make(src: int):
        def inner(x):
            # one-to-many broadcast as a masked psum: only the src replica
            # contributes, so the sum is bitwise x_src on every pod
            me = jax.lax.axis_index(axis)
            if jnp.issubdtype(x.dtype, jnp.integer) or x.dtype == jnp.bool_:
                xi = x.astype(jnp.int32)
                out = jax.lax.psum(jnp.where(me == src, xi, 0), axis)
                return out.astype(x.dtype)
            contrib = jnp.where(me == src, x, jnp.zeros_like(x))
            return jax.lax.psum(contrib, axis)

        def bcast(tree):
            return jax.tree.map(
                lambda x: _shard_map(inner, mesh, in_specs=P(),
                                     out_specs=P())(x), tree)
        return bcast

    return make


def majority_replica(fp_all: "np.ndarray"):
    """Host-side majority vote over gathered fingerprints — (n_replicas, 4)
    for the fused whole-state hash, (n_replicas, L, 4) for per-leaf.

    Returns (src_replica, ok) — ok False when no strict majority exists."""
    import numpy as np
    fp_all = np.asarray(fp_all)
    n = fp_all.shape[0]
    keys = [fp_all[i].reshape(-1, 4)[:, :2].tobytes() for i in range(n)]
    best, count = None, 0
    for i, k in enumerate(keys):
        c = keys.count(k)
        if c > count:
            best, count = i, c
    return best, count > n // 2


def make_pod_injector(mesh, spec, axis: str = "pod"):
    """Returns fn(tree, step) that flips spec's bit on pod == spec.replica
    only (physical divergence of a logically-replicated tree)."""
    from repro.core.injection import flip_bit

    def apply(tree, step):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        x = leaves[spec.leaf_idx]

        def inner(xl, st):
            rid = jax.lax.axis_index(axis)
            fire = jnp.logical_and(rid == spec.replica, st == spec.step)
            return jnp.where(fire, flip_bit(xl, spec.flat_idx, spec.bit), xl)

        leaves[spec.leaf_idx] = _shard_map(
            inner, mesh, in_specs=(P(), P()), out_specs=P())(x, jnp.asarray(step))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return apply


# ---------------------------------------------------------------------------
# TOE watchdog (host-side heartbeats)
# ---------------------------------------------------------------------------

class Watchdog:
    """Per-replica heartbeat monitor. The runtime beats around every replica
    execution; `check()` flags replicas whose last beat is older than
    `timeout_s` — the paper's configurable-lapse TOE detector. A replica that
    never progresses (infinite loop) is definitely detected."""

    def __init__(self, timeout_s: float, n_replicas: int = 2):
        self.timeout_s = timeout_s
        self.last_beat: Dict[int, float] = {r: time.monotonic()
                                            for r in range(n_replicas)}
        self.step_time: Dict[int, float] = {}
        # per-replica wall-clock separation needs a device sync after each
        # replica launch; executors only pay it while the watchdog is armed
        # (scenario delays arm it implicitly; see SequentialExecutor)
        self.armed: bool = False

    def arm(self) -> None:
        self.armed = True

    def disarm(self) -> None:
        self.armed = False

    def beat(self, replica: int, step: int) -> None:
        now = time.monotonic()
        prev = self.last_beat.get(replica, now)
        self.last_beat[replica] = now
        self.step_time[replica] = now - prev

    def stale(self) -> List[int]:
        now = time.monotonic()
        return [r for r, t in self.last_beat.items()
                if now - t > self.timeout_s]

    def skew(self) -> float:
        """Max pairwise difference of last-beat times — replica flow
        separation (the paper's 'appreciable delay between the two replicas')."""
        ts = list(self.last_beat.values())
        return max(ts) - min(ts) if len(ts) > 1 else 0.0

    def check(self, step: int) -> Optional[DetectionEvent]:
        bad = self.stale()
        if bad:
            return DetectionEvent(step=step, boundary="toe", effect="TOE",
                                  detail={"stale_replicas": bad,
                                          "timeout_s": self.timeout_s})
        return None
