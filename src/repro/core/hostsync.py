"""Host-sync accounting: every device->host readback flows through here.

The zero-sync hot path (DESIGN.md §11) is a *property*, not an aspiration:
the engine, the executors, the checkpoint store and the workload drivers
perform every device->host transfer through this module, so a test (or a
production canary) can wrap a region in `count_transfers()` and ASSERT that
a fault-free protected step with `validate_lag >= D` performs zero
readbacks between validation flushes.

Two kinds of counted operations:

  * `read_scalar` / `read_bool`  -- one small readback (a predicate, a step
    counter, a fingerprint row). Counted as 1 transfer, 1 batch.
  * `batched_get`                -- ONE logical transfer batch covering many
    leaves (`jax.device_get` on the whole list: the transfers are issued
    together and awaited once, instead of one blocking round-trip per
    leaf). Counted as 1 batch, len(leaves) items.

`copy_to_host_async` starts non-blocking D2H DMA for every leaf (where the
runtime supports it) so a later `batched_get` only *waits* instead of
serializing issue->wait per leaf; it performs no readback itself and is not
counted.

`count_transfers()` counting is thread-local BY DEFAULT: background
checkpoint writers receive host arrays, so all counted calls happen on the
driver thread — a scoped region counts only readbacks issued by the thread
that opened it (tests/test_obs.py documents this). For regions whose
readbacks may come from another thread (the detokenize-drain consumer, a
background restore), `count_transfers(cross_thread=True)` registers the
stats object on a process-wide, lock-protected list that EVERY thread's
`_note` walks — the scoped view then matches what the metrics registry
sees. Independent of either mode, when `repro.obs.enable_metrics()` is on
every `_note` also fans into the registry via the `_metrics_note` hook,
which aggregates across threads.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import jax
import numpy as np


@dataclass
class TransferStats:
    """Counts of device->host readbacks inside a `count_transfers` region."""

    transfers: int = 0          # individual arrays read back
    batches: int = 0            # transfer batches issued (1 per counted call)
    by_label: Dict[str, int] = field(default_factory=dict)

    def note(self, label: str, items: int = 1) -> None:
        self.transfers += items
        self.batches += 1
        self.by_label[label] = self.by_label.get(label, 0) + items


class _ActiveStats(threading.local):
    def __init__(self):
        self.stack: List[TransferStats] = []


_active = _ActiveStats()

# Cross-thread counting regions (`count_transfers(cross_thread=True)`).
# The unguarded truthiness test in `_note` is a benign race: registration
# happens-before the region's readbacks on the registering thread, and the
# lock serializes every mutation of both the list and the stats.
_shared_lock = threading.Lock()
_shared: List[TransferStats] = []

# Process-wide metrics fan-in, installed by `repro.obs.enable_metrics()`.
# None when metrics are off, so the disabled cost is one `is None` test.
_metrics_note: Optional[Callable[[str, int], None]] = None


@contextlib.contextmanager
def count_transfers(cross_thread: bool = False) -> Iterator[TransferStats]:
    """Count every device->host readback issued inside the block.

    Default scope is the calling thread (see the module docstring);
    `cross_thread=True` additionally counts readbacks issued by OTHER
    threads while the region is open — e.g. the detokenize-drain consumer
    — at the cost of a lock per counted call."""
    st = TransferStats()
    if cross_thread:
        with _shared_lock:
            _shared.append(st)
        try:
            yield st
        finally:
            with _shared_lock:
                _shared.remove(st)
    else:
        _active.stack.append(st)
        try:
            yield st
        finally:
            _active.stack.remove(st)


def _note(label: str, items: int = 1) -> None:
    for st in _active.stack:
        st.note(label, items)
    if _shared:
        with _shared_lock:
            for st in _shared:
                st.note(label, items)
    if _metrics_note is not None:
        _metrics_note(label, items)


def read_scalar(x, label: str = "scalar") -> np.ndarray:
    """One counted readback of a small array (predicate/counter/row)."""
    _note(label)
    return np.asarray(jax.device_get(x))


def read_bool(x, label: str = "predicate") -> bool:
    return bool(read_scalar(x, label=label))


def read_int(x, label: str = "counter") -> int:
    return int(read_scalar(x, label=label))


def copy_to_host_async(leaves: Sequence[Any]) -> None:
    """Start non-blocking D2H copies for every leaf (best effort: CPU arrays
    and non-jax leaves have nothing to overlap). Not counted — no readback
    completes here."""
    for l in leaves:
        start = getattr(l, "copy_to_host_async", None)
        if start is not None:
            try:
                start()
            except Exception:   # noqa: BLE001 — committed arrays only
                pass


def batched_get(leaves: Sequence[Any], label: str = "batch") -> List[Any]:
    """ONE transfer batch for a list of arrays: issue all copies, wait once.

    `jax.device_get` on a list fetches every leaf in a single call (and any
    DMA started by `copy_to_host_async` merely completes here), so a
    100-leaf state costs one batch — not 100 blocking round-trips."""
    leaves = list(leaves)
    _note(label, items=len(leaves))
    copy_to_host_async(leaves)
    return [np.asarray(l) for l in jax.device_get(leaves)]
