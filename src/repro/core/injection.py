"""Controlled fault injection — the paper's workfault generator (Sec. 4.2).

The paper injects a single bit-flip into one of the two replicated threads
from inside the application code, gated by an external flag file so the
re-execution after recovery does not re-inject. We reproduce both halves:

  * `inject_bitflip` / `inject_tree`: in-jit, replica-gated, step-gated exact
    bit flip in a chosen pytree leaf (params / grads / optimizer state).
  * `InjectionFlag`: the paper's ``injected.txt`` — a host-side flag file
    *outside* the checkpoint payload, so restarts never re-inject.

Effect classes (paper Sec. 2): TDC (corrupt data that propagates through the
commit boundary), FSC (corrupt state that only the final/param validation
sees), LE (corrupt dead data -> no effect), TOE (delay a replica past the
watchdog timeout). See core/scenarios.py for the scenario campaign.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


DTYPE_BITS = {"float32": 32, "int32": 32, "uint32": 32,
              "bfloat16": 16, "float16": 16}


@dataclass(frozen=True)
class InjectionSpec:
    """Static description of one injection experiment.

    leaf_path: index of the target leaf in tree_flatten order (static).
    flat_idx : flat element offset within the leaf (dynamic ok).
    bit      : bit to flip within the element's 32/16-bit pattern.
    step     : training step at which to inject.
    replica  : which replica id gets the corruption (the essence of SEDAR
               detection: the *other* replica stays clean).
    target   : grads | params | opt_state  (TDC vs FSC class) | kernel
               (corruption INSIDE a protected kernel's compute, pre-verify —
               the ABFT detection domain; see `make_kernel_fault`).
               Serving adds slot (one decode slot's logits), prefill (one
               pack row's logits during packed admission, leaf_idx = the
               row) and prefill_kernel (the packed-prefill ABFT checksum
               window) — distinct targets so a campaign aimed at one stage
               never fires, and gets disarmed, in another.
    n_elems  : number of corrupted elements (>1 defeats ABFT single-element
               correction: the detected-uncorrectable scenario class).
    dtype    : optional target-leaf dtype name; when given, `bit` is
               validated against the dtype's width at construction time.
    persistent : model a PERMANENT fault (stuck bit) instead of a transient
               SDC: the corruption fires on EVERY step >= `step` (the
               once-only injection flag is never marked, so re-executions
               after recovery re-inject). Detection then repeats until the
               consecutive-failure budget degrades to the L1 response —
               for serving, per-request rejection (DESIGN.md §13).
    """
    leaf_idx: int
    flat_idx: int
    bit: int
    step: int
    replica: int = 1
    target: str = "grads"
    n_elems: int = 1
    dtype: str = ""
    persistent: bool = False

    def __post_init__(self):
        if not 0 <= self.bit < 32:
            raise ValueError(f"bit {self.bit} outside any supported dtype "
                             f"(must be in [0, 32))")
        if self.dtype:
            width = DTYPE_BITS.get(self.dtype)
            if width is None:
                raise ValueError(f"unknown injection dtype {self.dtype!r}")
            if self.bit >= width:
                raise ValueError(
                    f"bit {self.bit} out of range for {self.dtype} "
                    f"(must be in [0, {width}))")
        if self.n_elems < 1:
            raise ValueError(f"n_elems must be >= 1, got {self.n_elems}")


def flip_bit(x: jnp.ndarray, flat_idx, bit: int) -> jnp.ndarray:
    """Flip one bit of one element (exact, dtype-preserving).

    `bit` is validated against the dtype's width — a silently clamped or
    wrapped index would corrupt a DIFFERENT bit than the experiment recorded,
    invalidating the campaign's predicted effect class."""
    dt = x.dtype
    shape = x.shape
    flat = x.reshape(-1)
    nbits = 16 if dt in (jnp.bfloat16, jnp.float16) else 32
    if not 0 <= bit < nbits:
        raise ValueError(f"bit {bit} out of range for {dt} "
                         f"(must be in [0, {nbits}))")
    if dt == jnp.float32:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint32)
        u = u.at[flat_idx].set(u[flat_idx] ^ jnp.uint32(1 << bit))
        out = jax.lax.bitcast_convert_type(u, jnp.float32)
    elif dt == jnp.bfloat16:
        u = jax.lax.bitcast_convert_type(flat, jnp.uint16)
        u = u.at[flat_idx].set(u[flat_idx] ^ jnp.uint16(1 << bit))
        out = jax.lax.bitcast_convert_type(u, jnp.bfloat16)
    elif dt in (jnp.int32, jnp.uint32):
        out = flat.at[flat_idx].set(flat[flat_idx] ^ jnp.asarray(1 << bit, dt))
    else:
        raise TypeError(f"injection unsupported for {dt}")
    return out.reshape(shape)


def make_kernel_fault(spec: InjectionSpec, *, step, armed):
    """In-kernel corruption (target='kernel'): returns fn(out) -> out' that
    flips `spec.bit` in `spec.n_elems` elements of a protected kernel's
    accumulated output — between compute and verify, i.e. inside the domain
    that only ABFT checksums (not replica comparison of inputs, not state
    fingerprints) can see at kernel granularity.

    Multiple elements are spread one row AND one column apart (stride
    width+1), so n_elems >= 2 violates >= 2 row and >= 2 column residuals —
    the detected-uncorrectable class. step/armed are traced scalars; the
    re-execution after recovery passes armed=0 and does not re-inject."""
    if spec.target != "kernel":
        raise ValueError(f"make_kernel_fault needs target='kernel', "
                         f"got {spec.target!r}")

    def apply(out: jnp.ndarray) -> jnp.ndarray:
        flat = out.reshape(-1)
        stride = out.shape[-1] + 1
        corrupted = flat
        for e in range(spec.n_elems):
            idx = (spec.flat_idx + e * stride) % flat.size
            corrupted = flip_bit(corrupted, idx, spec.bit)
        fire = jnp.logical_and(jnp.asarray(armed, jnp.bool_),
                               spec_step_hit(spec, step))
        return jnp.where(fire, corrupted, flat).reshape(out.shape)

    return apply


def spec_step_hit(spec: InjectionSpec, step) -> jnp.ndarray:
    """Traced step-gate: exact hit for transients, `>=` for persistent
    (stuck-bit) faults that re-manifest on every subsequent execution."""
    step = jnp.asarray(step)
    return step >= spec.step if spec.persistent else step == spec.step


def inject_tree(tree, spec: Optional[InjectionSpec], *, step, replica_id,
                armed=True):
    """Conditionally corrupt `tree` (in-jit). step/replica_id/armed are traced
    scalars; spec fields are static. No-op when spec is None.

    `armed` is the dynamic counterpart of the paper's injected.txt: after the
    first firing the runtime passes armed=0, so re-executions after a
    rollback do NOT re-inject."""
    if spec is None:
        return tree
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    # The injection ops must not perturb the CLEAN path bitwise: an
    # unconditionally-computed flip feeding a `where` gives the target
    # leaf's producer a second consumer, and XLA's changed fusion can drift
    # its rounding by 1 ULP — a never-firing spec would then diverge from
    # the uninjected program (breaking every bitwise fault-free-twin
    # comparison). `cond` keeps the flip in a separate branch computation:
    # the not-firing path routes the leaf through untouched.
    target = leaves[spec.leaf_idx]
    fire = jnp.logical_and(
        jnp.asarray(armed, jnp.bool_),
        jnp.logical_and(spec_step_hit(spec, step),
                        jnp.asarray(replica_id) == spec.replica))
    leaves[spec.leaf_idx] = jax.lax.cond(
        fire, lambda x: flip_bit(x, spec.flat_idx, spec.bit),
        lambda x: x, target)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class InjectionFlag:
    """The paper's ``injected.txt``: an external once-only flag so recovery
    re-executions do not re-inject (content survives checkpoint rollbacks
    because it lives OUTSIDE the checkpoint, paper Sec. 4.2)."""

    def __init__(self, path: str):
        self.path = path
        if not os.path.exists(path):
            self._write(0)

    def _write(self, v: int):
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"injected": v}, f)

    def already_injected(self) -> bool:
        with open(self.path) as f:
            return json.load(f)["injected"] > 0

    def mark(self):
        self._write(1)

    def arm_spec(self, spec: Optional[InjectionSpec]) -> Optional[InjectionSpec]:
        """Returns spec if not yet injected, else None (the paper's
        'function returns without making a new injection')."""
        if spec is None or self.already_injected():
            return None
        return spec


class MemoryInjectionFlag:
    """In-memory once-only flag with the InjectionFlag API, for workloads
    that have no workdir (e.g. the serving path: a transient fault does not
    repeat, so the retry after a detection must not re-inject)."""

    def __init__(self):
        self._injected = False

    def already_injected(self) -> bool:
        return self._injected

    def mark(self) -> None:
        self._injected = True

    def reset(self) -> None:
        self._injected = False

    def arm_spec(self, spec: Optional[InjectionSpec]) -> Optional[InjectionSpec]:
        if spec is None or self._injected:
            return None
        return spec


def random_spec(key, tree, *, step: int, replica: int = 1,
                target: str = "grads") -> InjectionSpec:
    """Uniformly random single-bit fault over a pytree (for campaigns)."""
    leaves = jax.tree_util.tree_flatten(tree)[0]
    sizes = np.array([int(np.prod(l.shape)) for l in leaves], np.int64)
    probs = sizes / sizes.sum()
    k1, k2, k3 = jax.random.split(key, 3)
    leaf = int(jax.random.choice(k1, len(leaves), p=jnp.asarray(probs)))
    idx = int(jax.random.randint(k2, (), 0, int(sizes[leaf])))
    nbits = 16 if leaves[leaf].dtype == jnp.bfloat16 else 32
    bit = int(jax.random.randint(k3, (), 0, nbits))
    return InjectionSpec(leaf_idx=leaf, flat_idx=idx, bit=bit, step=step,
                         replica=replica, target=target)
