"""The unified SEDAR engine: one detection/recovery core for every workload.

Paper Secs. 3.1–3.3 compose three orthogonal mechanisms — replicated
execution (detection), boundary validation (containment), and leveled
checkpointing (recovery). This module is the single place where that
composition lives (DESIGN.md §1):

    SedarEngine = ReplicaExecutor        (how redundant copies execute)
                × BoundarySchedule       (when boundaries fire)
                × recovery policy        (what a detection costs: L0 retry /
                                          L1 stop / L2 chain / L3 validated)
                × Watchdog + injection   (TOE detection, fault campaigns)

Workloads (training, serving, future batch/eval paths) are thin drivers:
they provide a jit-able `step_fn(state, batch, replica_id, armed) ->
(candidate, fingerprint, aux)` plus state fingerprints, then call
`run_protected_step()` per step and `on_detection()` per event. All
compare / commit-gate / validate / checkpoint / rollback / retry logic is
in the engine — no workload re-derives the protocol.

Executor backends:
  * plain       -- no redundancy (the unprotected baseline).
  * sequential  -- time redundancy: both replicas run on the same devices
                   one after the other, each owning a full state image.
  * fused       -- time redundancy in ONE launch (DESIGN.md §11): replica
                   state stacked on a leading axis, both replicas stepped by
                   a single vmapped jit that also computes the equality
                   predicate on device — the zero-sync hot path backend.
  * pod         -- space redundancy: replicas are pods of the production
                   mesh; fingerprints exchanged via all-gather in shard_map.
  * vote        -- N-modular redundancy (beyond-paper, DESIGN.md §6): >=3
                   pod replicas; a divergence is repaired FORWARD by
                   broadcasting the majority replica's state — no rollback.
  * abft/hybrid -- replica-free: checksum-carrying kernels detect (and for
                   single corruptions, forward-correct) in-kernel faults;
                   hybrid adds commit-time fingerprint validation for the
                   classes ABFT cannot see (abft/executor.py, DESIGN.md §10).

Deferred validation (DESIGN.md §11): with `BoundarySchedule.validate_lag=D`
> 1 the engine stops reading the per-step match predicate back to the host.
Executors that `supports_deferred` commit optimistically and hand back the
ON-DEVICE predicate; the engine parks it in a small device-resident ring and
forces one readback every D commits (and at validate/checkpoint/final
boundaries). Detection latency is bounded by D steps; recovery routes
through the unchanged L1/L2/L3 policies, and checkpoints are only cut after
a clean flush, so every stored version predates the oldest unvalidated step.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import hostsync
from repro.core.detection import (DetectionEvent, SedarSafeStop, Watchdog,
                                  majority_replica)
from repro.core.fingerprint import (fingerprints_equal, mismatch_report,
                                    pytree_fingerprint)
from repro.core.recovery import (MultiCheckpointRecovery, RecoveryAction,
                                 RetryRecovery, ValidatedCheckpointRecovery)


# ---------------------------------------------------------------------------
# Boundary schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BoundarySchedule:
    """When each SEDAR boundary fires (cadences in steps; 0 = never).

    commit_interval     -- TDC boundary: replica update-fingerprint compare
                           before the commit (paper: validate-before-send).
    validate_interval   -- FSC boundary: full-state fingerprint compare.
    checkpoint_interval -- L2/L3 checkpoint cadence (t_i analogue).
    toe_timeout_s       -- replica flow-separation lapse (TOE boundary).
    validate_lag        -- deferred validation window D (DESIGN.md §11):
                           commit predicates stay on device and are only
                           read back every D commits. 1 = the classic
                           sync-per-compare behavior; >1 trades detection
                           latency (<= D steps) for a sync-free hot path.
    """

    commit_interval: int = 1
    validate_interval: int = 0
    checkpoint_interval: int = 0
    toe_timeout_s: float = 120.0
    validate_lag: int = 1

    @classmethod
    def from_config(cls, sedar) -> "BoundarySchedule":
        return cls(commit_interval=max(int(sedar.validate_interval), 1),
                   validate_interval=int(sedar.param_validate_interval),
                   checkpoint_interval=int(sedar.checkpoint_interval),
                   toe_timeout_s=float(sedar.toe_timeout_s),
                   validate_lag=max(int(getattr(sedar, "validate_lag", 1)), 1))

    @staticmethod
    def _due(step: int, interval: int) -> bool:
        return interval > 0 and step > 0 and step % interval == 0

    def commit_due(self, step: int) -> bool:
        return self.commit_interval > 0 and step % self.commit_interval == 0

    def validate_due(self, step: int) -> bool:
        return self._due(step, self.validate_interval)

    def checkpoint_due(self, step: int) -> bool:
        return self._due(step, self.checkpoint_interval)


@dataclass
class StepOutcome:
    """Result of one protected step. `dual` is ALWAYS the state to continue
    from: the pre-step state when the commit was gated by a detection, the
    committed state otherwise (recovery then acts on it via on_detection)."""

    dual: Any
    aux: Any = None
    event: Optional[DetectionEvent] = None

    @property
    def committed(self) -> bool:
        return self.event is None or self.event.boundary not in ("commit",
                                                                 "toe")


class _EqCache:
    """One-slot memo for the last state-equality reduction, keyed on the id
    of the committed state object. validate() and validated_fp() land on
    the same state within one engine iteration — the reduction must not run
    twice. Executors invalidate on every execute, so a recycled id can
    never alias a stale entry."""

    __slots__ = ("_key", "_value")

    def __init__(self):
        self._key = None
        self._value = None

    def invalidate(self) -> None:
        self._key = None
        self._value = None

    def get(self, state_obj):
        """Cached value, or None on miss (cached values are never None)."""
        return self._value if self._key == id(state_obj) else None

    def put(self, state_obj, value):
        self._key = id(state_obj)
        self._value = value
        return value


def _default_localizer(c0, c1) -> List[Dict[str, Any]]:
    """Leaf-level localization for a commit mismatch: per-leaf fingerprints
    of the two candidate states (the fused compare fingerprint is a single
    hash — localization recomputes at leaf granularity, off the hot path)."""
    fa, fb = pytree_fingerprint(c0), pytree_fingerprint(c1)
    return mismatch_report(c0, fa, fb)[:4]


# ---------------------------------------------------------------------------
# Replica executors
# ---------------------------------------------------------------------------

class ReplicaExecutor:
    """Protocol for redundant-execution backends.

    execute(dual, batch, step, armed, compare)
        -> (dual', aux, event | None); dual' == dual (by value) when event
           is not None.
    execute_deferred(dual, batch, step, armed, compare)
        -> (dual', aux, pred) where `pred` is the ON-DEVICE bool predicate
           "this step's replicas matched" and the commit is OPTIMISTIC
           (candidates adopted without reading pred — the engine's deferred
           ring decides when to sync). Only when `supports_deferred`.
    validate(dual, step)      -> DetectionEvent | None  (FSC boundary)
    validated_fp(dual)        -> (per-leaf fp of r0 [np], replicas_equal)
    init_dual(single)         -> dual state from one logical state
    adopt_single(single)      -> dual state from a restored L3 checkpoint
    primary(dual)             -> replica 0's logical state (the view drivers
                                 read tokens/steps from and L3 checkpoints)
    state_fp(dual)            -> per-leaf fingerprint of r0 (reporting)
    repair(event, dual)       -> (dual', record) | None  (forward correction)
    """

    name = "base"
    n_replicas = 1
    supports_deferred = False

    @property
    def can_validate(self) -> bool:
        """Whether the ENGINE should drive the periodic FSC boundary by
        calling `validate()` after commits (replica backends: compare
        replicas). Executors that implement their own periodic check (abft
        hybrid validates at step ENTRY) return False here and
        `can_validate_final` True."""
        return self.n_replicas > 1

    @property
    def can_validate_final(self) -> bool:
        """Whether `validate()` is meaningful for the end-of-run final
        comparison (paper Sec. 3.1)."""
        return self.can_validate

    def init_dual(self, single):
        return {"r0": single}

    def adopt_single(self, single):
        return {"r0": single}

    def primary(self, dual):
        return dual["r0"]

    def peek(self, dual, key: str):
        """Replica-0 view of ONE top-level state entry — what drivers read
        tokens/step counters through (cheaper than `primary()`, which slices
        every leaf)."""
        return dual["r0"][key]

    def map_state(self, fn, dual, *others):
        """Apply `fn` to EVERY replica's logical state (driver-side state
        surgery: slot admission / eviction / per-slot rollback merges in the
        serving path, DESIGN.md §13). `others` are additional duals whose
        matching replica states are passed as extra positional args. The
        transformation must be replica-symmetric — applying anything
        divergent would manufacture a detection."""
        return {"r0": fn(dual["r0"], *[o["r0"] for o in others])}

    def note_external_update(self) -> None:
        """Drivers call this after `map_state` mutated the resident state
        outside a protected step, so executors that cache state-derived
        baselines (e.g. the hybrid commit-time fingerprint) can drop them
        instead of flagging the legitimate mutation as corruption."""

    def execute_deferred(self, dual, batch, step: int, armed,
                         compare: bool = True):
        raise NotImplementedError(
            f"backend {self.name!r} does not support deferred validation")

    def repair(self, event: DetectionEvent, dual
               ) -> Optional[Tuple[Any, Dict[str, Any]]]:
        return None

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        return None

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        return np.asarray(self.state_fp(dual)), True

    def state_fp(self, dual):
        raise NotImplementedError


class PlainExecutor(ReplicaExecutor):
    """No redundancy: the unprotected baseline (replication='none')."""

    name = "none"
    n_replicas = 1

    def __init__(self, step_fn: Callable, state_fp_fn: Callable):
        self.step_fn = step_fn
        self.state_fp_fn = state_fp_fn

    def execute(self, dual, batch, step: int, armed, compare: bool):
        cand, _fp, aux = self.step_fn(dual["r0"], batch, jnp.asarray(0),
                                      armed)
        return {"r0": cand}, aux, None

    def state_fp(self, dual):
        return self.state_fp_fn(dual["r0"])


class SequentialExecutor(ReplicaExecutor):
    """Time redundancy: replicas run back-to-back on the same devices, each
    owning a FULL state image (the paper's per-thread memory image), so
    FSC-class corruption is representable and detectable."""

    name = "sequential"
    n_replicas = 2
    supports_deferred = True

    def __init__(self, step_fn: Callable, state_fp_fn: Callable,
                 fast_state_fp_fn: Optional[Callable] = None,
                 watchdog: Optional[Watchdog] = None,
                 toe_timeout_s: float = 120.0,
                 delay_source: Optional[Callable[[], dict]] = None,
                 localizer: Callable = _default_localizer):
        self.step_fn = step_fn
        self.state_fp_fn = state_fp_fn
        self.fast_state_fp_fn = fast_state_fp_fn or state_fp_fn
        self.watchdog = watchdog
        self.toe_timeout_s = toe_timeout_s
        self.delay_source = delay_source or (lambda: {})
        self.localizer = localizer
        # EMA of the UNSYNCED per-step dispatch wall (jit-level cost): the
        # fast path never calls block_until_ready just to measure time
        self.ema_step_s: Optional[float] = None
        self._val_cache = _EqCache()

    def init_dual(self, single):
        return {"r0": single, "r1": jax.tree.map(jnp.copy, single)}

    adopt_single = init_dual   # a validated single state seeds both replicas

    def _timing_armed(self, delays: dict) -> bool:
        """Per-replica wall-clock separation (the TOE lapse) requires a
        device sync after EACH replica; pay it only when the boundary can
        actually fire — a scenario delay is pending or the watchdog was
        armed explicitly. Otherwise replica launches overlap freely."""
        return bool(delays) or (self.watchdog is not None
                                and getattr(self.watchdog, "armed", False))

    def _launch(self, dual, batch, step: int, armed, timed: bool,
                delays: dict):
        outs, exec_t = {}, {}
        for rid in range(self.n_replicas):
            # one-shot scenario hook (the paper injects the delay once; the
            # re-execution after recovery is not delayed again)
            delay = delays.pop((step, rid), None)
            t_r = time.monotonic()
            if delay:
                time.sleep(delay)
            outs[rid] = self.step_fn(dual[f"r{rid}"], batch,
                                     jnp.asarray(rid), armed)
            if timed:
                jax.block_until_ready(outs[rid][1])
            exec_t[rid] = time.monotonic() - t_r
            if self.watchdog is not None:
                self.watchdog.beat(rid, step)
        self._val_cache.invalidate()
        return outs, exec_t

    def _note_wall(self, t0: float) -> None:
        dt = time.monotonic() - t0
        self.ema_step_s = dt if self.ema_step_s is None else \
            0.9 * self.ema_step_s + 0.1 * dt

    def _launch_with_toe(self, dual, batch, step: int, armed):
        """Timed dual launch + TOE boundary, shared by the plain and
        slotted sequential executors. Returns (outs, toe_event | None);
        TOE only fires when the per-replica walls were actually synced."""
        delays = self.delay_source() or {}
        timed = self._timing_armed(delays)
        t0 = time.monotonic()
        outs, exec_t = self._launch(dual, batch, step, armed, timed, delays)
        self._note_wall(t0)
        if timed and abs(exec_t[1] - exec_t[0]) > self.toe_timeout_s:
            return outs, DetectionEvent(
                step=step, boundary="toe", effect="TOE",
                detail={"dt0": exec_t[0], "dt1": exec_t[1],
                        "timeout_s": self.toe_timeout_s})
        return outs, None

    def execute(self, dual, batch, step: int, armed, compare: bool):
        outs, toe = self._launch_with_toe(dual, batch, step, armed)
        if toe is not None:
            return dual, outs[0][2], toe

        (c0, fp0, aux0), (c1, fp1, _aux1) = outs[0], outs[1]
        if compare and not hostsync.read_bool(fingerprints_equal(fp0, fp1),
                                              label="commit_compare"):
            detail = {"mismatch": self.localizer(c0, c1)}
            return dual, aux0, DetectionEvent(step=step, boundary="commit",
                                              effect="TDC", detail=detail)
        # containment held (or compare skipped this step): adopt candidates
        return {"r0": c0, "r1": c1}, aux0, None

    def execute_deferred(self, dual, batch, step: int, armed,
                         compare: bool = True):
        """Optimistic commit: both candidates adopted, the match predicate
        stays on device for the engine's deferred ring. No TOE timing (it
        would reintroduce the per-replica sync this path exists to avoid)."""
        delays = self.delay_source() or {}
        t0 = time.monotonic()
        outs, _ = self._launch(dual, batch, step, armed, False, delays)
        self._note_wall(t0)
        (c0, fp0, aux0), (c1, fp1, _aux1) = outs[0], outs[1]
        pred = fingerprints_equal(fp0, fp1)
        return {"r0": c0, "r1": c1}, aux0, pred

    def _resident_eq(self, dual) -> bool:
        """Full-state replica comparison, cached per dual object (_EqCache):
        re-reducing it between validate() and validated_fp() would double
        the FSC cost."""
        hit = self._val_cache.get(dual.get("r0"))
        if hit is not None:
            return hit
        fp0 = self.fast_state_fp_fn(dual["r0"])
        fp1 = self.fast_state_fp_fn(dual["r1"])
        equal = hostsync.read_bool(fingerprints_equal(fp0, fp1),
                                   label="state_validate")
        return self._val_cache.put(dual.get("r0"), equal)

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        if self._resident_eq(dual):
            return None
        return DetectionEvent(step=step, boundary="validate", effect="FSC")

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        return (hostsync.read_scalar(self.state_fp_fn(dual["r0"]),
                                     label="validated_fp"),
                self._resident_eq(dual))

    def state_fp(self, dual):
        return self.state_fp_fn(dual["r0"])

    def map_state(self, fn, dual, *others):
        return {"r0": fn(dual["r0"], *[o["r0"] for o in others]),
                "r1": fn(dual["r1"], *[o["r1"] for o in others])}


# ---------------------------------------------------------------------------
# Slot-granular executors (continuous-batching serving, DESIGN.md §13)
# ---------------------------------------------------------------------------

def _slot_eq(fp0, fp1) -> jnp.ndarray:
    """Per-slot replica equality from PER-SLOT fingerprints (N, 4): exact
    match on the hash words, one bool per sequence slot."""
    return jnp.all(fp0[..., :2] == fp1[..., :2], axis=-1)


def _slot_mismatch_event(eq, step: int,
                         extra: Optional[Dict[str, Any]] = None
                         ) -> DetectionEvent:
    """Fault-path localization shared by the slotted backends: ONE extra
    readback resolves the per-slot equality vector into the event's slot
    list (`detail={slots, partial, ...}`)."""
    eq_h = hostsync.read_scalar(eq, label="slot_compare")
    bad = [int(i) for i in np.nonzero(~np.asarray(eq_h, bool))[0]]
    detail: Dict[str, Any] = {"slots": bad, "partial": True}
    if extra:
        detail.update(extra)
    return DetectionEvent(step=step, boundary="commit", effect="TDC",
                          detail=detail)


def slot_select(mask, new, old, n_slots: int, axis: int = 0):
    """Per-slot pytree merge: `where(mask)` along the slot axis for leaves
    that carry it (shape[axis] == n_slots); leaves WITHOUT a slot axis
    (e.g. the global decode tick) adopt `new` unconditionally."""
    def sel(a, b):
        if a.ndim > axis and a.shape[axis] == n_slots:
            m = jnp.reshape(mask, (1,) * axis + (n_slots,)
                            + (1,) * (a.ndim - axis - 1))
            return jnp.where(m, a, b)
        return a
    return jax.tree.map(sel, new, old)


class SlottedSequentialExecutor(SequentialExecutor):
    """Time redundancy over a PACKED sequence batch (DESIGN.md §13): the
    step_fn's fingerprint carries a leading slot axis (N, 4), so a commit
    mismatch is LOCALIZED to sequence slots and the matching slots'
    candidates are PARTIALLY COMMITTED — one corrupted sequence no longer
    gates the whole batch. Faulty slots keep their pre-step image (their
    per-slot position does not advance), so the next protected step simply
    re-decodes them while the committed slots stream on: the rework quantum
    is the affected sequence, not the batch (cf. Samfass & Weinzierl,
    task-local redundancy)."""

    name = "slotted"

    def __init__(self, *args, n_slots: int = 1, **kw):
        super().__init__(*args, **kw)
        self.n_slots = int(n_slots)

    def execute(self, dual, batch, step: int, armed, compare: bool):
        outs, toe = self._launch_with_toe(dual, batch, step, armed)
        if toe is not None:
            return dual, outs[0][2], toe
        (c0, fp0, aux0), (c1, fp1, _aux1) = outs[0], outs[1]
        if not compare:
            return {"r0": c0, "r1": c1}, aux0, None
        eq = _slot_eq(fp0, fp1)
        if hostsync.read_bool(jnp.all(eq), label="commit_compare"):
            return {"r0": c0, "r1": c1}, aux0, None
        # fault path: the matching slots commit and only the faulty ones
        # stay pre-step
        merged = {"r0": slot_select(eq, c0, dual["r0"], self.n_slots),
                  "r1": slot_select(eq, c1, dual["r1"], self.n_slots)}
        return merged, aux0, _slot_mismatch_event(eq, step)

    def execute_deferred(self, dual, batch, step: int, armed,
                         compare: bool = True):
        """Optimistic per-slot commit: the (N,) match-predicate VECTOR joins
        the engine's deferred ring, so a failed flush localizes both the
        step and the slots."""
        delays = self.delay_source() or {}
        t0 = time.monotonic()
        outs, _ = self._launch(dual, batch, step, armed, False, delays)
        self._note_wall(t0)
        (c0, fp0, aux0), (c1, fp1, _aux1) = outs[0], outs[1]
        return {"r0": c0, "r1": c1}, aux0, _slot_eq(fp0, fp1)


class FusedSequentialExecutor(ReplicaExecutor):
    """Time redundancy in ONE launch (DESIGN.md §11): replica state is
    stacked on a leading axis and both replicas are stepped by a single
    vmapped jit that also computes the replica-equality predicate on device.

    Versus `SequentialExecutor` this removes, per protected step: one kernel
    dispatch (two launches fuse into one), two `block_until_ready` syncs and
    — with the in-jit commit gate or the deferred ring — the per-step host
    readback of the compare bit. With buffer donation the stacked state is
    updated in place, so the dual image stops doubling peak memory on copy.

    The commit gate mirrors the pod backend: candidates are committed only
    `where(eq)`, so a mismatch returns the pre-step values and L0 retry
    re-executes from them even though the input buffers were donated.
    Deferred mode runs the SAME compiled program (one executable for both
    lag modes keeps trajectories bitwise-identical across `validate_lag`
    settings — a second lowering would reassociate float ops) and merely
    skips the predicate readback: a deferred mismatch freezes the replicas
    in place, later steps run batch-skewed until the ring flush localizes
    the faulty step, and checkpoint rollback repairs the skew. Per-replica
    TOE timing is not representable — the replicas share one launch; the
    TOE boundary needs the sequential backend."""

    name = "fused"
    n_replicas = 2
    supports_deferred = True

    def __init__(self, step_fn: Callable, state_fp_fn: Callable,
                 fast_state_fp_fn: Optional[Callable] = None,
                 watchdog: Optional[Watchdog] = None, donate: bool = True):
        self.step_fn = step_fn
        self.state_fp_fn = state_fp_fn
        self.fast_state_fp_fn = fast_state_fp_fn or state_fp_fn
        self.watchdog = watchdog
        self._val_cache = _EqCache()
        self._build_programs(step_fn, donate)

    # -- overridable reduction/commit hooks (the slotted subclass swaps
    # ONLY these two; the launch/validate/donation machinery is shared) ----

    def _replica_eq(self, fps):
        """Traced replica-equality reduction over the stacked fps."""
        return fingerprints_equal(fps[0], fps[1])

    def _commit_gate(self, commit, cands, stacked):
        """Traced commit: adopt `cands` where `commit` holds, else keep
        `stacked` (pre-step). Scalar-predicate gate as a lax.cond, NOT a
        per-leaf jnp.where: select lowers to a full elementwise pass over
        both operands of every leaf (~3x the whole step on CPU), while the
        conditional just forwards the chosen pytree."""
        return jax.lax.cond(jnp.all(commit), lambda c, s: c,
                            lambda c, s: s, cands, stacked)

    def _build_programs(self, step_fn: Callable, donate: bool) -> None:
        n = self.n_replicas

        def _core(stacked, batch, armed):
            rids = jnp.arange(n, dtype=jnp.int32)
            cands, fps, auxs = jax.vmap(
                step_fn, in_axes=(0, None, 0, None))(stacked, batch, rids,
                                                     armed)
            return cands, self._replica_eq(fps), \
                jax.tree.map(lambda a: a[0], auxs)

        def _gated(stacked, batch, armed, compare):
            cands, eq, aux0 = _core(stacked, batch, armed)
            # the gate only bites on compare steps: off-boundary steps must
            # adopt the candidates unconditionally (like the sequential
            # backend) or a divergence there would be silently REVERTED and
            # never reach a detection boundary
            commit = jnp.logical_or(eq, jnp.logical_not(compare))
            return self._commit_gate(commit, cands, stacked), eq, aux0

        def _validate(stacked):
            fps = jax.vmap(self.fast_state_fp_fn)(stacked)
            return fingerprints_equal(fps[0], fps[1])

        # donation is skipped on CPU (XLA:CPU cannot alias; donating only
        # produces "donated buffer unusable" warnings in the test container)
        donate_args = (0,) if (donate and jax.default_backend() != "cpu") \
            else ()
        self._step_gated = jax.jit(_gated, donate_argnums=donate_args)
        self._validate_jit = jax.jit(_validate)

    def init_dual(self, single):
        return {"s": jax.tree.map(
            lambda x: jnp.stack([jnp.asarray(x)] * self.n_replicas), single)}

    adopt_single = init_dual

    def primary(self, dual):
        return jax.tree.map(lambda x: x[0], dual["s"])

    def peek(self, dual, key: str):
        return jax.tree.map(lambda x: x[0], dual["s"][key])

    def _beat(self, step: int) -> None:
        if self.watchdog is not None:
            for rid in range(self.n_replicas):
                self.watchdog.beat(rid, step)

    def _launch(self, dual, batch, step: int, armed, compare: bool):
        new, eq, aux = self._step_gated(dual["s"], batch, armed,
                                        jnp.asarray(compare, jnp.bool_))
        self._val_cache.invalidate()
        self._beat(step)
        return {"s": new}, eq, aux

    def execute(self, dual, batch, step: int, armed, compare: bool):
        dual2, eq, aux = self._launch(dual, batch, step, armed, compare)
        if compare and not hostsync.read_bool(eq, label="commit_compare"):
            # gated: dual2 carries the pre-step values (leaf-level
            # localization would need the discarded candidates; the fused
            # hot path trades it away — the sequential backend keeps it)
            return dual2, aux, DetectionEvent(step=step, boundary="commit",
                                              effect="TDC",
                                              detail={"fused": True})
        return dual2, aux, None

    def execute_deferred(self, dual, batch, step: int, armed,
                         compare: bool = True):
        dual2, eq, aux = self._launch(dual, batch, step, armed, compare)
        return dual2, aux, eq

    def _resident_eq(self, dual) -> bool:
        hit = self._val_cache.get(dual.get("s"))
        if hit is not None:
            return hit
        equal = hostsync.read_bool(self._validate_jit(dual["s"]),
                                   label="state_validate")
        return self._val_cache.put(dual.get("s"), equal)

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        if self._resident_eq(dual):
            return None
        return DetectionEvent(step=step, boundary="validate", effect="FSC")

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        return (hostsync.read_scalar(self.state_fp_fn(self.primary(dual)),
                                     label="validated_fp"),
                self._resident_eq(dual))

    def state_fp(self, dual):
        return self.state_fp_fn(self.primary(dual))

    def map_state(self, fn, dual, *others):
        """Unstack -> apply per replica -> restack. Driver-side surgery is
        off the hot path, so the extra copies are acceptable; fn must be
        replica-symmetric (see the base-class contract)."""
        outs = []
        for i in range(self.n_replicas):
            args = [jax.tree.map(lambda x, i=i: x[i], d["s"])
                    for d in (dual,) + tuple(others)]
            outs.append(fn(*args))
        return {"s": jax.tree.map(lambda *xs: jnp.stack(list(xs)), *outs)}


class SlottedFusedExecutor(FusedSequentialExecutor):
    """Single-launch time redundancy over a packed sequence batch
    (DESIGN.md §13): per-slot fingerprints, and the in-jit commit gate is
    PER SLOT — a `lax.cond` keeps the fault-free path free of the per-leaf
    select (all slots matched -> forward the candidate pytree), and only a
    mismatching step pays the slot-masked merge. Deferred mode runs the
    SAME compiled program and parks the (N,) predicate in the engine ring."""

    name = "slotted_fused"

    def __init__(self, step_fn: Callable, state_fp_fn: Callable,
                 fast_state_fp_fn: Optional[Callable] = None,
                 watchdog: Optional[Watchdog] = None, donate: bool = True,
                 n_slots: int = 1):
        self.n_slots = int(n_slots)     # before _build_programs traces
        super().__init__(step_fn, state_fp_fn,
                         fast_state_fp_fn=fast_state_fp_fn,
                         watchdog=watchdog, donate=donate)

    def _replica_eq(self, fps):
        return _slot_eq(fps[0], fps[1])              # (n_slots,)

    def _commit_gate(self, commit, cands, stacked):
        # per-slot gate; slot axis is 1 (leaves stacked (replica, slot, …)).
        # lax.cond keeps the all-matched fault-free path free of the
        # per-leaf slot_select pass
        return jax.lax.cond(
            jnp.all(commit), lambda c, s: c,
            lambda c, s: slot_select(commit, c, s, self.n_slots, axis=1),
            cands, stacked)

    def execute(self, dual, batch, step: int, armed, compare: bool):
        dual2, eq, aux = self._launch(dual, batch, step, armed, compare)
        if compare and not hostsync.read_bool(jnp.all(eq),
                                              label="commit_compare"):
            # dual2 already carries the per-slot partial commit (in-jit)
            return dual2, aux, _slot_mismatch_event(eq, step,
                                                    {"fused": True})
        return dual2, aux, None

    def execute_deferred(self, dual, batch, step: int, armed,
                         compare: bool = True):
        dual2, eq, aux = self._launch(dual, batch, step, armed, compare)
        return dual2, aux, eq


class PodExecutor(ReplicaExecutor):
    """Space redundancy: replicas are pods of the production mesh; one jit'd
    step runs the compare + gated commit inside shard_map.

    `pod_step(state, batch, armed) -> (new_state, eq, fp_all, aux)` must
    commit candidates only where eq (the in-jit analogue of the sequential
    compare-then-commit); `pod_validate(state) -> (eq, fp_all)` compares
    full-state fingerprints over the replica axis.

    `eq` may be a scalar (legacy whole-state compare) or a per-lane bool
    vector from `make_lane_comparator` (DESIGN.md §16) — all hot-path reads
    reduce it with jnp.all; the lane vector itself is only read back on the
    fault path, where `lane_hosts` (lane indices -> host ids) translates it
    into a device/host localization on the DetectionEvent."""

    name = "pod"
    n_replicas = 2
    supports_deferred = True

    def __init__(self, pod_step: Callable, pod_validate: Callable,
                 state_fp_fn: Callable, *,
                 lane_hosts: Optional[Callable] = None):
        self.pod_step = pod_step
        self.pod_validate = pod_validate
        self.state_fp_fn = state_fp_fn
        self.lane_hosts = lane_hosts
        # last pod_validate reduction (_EqCache): validate() and
        # validated_fp() hit the same committed state in one engine
        # iteration — the all-gather compare must not run twice
        self._val_cache = _EqCache()

    def _lane_detail(self, eq) -> Dict[str, Any]:
        """Fault-path-only localization: read the per-lane predicate back
        and name the disagreeing lanes (and their owning hosts)."""
        if jnp.ndim(eq) == 0:
            return {}
        vec = np.asarray(hostsync.batched_get([eq],
                                              label="commit_lanes")[0])
        lanes = [int(i) for i in np.nonzero(~vec)[0]]
        detail: Dict[str, Any] = {"lanes": lanes}
        if self.lane_hosts is not None and lanes:
            detail["hosts"] = sorted({int(h)
                                      for h in self.lane_hosts(lanes)})
        return detail

    def annotate_event(self, event: DetectionEvent) -> None:
        """Deferred-flush events localize per ring slot; for the pod
        backend a ring slot IS a fingerprint lane — translate."""
        slots = event.detail.get("slots")
        if slots and "lanes" not in event.detail:
            event.detail["lanes"] = list(slots)
            if self.lane_hosts is not None:
                event.detail["hosts"] = sorted(
                    {int(h) for h in self.lane_hosts(slots)})

    def execute(self, dual, batch, step: int, armed, compare: bool):
        new_state, eq, fp_all, aux = self.pod_step(dual["r0"], batch, armed)
        self._val_cache.invalidate()
        if compare and not hostsync.read_bool(jnp.all(eq),
                                              label="commit_compare"):
            return dual, aux, DetectionEvent(step=step, boundary="commit",
                                             effect="TDC",
                                             detail=self._lane_detail(eq))
        return {"r0": new_state}, aux, None

    def execute_deferred(self, dual, batch, step: int, armed,
                         compare: bool = True):
        """pod_step gates the commit in-jit, so a deferred mismatch FREEZES
        the state rather than diverging it; the ring flush still localizes
        the faulty step and rollback repairs the (batch-skewed) replay."""
        new_state, eq, fp_all, aux = self.pod_step(dual["r0"], batch, armed)
        self._val_cache.invalidate()
        return {"r0": new_state}, aux, eq

    def _state_eq(self, dual):
        hit = self._val_cache.get(dual.get("r0"))
        if hit is not None:
            return hit
        eq, fp_all = self.pod_validate(dual["r0"])
        eqb = hostsync.read_bool(jnp.all(eq), label="state_validate")
        return self._val_cache.put(dual.get("r0"), (eqb, fp_all, eq))

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        eqb, fp_all, eq = self._state_eq(dual)
        if eqb:
            return None
        detail = {"fp_all": hostsync.read_scalar(fp_all, label="fp_all")}
        detail.update(self._lane_detail(eq))
        return DetectionEvent(step=step, boundary="validate", effect="FSC",
                              detail=detail)

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        eqb = self._state_eq(dual)[0]
        return (hostsync.read_scalar(self.state_fp_fn(dual["r0"]),
                                     label="validated_fp"), eqb)

    def state_fp(self, dual):
        return self.state_fp_fn(dual["r0"])


class VoteExecutor(PodExecutor):
    """Beyond-paper N-modular redundancy (DESIGN.md §6): >=3 pod replicas.

    A state divergence is repaired FORWARD by broadcasting the majority
    replica's state (no rollback, no recomputation); a transient commit
    mismatch simply re-executes. Falls back to the engine's recovery policy
    when no strict majority exists. Deferred validation is disabled: the
    forward-repair protocol consumes the per-step predicate (and fp_all)
    immediately."""

    name = "vote"
    supports_deferred = False

    def __init__(self, pod_step: Callable, pod_validate: Callable,
                 state_fp_fn: Callable, broadcaster: Callable,
                 n_replicas: int = 3):
        super().__init__(pod_step, pod_validate, state_fp_fn)
        self.broadcaster = broadcaster
        self.n_replicas = n_replicas

    def repair(self, event: DetectionEvent, dual
               ) -> Optional[Tuple[Any, Dict[str, Any]]]:
        if event.boundary in ("validate", "final") and \
                "fp_all" in event.detail:
            src, ok = majority_replica(event.detail["fp_all"])
            if ok:
                repaired = self.broadcaster(src)(dual["r0"])
                return {"r0": repaired}, {"kind": "vote_repair", "step": None,
                                          "rollbacks": 0, "src_replica": src}
            return None
        if event.boundary == "commit":
            # transient update fault: simple re-execution, no rollback
            return dual, {"kind": "vote_retry", "step": None, "rollbacks": 0}
        return None


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class SedarEngine:
    """Composes executor × schedule × recovery × watchdog × injection behind
    `run_protected_step()` + `on_detection()` (DESIGN.md §1).

    The engine owns the event/recovery/checkpoint records for a run
    (`detections`, `recoveries`, `checkpoints`); drivers alias or copy them
    into their own reports. Call `reset()` at the start of each run."""

    def __init__(self, executor: ReplicaExecutor, schedule: BoundarySchedule,
                 recovery, *, watchdog: Optional[Watchdog] = None,
                 inj_spec=None, inj_flag=None,
                 init_fn: Optional[Callable[[], Any]] = None,
                 notify: Optional[Callable[[DetectionEvent], None]] = None):
        self.executor = executor
        self.schedule = schedule
        self.recovery = recovery
        self.watchdog = watchdog
        self.inj_spec = inj_spec
        self.inj_flag = inj_flag
        self.init_fn = init_fn
        self.notify = notify or (lambda e: print(str(e), flush=True))
        self.detections: List[DetectionEvent] = []
        self.recoveries: List[Dict[str, Any]] = []
        self.checkpoints: List[int] = []
        # -- deferred validation window (DESIGN.md §11) ---------------------
        # The effective lag degrades to 1 (classic sync-per-compare) when the
        # executor cannot hand back an on-device predicate, or when recovery
        # is L0 re-execution: a retry can only rewind the CURRENT step, and
        # with optimistic commits the faulty step is up to D steps in the
        # past — only checkpoint rollback (or a stop) can reach it.
        lag = max(int(getattr(schedule, "validate_lag", 1)), 1)
        if lag > 1 and not getattr(executor, "supports_deferred", False):
            lag = 1
        if lag > 1 and isinstance(recovery, RetryRecovery):
            lag = 1
        self.validate_lag = lag
        self._ring: List[Tuple[int, Any]] = []   # device-resident predicates
        self.validated_frontier = 0              # first step NOT yet validated
        # device-resident token emission ring (DESIGN.md §18): when a
        # serving driver attaches one, every deferred step parks its
        # emission refs and flush_deferred fuses the drained window into
        # the SAME readback as the combined commit predicate
        self.emission_ring = None
        # -- live reconfiguration (DESIGN.md §17) ---------------------------
        # autotuner transitions are per-run: reset() restores the configured
        # baseline so a cached engine (serve's _batch_engines) never leaks a
        # tuned knob into the next run
        self.reconfigs: List[Dict[str, Any]] = []
        self._base_schedule = self.schedule
        self._base_lag = self.validate_lag

    @property
    def pending_validation(self) -> bool:
        """True while deferred predicates are parked in the device ring."""
        return bool(self._ring)

    # -- lifecycle ----------------------------------------------------------

    def reset(self) -> None:
        self.detections.clear()
        self.recoveries.clear()
        self.checkpoints.clear()
        self._ring.clear()
        self.validated_frontier = 0
        self.emission_ring = None     # drivers re-attach per run
        self.reconfigs.clear()
        self.schedule = self._base_schedule
        self.validate_lag = self._base_lag

    def apply_reconfig(self, *, validate_lag: Optional[int] = None,
                       checkpoint_interval: Optional[int] = None,
                       tier_schedule=None,
                       reason: str = "") -> Optional[Dict[str, Any]]:
        """Apply an autotuner knob change at a clean boundary.

        Safety argument (DESIGN.md §17): a lag change only takes effect
        when the deferred ring is EMPTY — every optimistic commit so far
        has been validated, so shrinking or growing the window cannot
        strand an unvalidated predicate or change which steps a pending
        fault rolls back. Mid-window calls return None (caller retries at
        the next flush); the same `__init__` clamps apply, so an executor
        without deferred support or an L0-retry recovery keeps lag 1 no
        matter what the tuner asks for. No-op changes return None without
        journaling; an applied transition is appended to `reconfigs` and
        journaled as a `reconfig` line (byte-for-byte via reconcile()).
        """
        if self._ring:
            return None
        changes: Dict[str, Any] = {}
        if validate_lag is not None:
            lag = max(int(validate_lag), 1)
            if lag > 1 and not getattr(self.executor, "supports_deferred",
                                       False):
                lag = 1
            if lag > 1 and isinstance(self.recovery, RetryRecovery):
                lag = 1
            if lag != self.validate_lag:
                changes["validate_lag"] = {"from": self.validate_lag,
                                           "to": lag}
                self.validate_lag = lag
                self.schedule = dataclasses.replace(self.schedule,
                                                    validate_lag=lag)
        if checkpoint_interval is not None:
            ci = max(int(checkpoint_interval), 0)
            if ci != self.schedule.checkpoint_interval:
                changes["checkpoint_interval"] = {
                    "from": self.schedule.checkpoint_interval, "to": ci}
                self.schedule = dataclasses.replace(
                    self.schedule, checkpoint_interval=ci)
                if hasattr(self.recovery, "interval"):
                    self.recovery.interval = ci
        if tier_schedule is not None:
            tiers = getattr(self.recovery, "tiers", None)
            if tiers is not None and tiers.schedule != tier_schedule:
                changes["tier_schedule"] = {
                    "from": dataclasses.asdict(tiers.schedule),
                    "to": dataclasses.asdict(tier_schedule)}
                tiers.schedule = tier_schedule
        if not changes:
            return None
        rec = {"kind": "reconfig", "step": int(self.validated_frontier),
               "reason": str(reason), "changes": changes}
        self.reconfigs.append(rec)
        obs.note_reconfig(rec)
        return rec

    def init_dual(self):
        if self.init_fn is None:
            raise RuntimeError("engine has no init_fn")
        return self.init_fn()

    # -- the protected step --------------------------------------------------

    def run_protected_step(self, dual, batch, step: int) -> StepOutcome:
        """Execute one redundant step at `step`: inject (if armed) ->
        execute replicas -> TDC commit gate (immediate or deferred) -> FSC
        validation boundary -> checkpoint boundary. Returns the state to
        continue from plus the detection event, if any (feed it to
        `on_detection`)."""
        armed = jnp.asarray(
            1 if (self.inj_flag is not None
                  and self.inj_flag.arm_spec(self.inj_spec) is not None)
            else 0, jnp.bool_)
        compare = self.schedule.commit_due(step)

        if self.validate_lag > 1:
            return self._run_deferred(dual, batch, step, armed, compare)

        dual2, aux, event = self.executor.execute(dual, batch, step, armed,
                                                  compare)
        self._mark_injected(step)
        if event is not None:
            return StepOutcome(dual=dual2, aux=aux, event=event)
        # the step committed: consecutive-failure budgets reset (whatever
        # failed before was transient)
        note = getattr(self.recovery, "note_success", None)
        if note is not None:
            note()
        if compare:
            self.validated_frontier = step + 1

        new_step = step + 1
        if self.executor.can_validate and \
                self.schedule.validate_due(new_step):
            with obs.span("validate", step=new_step):
                event = self.executor.validate(dual2, new_step)
            if event is not None:
                return StepOutcome(dual=dual2, aux=aux, event=event)

        # checkpoint boundary (right after validation — minimal window of
        # vulnerability, paper Sec. 3.2)
        event = self._maybe_checkpoint(dual2, new_step)
        return StepOutcome(dual=dual2, aux=aux, event=event)

    def _run_deferred(self, dual, batch, step: int, armed,
                      compare: bool) -> StepOutcome:
        """Zero-sync hot path: the commit is optimistic, the match predicate
        joins the device-resident ring, and the host only reads the ring
        back every `validate_lag` commits or at a validate/checkpoint
        boundary. A fault-free steady-state step performs NO device->host
        transfer (asserted by tests via `hostsync.count_transfers`)."""
        dual2, aux, pred = self.executor.execute_deferred(dual, batch, step,
                                                          armed, compare)
        self._mark_injected(step)
        if compare:
            self._ring.append((step, pred))
        if self.emission_ring is not None:
            # park BEFORE the flush check below, so the window's last tick
            # is in the ring when its own predicate flushes — the emission
            # refs are the step's existing outputs (no launch, no readback)
            self.emission_ring.park(step, aux)

        new_step = step + 1
        # a DURABLE checkpoint tier due at new_step also forces the flush
        # (§11 retention rule extended to the hierarchy); pure device-ring
        # saves do not — they snapshot optimistically inside the window
        sync_due = getattr(self.recovery, "sync_due", None)
        boundary_due = (self.schedule.validate_due(new_step)
                        or self.schedule.checkpoint_due(new_step)
                        or (sync_due is not None and sync_due(new_step)))
        if len(self._ring) >= self.validate_lag or boundary_due:
            event = self.flush_deferred()
            if event is not None:
                return StepOutcome(dual=dual2, aux=aux, event=event)
            note = getattr(self.recovery, "note_success", None)
            if note is not None:
                note()

        if self.executor.can_validate and \
                self.schedule.validate_due(new_step):
            with obs.span("validate", step=new_step):
                event = self.executor.validate(dual2, new_step)
            if event is not None:
                return StepOutcome(dual=dual2, aux=aux, event=event)

        event = self._maybe_checkpoint(dual2, new_step)
        return StepOutcome(dual=dual2, aux=aux, event=event)

    def flush_deferred(self, final: bool = False) -> Optional[DetectionEvent]:
        """Force the deferred-window readback: ONE host read of the combined
        ring predicate; only a failed flush pays a second read to localize
        the first mismatched step. Clean flush advances the validated
        frontier. Drivers call this at end of run; the engine calls it every
        `validate_lag` commits and before validate/checkpoint boundaries.

        With an `emission_ring` attached (DESIGN.md §18) the drained token
        window rides in the SAME `batched_get` as the combined predicate
        (label `token_emit`: one 3-item batch per D commits replaces 2·D
        per-tick emission reads); a failed flush truncates the ring at
        `slot_first_bad` BEFORE delivery, so rolled-back slots retract
        their un-drained tokens by construction. `final=True` forces the
        drain even below the ring's cadence (end of run)."""
        emis = self.emission_ring
        drain = emis.provide(final=final) if emis is not None else None
        if not self._ring:
            if drain is not None:
                # nothing pending validation: every parked row was already
                # proven clean by an earlier flush — pure delivery
                with obs.span("token_drain", rows=len(emis)):
                    vals = hostsync.batched_get(drain, label="token_emit")
                emis.deliver(vals)
            return None
        steps_, preds = zip(*self._ring)
        drain_vals = None
        if drain is not None:
            with obs.span("deferred_flush", steps=len(self._ring),
                          drain_rows=len(emis)):
                vals = hostsync.batched_get(
                    [jnp.all(jnp.stack(list(preds)))] + drain,
                    label="token_emit")
            ok = bool(np.all(vals[0]))
            drain_vals = vals[1:]
        else:
            with obs.span("deferred_flush", steps=len(self._ring)):
                ok = hostsync.read_bool(jnp.all(jnp.stack(list(preds))),
                                        label="deferred_flush")
        if ok:
            self.validated_frontier = steps_[-1] + 1
            self._ring.clear()
            if drain_vals is not None:
                emis.deliver(drain_vals)
            return None
        vals = hostsync.batched_get(list(preds), label="deferred_ring")
        bad = [s for s, v in zip(steps_, vals) if not bool(np.all(v))]
        detected_at = steps_[-1] + 1
        self._ring.clear()
        detail = {"detected_at": detected_at, "lag": detected_at - bad[0],
                  "faulty_steps": bad[:8]}
        # slot-granular localization (DESIGN.md §13): vector predicates
        # carry one bool per sequence slot, so a failed flush also reports
        # WHICH slots diverged and at which step each first went bad — the
        # per-request recovery rolls back only those slots
        slot_first: Optional[Dict[int, int]] = None
        if any(np.ndim(v) for v in vals):
            slot_first = {}
            for s, v in zip(steps_, vals):
                v = np.asarray(v)
                if v.ndim and not v.all():
                    for i in np.nonzero(~v)[0]:
                        slot_first.setdefault(int(i), s)
            detail["slots"] = sorted(slot_first)
            detail["slot_first_bad"] = slot_first
        if emis is not None:
            emis.truncate(slot_first, global_bad=bad[0])
            if drain_vals is not None:
                emis.deliver(drain_vals)
        return DetectionEvent(step=bad[0], boundary="deferred", effect="TDC",
                              detail=detail)

    def validate_final(self, dual, step: int) -> Optional[DetectionEvent]:
        """Final-results comparison (paper Sec. 3.1); the event is tagged
        boundary='final' so NMR repair still applies. Flushes the deferred
        window first — unvalidated optimistic commits must not reach the
        final comparison unexamined."""
        event = self.flush_deferred()
        if event is not None:
            return event
        if not self.executor.can_validate_final:
            return None
        event = self.executor.validate(dual, step)
        if event is not None:
            event.boundary = "final"
        return event

    # -- detection handling ---------------------------------------------------

    def on_detection(self, event: DetectionEvent, dual):
        """Record + notify + recover. Returns the state to continue from;
        raises SedarSafeStop when the policy is (or degrades to) L1."""
        # predicates parked for steps at/after the detection are stale: the
        # recovery target predates them, and a restored trajectory re-runs
        # (and re-validates) those steps
        self._ring.clear()
        annotate = getattr(self.executor, "annotate_event", None)
        if annotate is not None:
            # lane -> device/host localization (DESIGN.md §16), attached
            # before the event is journaled or surfaced to callbacks
            annotate(event)
        self.detections.append(event)
        obs.note_detection(event)
        self.notify(event)

        fix = self.executor.repair(event, dual)
        if fix is not None:
            repaired, record = fix
            record = dict(record, at=event.step)
            self.recoveries.append(record)
            obs.note_recovery(record)
            return repaired

        action: RecoveryAction = self.recovery.on_detection(event)
        record = {"kind": action.kind, "step": action.step,
                  "rollbacks": action.rollbacks, "at": event.step}
        self.recoveries.append(record)
        # journal in a finally so the record goes out AFTER any restore
        # planner info is merged in — and even when safe-stop raises
        try:
            if action.kind == "stop":
                raise SedarSafeStop(event)
            if action.kind == "retry":
                return dual      # transient fault: re-execute the same step
            if action.kind == "restart_scratch":
                self.validated_frontier = 0
                return self.init_dual()
            if action.step is not None:
                self.validated_frontier = min(self.validated_frontier,
                                              action.step)
            if isinstance(self.recovery, ValidatedCheckpointRecovery):
                # L3 stores ONE validated state; re-seed every replica
                # from it
                with obs.span("rollback", step=action.step, kind=action.kind):
                    single = self.recovery.restore(
                        action, self.executor.primary(dual))
                    self._merge_restore_info(record)
                    single = jax.tree.map(jnp.asarray, single)
                    return self.executor.adopt_single(single)
            with obs.span("rollback", step=action.step, kind=action.kind):
                restored = self.recovery.restore(action, dual)
                self._merge_restore_info(record)
                return jax.tree.map(jnp.asarray, restored)
        finally:
            obs.note_recovery(record)

    def _merge_restore_info(self, record: Dict[str, Any]) -> None:
        """Fold the restore planner's outcome (tier, version, any corruption
        fallbacks — DESIGN.md §12) into the already-appended recovery
        record, so drivers report WHERE the state came back from."""
        info = getattr(self.recovery, "last_restore_info", None)
        if info:
            record.update(info)

    # -- internals ------------------------------------------------------------

    def _mark_injected(self, step: int) -> None:
        # persistent (stuck-bit) specs are never marked: the fault
        # re-manifests on every step by definition, so recovery
        # re-executions MUST re-inject (DESIGN.md §13 rejection path)
        if (self.inj_spec is not None and self.inj_flag is not None
                and not getattr(self.inj_spec, "persistent", False)
                and not self.inj_flag.already_injected()
                and step == self.inj_spec.step):
            self.inj_flag.mark()

    def _maybe_checkpoint(self, dual, step: int) -> Optional[DetectionEvent]:
        r = self.recovery
        if isinstance(r, MultiCheckpointRecovery):
            if step == 0 or not r.due(step):
                # the cadence check runs HERE so the off-boundary steps do
                # not pay the state-fingerprint readback (it used to sync
                # every step just to hand maybe_checkpoint an unused array)
                return None
            # fingerprint readback only when a manifest-writing tier saves:
            # a device-ring snapshot (tiered L2, every step) stays sync-free
            fp = hostsync.read_scalar(self.executor.state_fp(dual),
                                      label="checkpoint_fp") \
                if r.fp_needed(step) else None
            with obs.span("checkpoint", step=step):
                if r.maybe_checkpoint(step, dual, fp,
                                      validated_floor=self.validated_frontier):
                    self.checkpoints.append(step)
                    obs.note_checkpoint(step)
            return None
        if isinstance(r, ValidatedCheckpointRecovery):
            if step == 0 or step % r.interval != 0:
                return None
            fp0, fp_equal = self.executor.validated_fp(dual)
            with obs.span("checkpoint", step=step):
                ev = r.maybe_checkpoint(step,
                                        {"r0": self.executor.primary(dual)},
                                        fp0, fp_equal=fp_equal)
            if ev is None:
                self.checkpoints.append(step)
                obs.note_checkpoint(step)
            return ev
        return None   # SafeStop / RetryRecovery store no checkpoints
