"""The unified SEDAR engine: one detection/recovery core for every workload.

Paper Secs. 3.1–3.3 compose three orthogonal mechanisms — replicated
execution (detection), boundary validation (containment), and leveled
checkpointing (recovery). This module is the single place where that
composition lives (DESIGN.md §1):

    SedarEngine = ReplicaExecutor        (how redundant copies execute)
                × BoundarySchedule       (when boundaries fire)
                × recovery policy        (what a detection costs: L0 retry /
                                          L1 stop / L2 chain / L3 validated)
                × Watchdog + injection   (TOE detection, fault campaigns)

Workloads (training, serving, future batch/eval paths) are thin drivers:
they provide a jit-able `step_fn(state, batch, replica_id, armed) ->
(candidate, fingerprint, aux)` plus state fingerprints, then call
`run_protected_step()` per step and `on_detection()` per event. All
compare / commit-gate / validate / checkpoint / rollback / retry logic is
in the engine — no workload re-derives the protocol.

Executor backends:
  * plain       -- no redundancy (the unprotected baseline).
  * sequential  -- time redundancy: both replicas run on the same devices
                   one after the other, each owning a full state image.
  * pod         -- space redundancy: replicas are pods of the production
                   mesh; fingerprints exchanged via all-gather in shard_map.
  * vote        -- N-modular redundancy (beyond-paper, DESIGN.md §6): >=3
                   pod replicas; a divergence is repaired FORWARD by
                   broadcasting the majority replica's state — no rollback.
  * abft/hybrid -- replica-free: checksum-carrying kernels detect (and for
                   single corruptions, forward-correct) in-kernel faults;
                   hybrid adds commit-time fingerprint validation for the
                   classes ABFT cannot see (abft/executor.py, DESIGN.md §10).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detection import (DetectionEvent, SedarSafeStop, Watchdog,
                                  majority_replica)
from repro.core.fingerprint import (fingerprints_equal, mismatch_report,
                                    pytree_fingerprint)
from repro.core.recovery import (MultiCheckpointRecovery, RecoveryAction,
                                 ValidatedCheckpointRecovery)


# ---------------------------------------------------------------------------
# Boundary schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BoundarySchedule:
    """When each SEDAR boundary fires (cadences in steps; 0 = never).

    commit_interval     -- TDC boundary: replica update-fingerprint compare
                           before the commit (paper: validate-before-send).
    validate_interval   -- FSC boundary: full-state fingerprint compare.
    checkpoint_interval -- L2/L3 checkpoint cadence (t_i analogue).
    toe_timeout_s       -- replica flow-separation lapse (TOE boundary).
    """

    commit_interval: int = 1
    validate_interval: int = 0
    checkpoint_interval: int = 0
    toe_timeout_s: float = 120.0

    @classmethod
    def from_config(cls, sedar) -> "BoundarySchedule":
        return cls(commit_interval=max(int(sedar.validate_interval), 1),
                   validate_interval=int(sedar.param_validate_interval),
                   checkpoint_interval=int(sedar.checkpoint_interval),
                   toe_timeout_s=float(sedar.toe_timeout_s))

    @staticmethod
    def _due(step: int, interval: int) -> bool:
        return interval > 0 and step > 0 and step % interval == 0

    def commit_due(self, step: int) -> bool:
        return self.commit_interval > 0 and step % self.commit_interval == 0

    def validate_due(self, step: int) -> bool:
        return self._due(step, self.validate_interval)

    def checkpoint_due(self, step: int) -> bool:
        return self._due(step, self.checkpoint_interval)


@dataclass
class StepOutcome:
    """Result of one protected step. `dual` is ALWAYS the state to continue
    from: the pre-step state when the commit was gated by a detection, the
    committed state otherwise (recovery then acts on it via on_detection)."""

    dual: Any
    aux: Any = None
    event: Optional[DetectionEvent] = None

    @property
    def committed(self) -> bool:
        return self.event is None or self.event.boundary not in ("commit",
                                                                 "toe")


def _default_localizer(c0, c1) -> List[Dict[str, Any]]:
    """Leaf-level localization for a commit mismatch: per-leaf fingerprints
    of the two candidate states (the fused compare fingerprint is a single
    hash — localization recomputes at leaf granularity, off the hot path)."""
    fa, fb = pytree_fingerprint(c0), pytree_fingerprint(c1)
    return mismatch_report(c0, fa, fb)[:4]


# ---------------------------------------------------------------------------
# Replica executors
# ---------------------------------------------------------------------------

class ReplicaExecutor:
    """Protocol for redundant-execution backends.

    execute(dual, batch, step, armed, compare)
        -> (dual', aux, event | None); dual' == dual when event is not None.
    validate(dual, step)      -> DetectionEvent | None  (FSC boundary)
    validated_fp(dual)        -> (per-leaf fp of r0 [np], replicas_equal)
    init_dual(single)         -> dual state from one logical state
    adopt_single(single)      -> dual state from a restored L3 checkpoint
    state_fp(dual)            -> per-leaf fingerprint of r0 (reporting)
    repair(event, dual)       -> (dual', record) | None  (forward correction)
    """

    name = "base"
    n_replicas = 1

    @property
    def can_validate(self) -> bool:
        """Whether the ENGINE should drive the periodic FSC boundary by
        calling `validate()` after commits (replica backends: compare
        replicas). Executors that implement their own periodic check (abft
        hybrid validates at step ENTRY) return False here and
        `can_validate_final` True."""
        return self.n_replicas > 1

    @property
    def can_validate_final(self) -> bool:
        """Whether `validate()` is meaningful for the end-of-run final
        comparison (paper Sec. 3.1)."""
        return self.can_validate

    def init_dual(self, single):
        return {"r0": single}

    def adopt_single(self, single):
        return {"r0": single}

    def repair(self, event: DetectionEvent, dual
               ) -> Optional[Tuple[Any, Dict[str, Any]]]:
        return None

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        return None

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        return np.asarray(self.state_fp(dual)), True

    def state_fp(self, dual):
        raise NotImplementedError


class PlainExecutor(ReplicaExecutor):
    """No redundancy: the unprotected baseline (replication='none')."""

    name = "none"
    n_replicas = 1

    def __init__(self, step_fn: Callable, state_fp_fn: Callable):
        self.step_fn = step_fn
        self.state_fp_fn = state_fp_fn

    def execute(self, dual, batch, step: int, armed, compare: bool):
        cand, _fp, aux = self.step_fn(dual["r0"], batch, jnp.asarray(0),
                                      armed)
        return {"r0": cand}, aux, None

    def state_fp(self, dual):
        return self.state_fp_fn(dual["r0"])


class SequentialExecutor(ReplicaExecutor):
    """Time redundancy: replicas run back-to-back on the same devices, each
    owning a FULL state image (the paper's per-thread memory image), so
    FSC-class corruption is representable and detectable."""

    name = "sequential"
    n_replicas = 2

    def __init__(self, step_fn: Callable, state_fp_fn: Callable,
                 fast_state_fp_fn: Optional[Callable] = None,
                 watchdog: Optional[Watchdog] = None,
                 toe_timeout_s: float = 120.0,
                 delay_source: Optional[Callable[[], dict]] = None,
                 localizer: Callable = _default_localizer):
        self.step_fn = step_fn
        self.state_fp_fn = state_fp_fn
        self.fast_state_fp_fn = fast_state_fp_fn or state_fp_fn
        self.watchdog = watchdog
        self.toe_timeout_s = toe_timeout_s
        self.delay_source = delay_source or (lambda: {})
        self.localizer = localizer

    def init_dual(self, single):
        return {"r0": single, "r1": jax.tree.map(jnp.copy, single)}

    adopt_single = init_dual   # a validated single state seeds both replicas

    def execute(self, dual, batch, step: int, armed, compare: bool):
        outs, exec_t = {}, {}
        delays = self.delay_source() or {}
        for rid in range(self.n_replicas):
            # one-shot scenario hook (the paper injects the delay once; the
            # re-execution after recovery is not delayed again)
            delay = delays.pop((step, rid), None)
            t_r = time.monotonic()
            if delay:
                time.sleep(delay)
            outs[rid] = self.step_fn(dual[f"r{rid}"], batch,
                                     jnp.asarray(rid), armed)
            jax.block_until_ready(outs[rid][1])
            exec_t[rid] = time.monotonic() - t_r
            if self.watchdog is not None:
                self.watchdog.beat(rid, step)

        # TOE: replica flow separation beyond the configured lapse
        if abs(exec_t[1] - exec_t[0]) > self.toe_timeout_s:
            return dual, outs[0][2], DetectionEvent(
                step=step, boundary="toe", effect="TOE",
                detail={"dt0": exec_t[0], "dt1": exec_t[1],
                        "timeout_s": self.toe_timeout_s})

        (c0, fp0, aux0), (c1, fp1, _aux1) = outs[0], outs[1]
        if compare and not bool(np.asarray(fingerprints_equal(fp0, fp1))):
            detail = {"mismatch": self.localizer(c0, c1)}
            return dual, aux0, DetectionEvent(step=step, boundary="commit",
                                              effect="TDC", detail=detail)
        # containment held (or compare skipped this step): adopt candidates
        return {"r0": c0, "r1": c1}, aux0, None

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        fp0 = self.fast_state_fp_fn(dual["r0"])
        fp1 = self.fast_state_fp_fn(dual["r1"])
        if bool(np.asarray(fingerprints_equal(fp0, fp1))):
            return None
        return DetectionEvent(step=step, boundary="validate", effect="FSC")

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        fp0 = self.fast_state_fp_fn(dual["r0"])
        fp1 = self.fast_state_fp_fn(dual["r1"])
        equal = bool(np.asarray(fingerprints_equal(fp0, fp1)))
        return np.asarray(self.state_fp_fn(dual["r0"])), equal

    def state_fp(self, dual):
        return self.state_fp_fn(dual["r0"])


class PodExecutor(ReplicaExecutor):
    """Space redundancy: replicas are pods of the production mesh; one jit'd
    step runs the compare + gated commit inside shard_map.

    `pod_step(state, batch, armed) -> (new_state, eq, fp_all, aux)` must
    commit candidates only where eq (the in-jit analogue of the sequential
    compare-then-commit); `pod_validate(state) -> (eq, fp_all)` compares
    full-state fingerprints over the replica axis."""

    name = "pod"
    n_replicas = 2

    def __init__(self, pod_step: Callable, pod_validate: Callable,
                 state_fp_fn: Callable):
        self.pod_step = pod_step
        self.pod_validate = pod_validate
        self.state_fp_fn = state_fp_fn

    def execute(self, dual, batch, step: int, armed, compare: bool):
        new_state, eq, fp_all, aux = self.pod_step(dual["r0"], batch, armed)
        if compare and not bool(np.asarray(eq)):
            return dual, aux, DetectionEvent(step=step, boundary="commit",
                                             effect="TDC")
        return {"r0": new_state}, aux, None

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        eq, fp_all = self.pod_validate(dual["r0"])
        if bool(np.asarray(eq)):
            return None
        return DetectionEvent(step=step, boundary="validate", effect="FSC",
                              detail={"fp_all": np.asarray(fp_all)})

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        eq, _ = self.pod_validate(dual["r0"])
        return np.asarray(self.state_fp_fn(dual["r0"])), bool(np.asarray(eq))

    def state_fp(self, dual):
        return self.state_fp_fn(dual["r0"])


class VoteExecutor(PodExecutor):
    """Beyond-paper N-modular redundancy (DESIGN.md §6): >=3 pod replicas.

    A state divergence is repaired FORWARD by broadcasting the majority
    replica's state (no rollback, no recomputation); a transient commit
    mismatch simply re-executes. Falls back to the engine's recovery policy
    when no strict majority exists."""

    name = "vote"

    def __init__(self, pod_step: Callable, pod_validate: Callable,
                 state_fp_fn: Callable, broadcaster: Callable,
                 n_replicas: int = 3):
        super().__init__(pod_step, pod_validate, state_fp_fn)
        self.broadcaster = broadcaster
        self.n_replicas = n_replicas

    def repair(self, event: DetectionEvent, dual
               ) -> Optional[Tuple[Any, Dict[str, Any]]]:
        if event.boundary in ("validate", "final") and \
                "fp_all" in event.detail:
            src, ok = majority_replica(event.detail["fp_all"])
            if ok:
                repaired = self.broadcaster(src)(dual["r0"])
                return {"r0": repaired}, {"kind": "vote_repair", "step": None,
                                          "rollbacks": 0, "src_replica": src}
            return None
        if event.boundary == "commit":
            # transient update fault: simple re-execution, no rollback
            return dual, {"kind": "vote_retry", "step": None, "rollbacks": 0}
        return None


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class SedarEngine:
    """Composes executor × schedule × recovery × watchdog × injection behind
    `run_protected_step()` + `on_detection()` (DESIGN.md §1).

    The engine owns the event/recovery/checkpoint records for a run
    (`detections`, `recoveries`, `checkpoints`); drivers alias or copy them
    into their own reports. Call `reset()` at the start of each run."""

    def __init__(self, executor: ReplicaExecutor, schedule: BoundarySchedule,
                 recovery, *, watchdog: Optional[Watchdog] = None,
                 inj_spec=None, inj_flag=None,
                 init_fn: Optional[Callable[[], Any]] = None,
                 notify: Optional[Callable[[DetectionEvent], None]] = None):
        self.executor = executor
        self.schedule = schedule
        self.recovery = recovery
        self.watchdog = watchdog
        self.inj_spec = inj_spec
        self.inj_flag = inj_flag
        self.init_fn = init_fn
        self.notify = notify or (lambda e: print(str(e), flush=True))
        self.detections: List[DetectionEvent] = []
        self.recoveries: List[Dict[str, Any]] = []
        self.checkpoints: List[int] = []

    # -- lifecycle ----------------------------------------------------------

    def reset(self) -> None:
        self.detections.clear()
        self.recoveries.clear()
        self.checkpoints.clear()

    def init_dual(self):
        if self.init_fn is None:
            raise RuntimeError("engine has no init_fn")
        return self.init_fn()

    # -- the protected step --------------------------------------------------

    def run_protected_step(self, dual, batch, step: int) -> StepOutcome:
        """Execute one redundant step at `step`: inject (if armed) ->
        execute replicas -> TDC commit gate -> FSC validation boundary ->
        checkpoint boundary. Returns the state to continue from plus the
        detection event, if any (feed it to `on_detection`)."""
        armed = jnp.asarray(
            1 if (self.inj_flag is not None
                  and self.inj_flag.arm_spec(self.inj_spec) is not None)
            else 0, jnp.bool_)
        compare = self.schedule.commit_due(step)
        dual2, aux, event = self.executor.execute(dual, batch, step, armed,
                                                  compare)
        self._mark_injected(step)
        if event is not None:
            return StepOutcome(dual=dual2, aux=aux, event=event)
        # the step committed: consecutive-failure budgets reset (whatever
        # failed before was transient)
        note = getattr(self.recovery, "note_success", None)
        if note is not None:
            note()

        new_step = step + 1
        if self.executor.can_validate and \
                self.schedule.validate_due(new_step):
            event = self.executor.validate(dual2, new_step)
            if event is not None:
                return StepOutcome(dual=dual2, aux=aux, event=event)

        # checkpoint boundary (right after validation — minimal window of
        # vulnerability, paper Sec. 3.2)
        event = self._maybe_checkpoint(dual2, new_step)
        return StepOutcome(dual=dual2, aux=aux, event=event)

    def validate_final(self, dual, step: int) -> Optional[DetectionEvent]:
        """Final-results comparison (paper Sec. 3.1); the event is tagged
        boundary='final' so NMR repair still applies."""
        if not self.executor.can_validate_final:
            return None
        event = self.executor.validate(dual, step)
        if event is not None:
            event.boundary = "final"
        return event

    # -- detection handling ---------------------------------------------------

    def on_detection(self, event: DetectionEvent, dual):
        """Record + notify + recover. Returns the state to continue from;
        raises SedarSafeStop when the policy is (or degrades to) L1."""
        self.detections.append(event)
        self.notify(event)

        fix = self.executor.repair(event, dual)
        if fix is not None:
            repaired, record = fix
            record = dict(record, at=event.step)
            self.recoveries.append(record)
            return repaired

        action: RecoveryAction = self.recovery.on_detection(event)
        self.recoveries.append({"kind": action.kind, "step": action.step,
                                "rollbacks": action.rollbacks,
                                "at": event.step})
        if action.kind == "stop":
            raise SedarSafeStop(event)
        if action.kind == "retry":
            return dual          # transient fault: re-execute the same step
        if action.kind == "restart_scratch":
            return self.init_dual()
        if isinstance(self.recovery, ValidatedCheckpointRecovery):
            # L3 stores ONE validated state; re-seed every replica from it
            single = self.recovery.restore(action, dual["r0"])
            single = jax.tree.map(jnp.asarray, single)
            return self.executor.adopt_single(single)
        restored = self.recovery.restore(action, dual)
        return jax.tree.map(jnp.asarray, restored)

    # -- internals ------------------------------------------------------------

    def _mark_injected(self, step: int) -> None:
        if (self.inj_spec is not None and self.inj_flag is not None
                and not self.inj_flag.already_injected()
                and step == self.inj_spec.step):
            self.inj_flag.mark()

    def _maybe_checkpoint(self, dual, step: int) -> Optional[DetectionEvent]:
        r = self.recovery
        if isinstance(r, MultiCheckpointRecovery):
            if r.maybe_checkpoint(step, dual,
                                  np.asarray(self.executor.state_fp(dual))):
                self.checkpoints.append(step)
            return None
        if isinstance(r, ValidatedCheckpointRecovery):
            if step == 0 or step % r.interval != 0:
                return None
            fp0, fp_equal = self.executor.validated_fp(dual)
            ev = r.maybe_checkpoint(step, dual, fp0, fp_equal=fp_equal)
            if ev is None:
                self.checkpoints.append(step)
            return ev
        return None   # SafeStop / RetryRecovery store no checkpoints
