"""State fingerprinting — SEDAR's comparison primitive.

The paper compares full message buffers between replicated threads (cheap in
a shared L2). On TPU the replicas are pods, so we compress every tensor into
a 128-bit fingerprint + 2 diagnostic stats in ONE streaming pass and compare
only fingerprints across the replica axis (a few hundred bytes over ICI/DCN).

Fingerprint of a tensor (after exact upcast to f32 and bitcast to u32):
    h1 = sum_i ((x_i XOR (i * C1)) * C2)       mod 2^32  (order-sensitive sum)
    h2 = sum_i (t XOR (t >> 15)), t = (x_i+i)*C3         (independent mix)
    s  = sum(x)  (f32)                                   (diagnostic)
    a  = max(|x|) (f32)                                  (diagnostic)

(Both hashes reduce with modular ADD — XLA lowers add-reductions everywhere
incl. SPMD partitions; xor-fold reductions are rejected by some backends.)

Both h1 and h2 are associative/commutative reductions over position-mixed
words, so they vectorize on the VPU, tile cleanly in VMEM (see
kernels/fingerprint.py for the Pallas version) and are bitwise deterministic.
A single flipped bit anywhere changes h1 (and almost surely h2).

`pytree_fingerprint` returns a (n_leaves, 4) uint32 array (stats bitcast), so
replica comparison is a single small array equality.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

C1 = np.uint32(2654435761)   # Knuth multiplicative
C2 = np.uint32(2246822519)   # xxhash prime
C3 = np.uint32(3266489917)   # xxhash prime


def _to_u32(x) -> jnp.ndarray:
    """Exact reinterpretation of any dtype as a flat u32 vector."""
    x = jnp.asarray(x)
    if x.dtype in (jnp.float64, jnp.int64):  # CPU tests may use 64-bit
        x = x.astype(jnp.float32) if jnp.issubdtype(x.dtype, jnp.floating) \
            else x.astype(jnp.int32)
    if x.dtype in (jnp.bfloat16, jnp.float16):
        x = x.astype(jnp.float32)            # exact upcast
    if x.dtype == jnp.float32:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    elif x.dtype in (jnp.int32, jnp.uint32):
        u = x.astype(jnp.uint32)
    elif x.dtype == jnp.bool_:
        u = x.astype(jnp.uint32)
    elif x.dtype in (jnp.int8, jnp.uint8, jnp.int16, jnp.uint16):
        u = x.astype(jnp.uint32)
    else:
        raise TypeError(f"unsupported dtype {x.dtype}")
    return u.reshape(-1)


def tensor_fingerprint(x) -> jnp.ndarray:
    """-> (4,) uint32: [h1, h2, bits(sum), bits(absmax)]."""
    u = _to_u32(x)
    n = u.shape[0]
    idx = jax.lax.iota(jnp.uint32, n)
    h1 = jnp.sum((u ^ (idx * C1)) * C2, dtype=jnp.uint32)
    t2 = (u + idx) * C3
    h2 = jnp.sum(t2 ^ (t2 >> jnp.uint32(15)), dtype=jnp.uint32)
    xf = jnp.asarray(x)
    if jnp.issubdtype(xf.dtype, jnp.floating):
        xf32 = xf.astype(jnp.float32)
        s = jnp.sum(xf32)
        a = jnp.max(jnp.abs(xf32)) if xf.size else jnp.float32(0)
    else:
        s = jnp.float32(0)
        a = jnp.float32(0)
    sb = jax.lax.bitcast_convert_type(s.astype(jnp.float32), jnp.uint32)
    ab = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.uint32)
    return jnp.stack([h1, h2, sb, ab])


def pytree_fingerprint(tree, use_pallas: bool = False) -> jnp.ndarray:
    """-> (n_leaves, 4) uint32, leaf order = tree_flatten order."""
    leaves = jax.tree.leaves(tree)
    if use_pallas:
        from repro.kernels.ops import fingerprint as fp_kernel
        fps = [fp_kernel(l) for l in leaves]
    else:
        fps = [tensor_fingerprint(l) for l in leaves]
    return jnp.stack(fps) if fps else jnp.zeros((0, 4), jnp.uint32)


def fingerprints_equal(fp_a, fp_b) -> jnp.ndarray:
    """Exact equality on the hash words (cols 0..1); stats are diagnostics."""
    return jnp.all(fp_a[..., :2] == fp_b[..., :2])


def mismatch_report(tree, fp_a, fp_b):
    """Host-side: list of (leaf_path, fp_a_row, fp_b_row) that differ."""
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    a = np.asarray(fp_a)
    b = np.asarray(fp_b)
    out = []
    for i, path in enumerate(paths):
        if not np.array_equal(a[i, :2], b[i, :2]):
            out.append({
                "leaf": path,
                "h_a": [int(a[i, 0]), int(a[i, 1])],
                "h_b": [int(b[i, 0]), int(b[i, 1])],
                "sum_a": float(np.frombuffer(a[i, 2].tobytes(), np.float32)[0]),
                "sum_b": float(np.frombuffer(b[i, 2].tobytes(), np.float32)[0]),
            })
    return out
