"""State fingerprinting — SEDAR's comparison primitive.

The paper compares full message buffers between replicated threads (cheap in
a shared L2). On TPU the replicas are pods, so we compress every tensor into
a 128-bit fingerprint + 2 diagnostic stats in ONE streaming pass and compare
only fingerprints across the replica axis (a few hundred bytes over ICI/DCN).

Fingerprint of a tensor (after exact upcast to f32 and bitcast to u32):
    h1 = sum_i ((x_i XOR (i * C1)) * C2)       mod 2^32  (order-sensitive sum)
    h2 = sum_i (t XOR (t >> 15)), t = (x_i+i)*C3         (independent mix)
    s  = sum(x)  (f32)                                   (diagnostic)
    a  = max(|x|) (f32)                                  (diagnostic)

(Both hashes reduce with modular ADD — XLA lowers add-reductions everywhere
incl. SPMD partitions; xor-fold reductions are rejected by some backends.)

Both h1 and h2 are associative/commutative reductions over position-mixed
words, so they vectorize on the VPU, tile cleanly in VMEM (see
kernels/fingerprint.py for the Pallas version) and are bitwise deterministic.
A single flipped bit anywhere changes h1 (and almost surely h2).

`pytree_fingerprint` returns a (n_leaves, 4) uint32 array (stats bitcast), so
replica comparison is a single small array equality.

Two granularities (DESIGN.md §5):
  * per-leaf  -- `pytree_fingerprint` -> (n_leaves, 4). One reduction per
    leaf; keeps leaf-level localization for `mismatch_report`.
  * fused     -- `pytree_fingerprint_fused` -> (4,). All leaves are packed
    (bit-exactly, via `_to_u32`) into ONE flat u32 buffer and hashed in a
    single streaming pass — one kernel launch instead of n_leaves, which is
    what the comparison hot path wants (models have hundreds of leaves, most
    of them small). The fused hash is NOT comparable to per-leaf hashes
    (different index stream); both replicas must use the same granularity.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

C1 = np.uint32(2654435761)   # Knuth multiplicative
C2 = np.uint32(2246822519)   # xxhash prime
C3 = np.uint32(3266489917)   # xxhash prime


def _to_u32(x) -> jnp.ndarray:
    """Exact reinterpretation of any dtype as a flat u32 vector."""
    x = jnp.asarray(x)
    if x.dtype in (jnp.float64, jnp.int64):  # CPU tests may use 64-bit
        x = x.astype(jnp.float32) if jnp.issubdtype(x.dtype, jnp.floating) \
            else x.astype(jnp.int32)
    if x.dtype in (jnp.bfloat16, jnp.float16):
        x = x.astype(jnp.float32)            # exact upcast
    if x.dtype == jnp.float32:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    elif x.dtype in (jnp.int32, jnp.uint32):
        u = x.astype(jnp.uint32)
    elif x.dtype == jnp.bool_:
        u = x.astype(jnp.uint32)
    elif x.dtype in (jnp.int8, jnp.uint8, jnp.int16, jnp.uint16):
        u = x.astype(jnp.uint32)
    else:
        raise TypeError(f"unsupported dtype {x.dtype}")
    return u.reshape(-1)


def tensor_fingerprint(x) -> jnp.ndarray:
    """-> (4,) uint32: [h1, h2, bits(sum), bits(absmax)]."""
    u = _to_u32(x)
    n = u.shape[0]
    idx = jax.lax.iota(jnp.uint32, n)
    h1 = jnp.sum((u ^ (idx * C1)) * C2, dtype=jnp.uint32)
    t2 = (u + idx) * C3
    h2 = jnp.sum(t2 ^ (t2 >> jnp.uint32(15)), dtype=jnp.uint32)
    xf = jnp.asarray(x)
    if jnp.issubdtype(xf.dtype, jnp.floating):
        xf32 = xf.astype(jnp.float32)
        s = jnp.sum(xf32)
        a = jnp.max(jnp.abs(xf32)) if xf.size else jnp.float32(0)
    else:
        s = jnp.float32(0)
        a = jnp.float32(0)
    sb = jax.lax.bitcast_convert_type(s.astype(jnp.float32), jnp.uint32)
    ab = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.uint32)
    return jnp.stack([h1, h2, sb, ab])


def pytree_fingerprint(tree, use_pallas: bool = False) -> jnp.ndarray:
    """-> (n_leaves, 4) uint32, leaf order = tree_flatten order."""
    leaves = jax.tree.leaves(tree)
    if use_pallas:
        from repro.kernels.ops import fingerprint as fp_kernel
        fps = [fp_kernel(l) for l in leaves]
    else:
        fps = [tensor_fingerprint(l) for l in leaves]
    return jnp.stack(fps) if fps else jnp.zeros((0, 4), jnp.uint32)


def pack_tree_u32(tree) -> jnp.ndarray:
    """Bit-exact packing of every leaf into one flat u32 buffer
    (tree_flatten order). The packing is a reinterpretation, not a value
    conversion, so any single corrupted bit in any leaf is a corrupted bit
    in the packed buffer."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((0,), jnp.uint32)
    return jnp.concatenate([_to_u32(l) for l in leaves])


def packed_fingerprint(u: jnp.ndarray) -> jnp.ndarray:
    """Fingerprint of an already-packed u32 buffer -> (4,) uint32.

    Same mixing as `tensor_fingerprint`, with the kernel's diagnostic
    convention: sum/absmax are computed over the f32 REINTERPRETATION of the
    packed words (matches kernels/fingerprint.py bit-for-bit on the hash
    words; the float stats are diagnostics only).

    Non-u32 input is bit-reinterpreted via `_to_u32` (never value-cast —
    a value cast would truncate every float in (-1, 1) to 0 and make the
    fingerprint blind to corruption)."""
    u = jnp.asarray(u)
    if u.dtype != jnp.uint32:
        u = _to_u32(u)
    u = u.reshape(-1)
    n = u.shape[0]
    if n == 0:
        return jnp.zeros((4,), jnp.uint32)
    idx = jax.lax.iota(jnp.uint32, n)
    h1 = jnp.sum((u ^ (idx * C1)) * C2, dtype=jnp.uint32)
    t2 = (u + idx) * C3
    h2 = jnp.sum(t2 ^ (t2 >> jnp.uint32(15)), dtype=jnp.uint32)
    xf = jax.lax.bitcast_convert_type(u, jnp.float32)
    sb = jax.lax.bitcast_convert_type(jnp.sum(xf), jnp.uint32)
    ab = jax.lax.bitcast_convert_type(jnp.max(jnp.abs(xf)), jnp.uint32)
    return jnp.stack([h1, h2, sb, ab])


def pytree_fingerprint_fused(tree, use_pallas: Optional[bool] = None
                             ) -> jnp.ndarray:
    """Whole-state fingerprint -> (4,) uint32: ONE fingerprint over the
    logically-packed state instead of one per leaf.

    Two value-identical lowerings of the same hash (hash words compare equal
    across both — verified by tests):
      * Pallas (accelerators): flatten/concatenate the leaves once into a
        packed u32 buffer and make a single `fingerprint_pallas` pass over
        it — one kernel launch for the whole state.
      * jnp (CPU/XLA): per-leaf partial reductions with GLOBAL element
        offsets folded into the index stream, combined with one final
        add/max. Modular-add reductions are associative/commutative, so the
        partials sum to exactly the packed-buffer hash — without
        materializing the concatenation (which would cost an extra full
        write+read pass).

    `use_pallas=None` auto-selects from the JAX backend."""
    if use_pallas is None:
        from repro.kernels.fingerprint import default_interpret
        use_pallas = not default_interpret()
    if use_pallas:
        u = pack_tree_u32(tree)
        if u.shape[0]:
            from repro.kernels.ops import fingerprint_packed
            return fingerprint_packed(u)
        return jnp.zeros((4,), jnp.uint32)

    leaves = jax.tree.leaves(tree)
    h1s, h2s, ss, as_ = [], [], [], []
    offset = 0
    for l in leaves:
        u = _to_u32(l)
        n = u.shape[0]
        if n == 0:
            continue
        idx = jnp.uint32(offset) + jax.lax.iota(jnp.uint32, n)
        h1s.append(jnp.sum((u ^ (idx * C1)) * C2, dtype=jnp.uint32))
        t2 = (u + idx) * C3
        h2s.append(jnp.sum(t2 ^ (t2 >> jnp.uint32(15)), dtype=jnp.uint32))
        xf = jax.lax.bitcast_convert_type(u, jnp.float32)
        ss.append(jnp.sum(xf))
        as_.append(jnp.max(jnp.abs(xf)))
        offset += n
    if not h1s:
        return jnp.zeros((4,), jnp.uint32)
    h1 = jnp.sum(jnp.stack(h1s), dtype=jnp.uint32)
    h2 = jnp.sum(jnp.stack(h2s), dtype=jnp.uint32)
    s = jnp.sum(jnp.stack(ss))
    a = jnp.max(jnp.stack(as_))
    return jnp.stack([h1, h2, jax.lax.bitcast_convert_type(s, jnp.uint32),
                      jax.lax.bitcast_convert_type(a, jnp.uint32)])


def pytree_fingerprint_lanes(tree, n_lanes: int) -> jnp.ndarray:
    """Per-shard fingerprint lanes -> (n_lanes, 4) uint32 (DESIGN.md §16).

    The packed state is split into `n_lanes` equal contiguous chunks
    (zero-padded tail) and each chunk is hashed independently, so a replica
    divergence localizes to the lane covering the corrupted words instead
    of collapsing into one whole-state bit. Lane i covers packed u32 words
    [i*W, (i+1)*W), W = ceil(N/n_lanes); callers align n_lanes with shard
    ownership (lane index -> data shard -> host, see
    runtime/cluster.lanes_to_hosts). NOT comparable with the fused or
    per-leaf granularities (different index streams)."""
    L = max(int(n_lanes), 1)
    u = pack_tree_u32(tree)
    n = int(u.shape[0])
    if n == 0:
        return jnp.zeros((L, 4), jnp.uint32)
    width = -(-n // L)
    pad = L * width - n
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,), jnp.uint32)])
    return jax.vmap(packed_fingerprint)(u.reshape(L, width))


def lane_of_leaf_index(tree, leaf_idx: int, flat_idx: int, n_lanes: int
                       ) -> int:
    """Host-side: which fingerprint lane covers element `flat_idx` of leaf
    `leaf_idx` (tree_flatten order) under `pytree_fingerprint_lanes`.
    Assumes 32-bit leaves (one packed word per element), which holds for
    every training state here after `_to_u32`'s 64->32 narrowing."""
    leaves = jax.tree.leaves(tree)
    off = sum(int(np.size(l)) for l in leaves[:leaf_idx]) + int(flat_idx)
    total = sum(int(np.size(l)) for l in leaves)
    L = max(int(n_lanes), 1)
    width = -(-total // L)
    return off // width


def fingerprints_equal(fp_a, fp_b) -> jnp.ndarray:
    """Exact equality on the hash words (cols 0..1); stats are diagnostics."""
    return jnp.all(fp_a[..., :2] == fp_b[..., :2])


def mismatch_report(tree, fp_a, fp_b):
    """Host-side: list of (leaf_path, fp_a_row, fp_b_row) that differ."""
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    a = np.asarray(fp_a)
    b = np.asarray(fp_b)
    out = []
    for i, path in enumerate(paths):
        if not np.array_equal(a[i, :2], b[i, :2]):
            out.append({
                "leaf": path,
                "h_a": [int(a[i, 0]), int(a[i, 1])],
                "h_b": [int(b[i, 0]), int(b[i, 1])],
                "sum_a": float(np.frombuffer(a[i, 2].tobytes(), np.float32)[0]),
                "sum_b": float(np.frombuffer(b[i, 2].tobytes(), np.float32)[0]),
            })
    return out
