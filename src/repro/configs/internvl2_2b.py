"""internvl2-2b [vlm] — arXiv:2404.16821 (hf tier).

Backbone: InternLM2-1.8B — 24L d_model=2048 16H (GQA kv=8, head_dim=128)
d_ff=8192 vocab=92553.

The InternViT-300M vision frontend is a STUB per the task spec: input_specs()
supplies precomputed patch embeddings (batch, frontend_seq, d_model) that are
concatenated in front of the token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8_192,
    vocab_size=92_553,
    frontend="vision_stub",
    frontend_seq=256,        # 256 visual tokens after pixel-shuffle (448px / 14 / 2)^2
    frontend_dim=2_048,      # already projected to backbone width by the stub
    rope_theta=1_000_000.0,
)
