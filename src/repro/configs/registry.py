"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_ARCH_MODULES: Dict[str, str] = {
    "mistral-large-123b":    "repro.configs.mistral_large_123b",
    "starcoder2-7b":         "repro.configs.starcoder2_7b",
    "qwen2-72b":             "repro.configs.qwen2_72b",
    "qwen2-0.5b":            "repro.configs.qwen2_0_5b",
    "phi3.5-moe-42b-a6.6b":  "repro.configs.phi35_moe_42b",
    "dbrx-132b":             "repro.configs.dbrx_132b",
    "recurrentgemma-2b":     "repro.configs.recurrentgemma_2b",
    "internvl2-2b":          "repro.configs.internvl2_2b",
    "seamless-m4t-medium":   "repro.configs.seamless_m4t_medium",
    "xlstm-125m":            "repro.configs.xlstm_125m",
    "paper-testapp":         "repro.configs.paper_testapp",
}

ASSIGNED_ARCHS: List[str] = [k for k in _ARCH_MODULES if k != "paper-testapp"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(sorted(_ARCH_MODULES))}"
        )
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)
