"""starcoder2-7b [dense] — arXiv:2402.19173 (hf tier).

32L d_model=4608 36H (GQA kv=4, head_dim=128) d_ff=18432 vocab=49152. GQA + RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4_608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_432,
    vocab_size=49_152,
    qkv_bias=True,          # starcoder2 uses bias on linear layers
    rope_theta=100_000.0,
    mlp_act="gelu",         # starcoder2 uses a plain GELU MLP (d_ff = 4*d)
)
