"""The paper's synthetic test application, adapted.

The paper validates SEDAR on an MPI Master/Worker matrix multiplication
(C = A x B) with checkpoints cut after every communication phase
(CK0 / SCATTER / CK1 / BCAST / CK2 / MATMUL / GATHER / CK3 / VALIDATE).

Our analogue is a tiny dense LM whose train step exposes the same boundary
structure (grad all-reduce == the "send"; optimizer commit == checkpointable
phase; final param fingerprint == VALIDATE). The scenario campaign in
core/scenarios.py runs against this config. Additionally, core/scenarios.py
contains a literal Master/Worker matmul phase machine used to reproduce the
paper's 64-scenario Table-2 taxonomy exactly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-testapp",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=1_024,
    vocab_size=1_024,
    dtype="float32",
    param_dtype="float32",
    remat="none",
)
