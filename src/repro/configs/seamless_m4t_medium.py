"""seamless-m4t-medium [audio] — arXiv:2308.11596 (hf tier).

Enc-dec transformer backbone: 12 encoder + 12 decoder layers, d_model=1024,
16H (kv=16, head_dim=64), d_ff=4096, vocab=256206.

The speech frontend (w2v-BERT conformer) is a STUB per the task spec:
input_specs() supplies precomputed frame embeddings (batch, frontend_seq, 1024)
consumed by the text encoder stack; the decoder cross-attends to encoder output.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    cross_attention=True,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4_096,
    vocab_size=256_206,
    frontend="audio_stub",
    frontend_seq=1_024,       # precomputed speech frames fed to the encoder
    frontend_dim=1_024,
    rope_theta=10_000.0,
    mlp_act="gelu",           # NLLB/seamless transformer uses ReLU/GELU FFN
)
