"""Configuration dataclasses for SEDAR-JAX.

Every run is described by a `RunConfig`, which composes:
  * `ModelConfig`   -- architecture hyper-parameters (one per assigned arch).
  * `MeshConfig`    -- device mesh shape / axis names.
  * `TrainConfig`   -- optimizer / schedule / batching.
  * `SedarConfig`   -- the paper's fault-tolerance knobs (protection level,
                       checkpoint interval, comparison mode, ...).

Configs are plain frozen dataclasses so they can be hashed into jit static
arguments and serialized into checkpoint manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    The same dataclass describes every family in the assigned pool; family-
    specific fields are zero / empty when unused.
    """

    name: str
    family: str                       # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    mlp_act: str = "swiglu"           # swiglu | gelu

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0

    # --- hybrid (recurrentgemma-style) --------------------------------------
    # Repeating block pattern, e.g. ("recurrent", "recurrent", "attention").
    block_pattern: Tuple[str, ...] = ()
    window_size: int = 0              # sliding-window size for local attention
    d_rnn: int = 0                    # RG-LRU recurrent width (0 -> d_model)
    conv_width: int = 4               # temporal-conv width in recurrent block

    # --- ssm / xlstm ---------------------------------------------------------
    # e.g. ("mlstm", "slstm") repeated; chunk size for the chunkwise form.
    mlstm_chunk: int = 256
    proj_factor: float = 2.0          # xLSTM up-projection factor

    # --- encoder-decoder ------------------------------------------------------
    encoder_layers: int = 0           # >0 -> enc-dec model (decoder = num_layers)
    cross_attention: bool = False

    # --- modality frontend (stub per task spec) ------------------------------
    frontend: Optional[str] = None    # "vision_stub" | "audio_stub" | None
    frontend_seq: int = 0             # length of precomputed embedding sequence
    frontend_dim: int = 0             # width of precomputed embeddings

    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"           # activation / compute dtype
    param_dtype: str = "float32"      # master parameter dtype

    # --- attention implementation --------------------------------------------
    attention_impl: str = "xla"       # "xla" (einsum, GSPMD-native) | "pallas"

    # --- remat ---------------------------------------------------------------
    # "full" (save nothing inside checkpointed bodies) is the production
    # default: with two-level scan remat the only persisted activations are
    # the seq-sharded residual-stream carries; "minimal"
    # (dots_with_no_batch_dims_saveable) pins the FSDP-gathered weights and
    # blows HBM at 100B scale (see EXPERIMENTS.md §Perf iteration log).
    remat: str = "full"               # none | minimal | full

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.family == "hybrid" and self.d_rnn == 0:
            object.__setattr__(self, "d_rnn", self.d_model)

    # -- derived sizes ---------------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameter count N (exact, mirrors the builders in models/)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: only routed experts count)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axis_names

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# ---------------------------------------------------------------------------
# Training / serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    microbatch: int = 0               # 0 -> no gradient accumulation
    steps: int = 100
    optimizer: str = "adamw"          # adamw | sgdm | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    schedule: str = "cosine"          # cosine | linear | constant
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    # Distributed-optimization knobs
    grad_compression: str = "none"    # none | int8_ef  (cross-pod all-reduce)
    donate_state: bool = True


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 128
    context_len: int = 32_768
    prefill_chunk: int = 0            # 0 -> single-shot prefill
    cache_dtype: str = "bfloat16"


# ---------------------------------------------------------------------------
# SEDAR (the paper's knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SedarConfig:
    """Fault-tolerance configuration (paper Secs. 3.1-3.3).

    level:
      0 -- protection off (the paper's *baseline* is modeled separately as two
           independent instances + vote; see runtime/train.py --manual-vote).
      1 -- detection + notification + safe stop          (paper Sec. 3.1)
      2 -- multiple system-level checkpoints + rollback  (paper Sec. 3.2, Alg. 1)
      3 -- single validated application-level checkpoint (paper Sec. 3.3, Alg. 2)
    """

    level: int = 3
    # none | dual | sequential | fused (single-launch time redundancy,
    # DESIGN.md §11) | vote (N>=3, beyond paper) | abft | hybrid (replica-
    # free checksum detection, DESIGN.md §10; hybrid adds FSC fingerprint
    # checks)
    replication: str = "dual"
    replica_axis: str = "pod"         # mesh axis carrying replicas
    compare: str = "fingerprint"      # fingerprint | full   (full = paper's exact buffer compare)
    validate_interval: int = 1        # steps between gradient-fingerprint compares (TDC boundary)
    # deferred validation window D (DESIGN.md §11): commit predicates stay
    # on device and are read back every D compares. 1 = classic sync-per-
    # compare; >=8 makes the fault-free protected step host-sync-free at a
    # detection latency of <= D steps (requires a checkpointing level).
    validate_lag: int = 1
    param_validate_interval: int = 50 # steps between param/opt-state compares (FSC boundary)
    checkpoint_interval: int = 50     # steps between checkpoints (t_i analogue)
    checkpoint_dir: str = "/tmp/sedar_ckpt"
    max_checkpoints: int = 0          # L2 chain depth; 0 = unbounded (paper: none deleted)
    async_checkpoint: bool = True
    # -- tiered checkpoint hierarchy (DESIGN.md §12) -------------------------
    # comma-list of tiers (device | host | disk | partner); "disk" alone is
    # the classic flat store. device = on-device snapshot ring (instant
    # rollback, zero D2H/disk reads); host = host-RAM ring (one batched D2H,
    # no serialization); partner = redundant second directory with
    # independent digests (the Tier-2 corruption fallback).
    ckpt_tiers: str = "disk"
    device_ring_slots: int = 4        # Tier-0 ring capacity (versions)
    host_ring_slots: int = 4          # Tier-1 ring capacity (versions)
    device_ckpt_interval: int = 1     # Tier-0 cadence (steps; ~free)
    host_ckpt_interval: int = 0       # Tier-1 cadence; 0 -> checkpoint_interval
    partner_ckpt_interval: int = 0    # Tier-3 cadence; 0 -> checkpoint_interval
    ckpt_delta: bool = False          # L2 delta checkpoints (manifest leaf refs)
    ckpt_compress: bool = False       # np.savez_compressed leaf payloads
    toe_timeout_s: float = 120.0      # replica-heartbeat timeout (TOE detection)
    app_level_dtype: str = "float32"  # L3 payload dtype for params ("bfloat16" halves t_ca)
    fused_fingerprint: bool = True    # fuse fingerprint into the update step (beyond-paper opt)


# ---------------------------------------------------------------------------
# Top-level run
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)
    sedar: SedarConfig = field(default_factory=SedarConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Assigned input-shape sets (task spec: 4 shapes per LM arch)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k",    "train",   4_096,   256),
    ShapeSpec("prefill_32k", "prefill", 32_768,  32),
    ShapeSpec("decode_32k",  "decode",  32_768,  128),
    ShapeSpec("long_500k",   "decode",  524_288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(model: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Task-spec applicability: ``long_500k`` only for sub-quadratic archs.

    Returns (applicable, reason_if_not).
    """
    if shape.name == "long_500k" and model.family not in ("hybrid", "ssm"):
        return False, (
            "long_500k skipped: pure full-attention architecture (dense 500k KV "
            "cache); per task spec only SSM/hybrid/linear-attention archs run it "
            "(see DESIGN.md)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Scale an architecture down to CPU-smoke size, preserving its family
    structure (GQA ratio, MoE top-k, block pattern, enc-dec split, frontend)."""
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    # preserve GQA grouping: heads must be a multiple of kv heads
    heads = (heads // kv) * kv or kv
    head_dim = 16
    if cfg.family == "ssm":
        d_model = heads * head_dim      # xLSTM: inner dim == d_model
    else:
        d_model = heads * head_dim * 2  # up-projection headroom, divisible by heads
    pattern = cfg.block_pattern
    if pattern:
        layers = 2 * len(pattern)   # two full pattern groups
    elif cfg.family == "ssm":
        layers = 2
    else:
        layers = 2
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=257,              # deliberately non-multiple-of-2 vocab
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        window_size=min(cfg.window_size, 8) if cfg.window_size else 0,
        d_rnn=d_model if cfg.family == "hybrid" else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_seq=min(cfg.frontend_seq, 6) if cfg.frontend_seq else 0,
        frontend_dim=d_model if cfg.frontend_dim else 0,
        mlstm_chunk=8,
        dtype="float32",
        param_dtype="float32",
        remat="none",
    )
