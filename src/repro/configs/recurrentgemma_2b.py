"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (Griffin; hf tier).

26L d_model=2560 10H (GQA kv=1 for the local-attention blocks, head_dim=256)
d_ff=7680 vocab=256000. Block pattern 1 local-attention : 2 RG-LRU recurrent
(26 = 8 x (rec, rec, attn) + (rec, rec) tail). Sliding window 2048.

Sub-quadratic -> runs the long_500k cell (decode state = RG-LRU state +
2048-token ring window cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2_560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7_680,
    vocab_size=256_000,
    block_pattern=("recurrent", "recurrent", "attention"),
    window_size=2_048,
    d_rnn=2_560,
    conv_width=4,
    rope_theta=10_000.0,
)
