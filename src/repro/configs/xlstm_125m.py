"""xlstm-125m [ssm] — arXiv:2405.04517 (unverified tier).

12 blocks, d_model=768, 4 heads (head_dim=192), vocab=50304, d_ff=0 (xLSTM
blocks carry their own up/down projections, proj_factor=2). Alternating
mLSTM / sLSTM blocks (6 groups of 2).

Attention-free -> runs the long_500k cell (decode state is O(1) in sequence
length: per-head matrix memory C, normalizer n, stabilizer m).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm", "slstm"),
    proj_factor=2.0,
    mlstm_chunk=256,
)
