from repro.configs.base import (
    MeshConfig,
    ModelConfig,
    RunConfig,
    SedarConfig,
    ServeConfig,
    ShapeSpec,
    SHAPES,
    SHAPE_BY_NAME,
    TrainConfig,
    reduce_for_smoke,
    shape_applicable,
)
from repro.configs.registry import ASSIGNED_ARCHS, get_config, list_archs

__all__ = [
    "MeshConfig",
    "ModelConfig",
    "RunConfig",
    "SedarConfig",
    "ServeConfig",
    "ShapeSpec",
    "SHAPES",
    "SHAPE_BY_NAME",
    "TrainConfig",
    "reduce_for_smoke",
    "shape_applicable",
    "ASSIGNED_ARCHS",
    "get_config",
    "list_archs",
]
