"""qwen2-0.5b [dense] — arXiv:2407.10671 (hf tier).

24L d_model=896 14H (GQA kv=2, head_dim=64) d_ff=4864 vocab=151936. QKV bias,
tied embeddings. 14 heads are NOT divisible by TP=16: the sharding resolver
falls back (head axis replicated, d_ff/d_model sharded) — recorded per artifact.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4_864,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
