"""Logical-axis sharding rules with divisibility-aware fallback.

Model code annotates every parameter / activation dimension with a *logical*
axis name ("heads", "embed", "mlp", "batch", ...). The resolver maps logical
axes onto physical mesh axes:

  * tensor-parallel candidates  -> the "model" mesh axis
  * FSDP / data candidates      -> the "data" mesh axis (or ("pod","data") in
                                   baseline multi-pod mode)
  * sequence-parallel candidate -> optional (hillclimb knob)

A mesh axis is assigned to at most one dimension per tensor, in declaration
priority order, and only when the dimension size is divisible by the mesh-axis
extent. Any failed candidate falls through to the next dimension that can take
the axis (e.g. qwen2-0.5b: 14 heads % 16 != 0 -> the head axis stays
replicated and "model" lands on head_dim or d_ff instead). Every fallback is
*recorded* so the dry-run artifact shows exactly what sharded where — no
silent replication.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axes that want the tensor-parallel ("model") mesh axis, in priority
# order. Within one tensor, the first divisible dim wins.
MODEL_PARALLEL_AXES: Tuple[str, ...] = (
    "experts",      # MoE expert parallelism
    "heads",
    "kv_heads",
    "mlp",
    "vocab",
    "rnn",          # RG-LRU recurrent width
    "inner",        # xLSTM inner width
    "head_dim",     # fallback when the head axis is not divisible (params)
    "batch_dm",     # activations-only fallback: batch over data*model —
                    # keeps attention fully local when heads % TP != 0
                    # (sharding a contraction dim like head_dim would turn
                    # every QK^T/PV einsum into an all-reduce of the S^2
                    # matrix; batch sharding has no cross-device contraction)
)

# Logical axes that want the data/FSDP mesh axes.
DATA_PARALLEL_AXES: Tuple[str, ...] = (
    "batch",
    "batch_dm",     # if the combined data*model grab failed, plain data
    "embed",        # FSDP: parameters sharded along their embed dim
)

# Sequence axis: shardable over "model" under sequence parallelism (opt-in).
SEQUENCE_AXES: Tuple[str, ...] = ("seq",)


@dataclass(frozen=True)
class ShardingRules:
    """Physical mapping policy for one run."""

    model_axes: Tuple[str, ...] = ("model",)
    data_axes: Tuple[str, ...] = ("data",)      # ("pod","data") in baseline multi-pod
    sequence_parallel: bool = False             # shard activation seq dim over model_axes
    fsdp: bool = True                           # shard params' embed dim over data_axes

    def axis_size(self, mesh: Mesh, axes: Tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n


@dataclass
class FallbackRecord:
    tensor: str
    logical: str
    dim: int
    size: int
    wanted: Tuple[str, ...]
    reason: str


class Resolver:
    """Resolves logical-axis tuples to PartitionSpecs over a given mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[ShardingRules] = None):
        self.mesh = mesh
        self.rules = rules or ShardingRules()
        self.fallbacks: List[FallbackRecord] = []
        # replica ("pod" in dual mode) axes are intentionally absent from all
        # specs -> every tensor is replicated across replicas by construction.

    # -- core ----------------------------------------------------------------

    def spec(self, logical: Sequence[Optional[str]], shape: Sequence[int],
             name: str = "?") -> P:
        """Map one tensor's logical axes to a PartitionSpec."""
        assert len(logical) == len(shape), (name, logical, shape)
        assigned: Dict[int, Tuple[str, ...]] = {}
        used_mesh_axes: set = set()

        def try_assign(dim: int, axes: Tuple[str, ...]) -> bool:
            if any(a in used_mesh_axes for a in axes):
                return False
            n = self.rules.axis_size(self.mesh, axes)
            if n == 1 or shape[dim] % n != 0:
                return False
            assigned[dim] = axes
            used_mesh_axes.update(axes)
            return True

        # Pass 1: tensor parallel — priority order over logical names, then dims.
        for lname in MODEL_PARALLEL_AXES:
            if any(a in used_mesh_axes for a in self.rules.model_axes):
                break
            for dim, l in enumerate(logical):
                if l == lname and dim not in assigned:
                    # batch_dm takes data AND model together (fully-local
                    # fallback); everything else takes the model axes
                    axes = (self.rules.data_axes + self.rules.model_axes
                            if lname == "batch_dm" else self.rules.model_axes)
                    if try_assign(dim, axes):
                        break
                    self.fallbacks.append(FallbackRecord(
                        name, lname, dim, shape[dim], axes,
                        f"{shape[dim]} % {self.rules.axis_size(self.mesh, axes)} != 0",
                    ))

        # Pass 2: sequence parallelism (activations only; opt-in).
        if self.rules.sequence_parallel:
            for dim, l in enumerate(logical):
                if l in SEQUENCE_AXES and dim not in assigned:
                    try_assign(dim, self.rules.model_axes)

        # Pass 3: data / FSDP.
        for lname in DATA_PARALLEL_AXES:
            if lname == "embed" and not self.rules.fsdp:
                continue
            if any(a in used_mesh_axes for a in self.rules.data_axes):
                break
            for dim, l in enumerate(logical):
                if l == lname and dim not in assigned:
                    if try_assign(dim, self.rules.data_axes):
                        break
                    self.fallbacks.append(FallbackRecord(
                        name, lname, dim, shape[dim], self.rules.data_axes,
                        f"{shape[dim]} % {self.rules.axis_size(self.mesh, self.rules.data_axes)} != 0",
                    ))

        entries = []
        for dim in range(len(shape)):
            ax = assigned.get(dim)
            if ax is None:
                entries.append(None)
            elif len(ax) == 1:
                entries.append(ax[0])
            else:
                entries.append(tuple(ax))
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def named(self, logical, shape, name: str = "?") -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape, name))

    # -- pytree helpers --------------------------------------------------------

    def tree_specs(self, logical_tree, shape_tree):
        """Resolve a pytree of logical-axis tuples against matching shapes."""
        paths_logical = jax.tree_util.tree_flatten_with_path(
            logical_tree, is_leaf=lambda x: isinstance(x, tuple) and
            all(isinstance(e, (str, type(None))) for e in x))
        leaves_l, treedef = paths_logical
        leaves_s = jax.tree_util.tree_leaves(
            shape_tree, is_leaf=lambda x: isinstance(x, tuple) and
            all(isinstance(e, int) for e in x))
        assert len(leaves_l) == len(leaves_s), (len(leaves_l), len(leaves_s))
        out = []
        for (path, logical), shape in zip(leaves_l, leaves_s):
            name = jax.tree_util.keystr(path)
            out.append(self.spec(logical, shape, name))
        return jax.tree_util.tree_unflatten(treedef, out)

    def tree_shardings(self, logical_tree, abstract_tree):
        """NamedShardings for a pytree of ShapeDtypeStructs / arrays."""
        shape_tree = jax.tree.map(lambda x: tuple(x.shape), abstract_tree)
        specs = self.tree_specs(logical_tree, shape_tree)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def fallback_report(self) -> List[dict]:
        return [dataclasses.asdict(f) for f in self.fallbacks]


def batch_spec(rules: ShardingRules) -> P:
    """PartitionSpec entry for the global-batch dimension."""
    axes = rules.data_axes
    return axes[0] if len(axes) == 1 else tuple(axes)


def constrain(x, mesh: Mesh, *entries):
    """Convenience with_sharding_constraint that tolerates missing axes."""
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))
    except (ValueError, KeyError):
        return x
