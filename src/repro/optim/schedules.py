"""Learning-rate schedules (pure functions of the step scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(lr, warmup, total, final_frac=0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * (s + 1.0) / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn


def warmup_linear(lr, warmup, total, final_frac=0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * (s + 1.0) / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        lin = lr * (1 - (1 - final_frac) * prog)
        return jnp.where(s < warmup, warm, lin)
    return fn


def constant(lr):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def make_schedule(train_cfg):
    if train_cfg.schedule == "cosine":
        return warmup_cosine(train_cfg.lr, train_cfg.warmup_steps, train_cfg.steps)
    if train_cfg.schedule == "linear":
        return warmup_linear(train_cfg.lr, train_cfg.warmup_steps, train_cfg.steps)
    return constant(train_cfg.lr)
