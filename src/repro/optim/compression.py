"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick, baseline multi-pod mode only; SEDAR dual mode has no cross-pod grad
traffic by construction).

int8 error-feedback: quantize grads to int8 with a per-tensor scale before
the pod-axis reduction; the quantization residual is carried in the optimizer
side-state and added back next step (EF-SGD style), so the scheme is unbiased
in the long run. On a real fabric this cuts the pod-axis collective bytes 4x
(bf16) / 2x (f32->int8 plus f32 scale); the dry-run collective term reflects
it because the all-reduced tensor is materialized in int8.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def int8_error_feedback(grads, ef_state):
    """Returns (compressed-then-decompressed grads, new ef_state).

    ef_state mirrors grads (f32 residuals); pass None to initialize."""
    if ef_state is None:
        ef_state = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def comp(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [comp(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
