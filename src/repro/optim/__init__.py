from repro.optim.optimizers import (
    Optimizer,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    sgdm,
)
from repro.optim.schedules import make_schedule
from repro.optim.compression import int8_error_feedback

__all__ = [
    "Optimizer", "adamw", "sgdm", "make_optimizer", "apply_updates",
    "clip_by_global_norm", "global_norm", "make_schedule",
    "int8_error_feedback",
]
