"""From-scratch optimizers (no optax): AdamW, SGD-momentum.

An Optimizer is a pair of pure functions over pytrees:
    init(params)                 -> opt_state
    update(grads, opt_state, params, step) -> (updates, new_opt_state)
`updates` are the deltas to ADD to params (lr already applied, sign included).

All state mirrors the parameter pytree so SEDAR fingerprinting, sharding and
checkpointing treat it uniformly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], Tuple[Any, Any]]
    name: str = "opt"


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), gn


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def adamw(lr_fn, *, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
          grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        stepf = step.astype(jnp.float32) + 1.0
        lr = lr_fn(step)
        bc1 = 1.0 - beta1 ** stepf
        bc2 = 1.0 - beta2 ** stepf

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = beta1 * m + (1.0 - beta1) * gf
            v2 = beta2 * v + (1.0 - beta2) * gf * gf
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                           + weight_decay * p.astype(jnp.float32))
            return delta, m2, v2

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_state = {"m": treedef.unflatten([o[1] for o in out]),
                     "v": treedef.unflatten([o[2] for o in out])}
        return updates, new_state

    return Optimizer(init, update, "adamw")


def sgdm(lr_fn, *, momentum=0.9, weight_decay=0.0, grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr = lr_fn(step)

        def upd(g, m, p):
            gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m2 = momentum * m + gf
            return -lr * m2, m2

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (treedef.unflatten([o[0] for o in out]),
                {"m": treedef.unflatten([o[1] for o in out])})

    return Optimizer(init, update, "sgdm")


def make_optimizer(train_cfg) -> Optimizer:
    from repro.optim.schedules import make_schedule
    lr_fn = make_schedule(train_cfg)
    if train_cfg.optimizer == "adamw":
        return adamw(lr_fn, beta1=train_cfg.beta1, beta2=train_cfg.beta2,
                     eps=train_cfg.eps, weight_decay=train_cfg.weight_decay,
                     grad_clip=train_cfg.grad_clip)
    if train_cfg.optimizer == "sgdm":
        return sgdm(lr_fn, momentum=train_cfg.beta1,
                    weight_decay=train_cfg.weight_decay,
                    grad_clip=train_cfg.grad_clip)
    raise ValueError(train_cfg.optimizer)
