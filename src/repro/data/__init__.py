from repro.data.pipeline import MemmapCorpus, SyntheticLM, make_pipeline

__all__ = ["SyntheticLM", "MemmapCorpus", "make_pipeline"]
