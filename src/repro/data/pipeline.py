"""Deterministic, restartable data pipeline.

SEDAR determinism contract: the batch for step s is a pure function of
(seed, s) — independent of wall clock, host, or restart count — so (a) both
replicas always see identical inputs, and (b) a rollback to step s replays
exactly the batches the failed execution saw (required for the paper's
"re-execution manifests the same fault" semantics AND for recovery to
converge to the fault-free trajectory).

Pipeline state is therefore just the step counter; checkpointing the iterator
is O(1) regardless of scale. Two sources:

  * SyntheticLM: splitmix64-hashed tokens — zero I/O, used by tests/benches.
  * MemmapCorpus: windows into a binary uint16/uint32 token file via
    np.memmap, window offsets hashed from (seed, step, slot).

Both emit {"tokens": (B, S+?), "targets": ...}; the runtime device_puts with
the batch NamedSharding (each data-parallel rank materializes only its slice
on real multi-host systems; on this container the put is local).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclass
class SyntheticLM:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    frontend_seq: int = 0
    frontend_dim: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.global_batch, self.seq_len
        base = np.uint64(self.seed) * np.uint64(0x1000003) + np.uint64(step)
        idx = np.arange(B * (S + 1), dtype=np.uint64) + base * np.uint64(B * (S + 1))
        toks = (_splitmix64(idx) % np.uint64(self.vocab_size)).astype(np.int32)
        toks = toks.reshape(B, S + 1)
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if self.frontend_seq:
            n = B * self.frontend_seq * self.frontend_dim
            fidx = np.arange(n, dtype=np.uint64) + (base + np.uint64(7)) * np.uint64(n)
            emb = (_splitmix64(fidx).astype(np.float64) / 2**64 - 0.5).astype(np.float32)
            out["frontend_embeds"] = 0.1 * emb.reshape(B, self.frontend_seq,
                                                       self.frontend_dim)
        return out

    # checkpointable state == step (the runtime stores it inside TrainState)
    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": step}


@dataclass
class MemmapCorpus:
    path: str
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n = len(self._data) - (self.seq_len + 1)
        if self._n <= 0:
            raise ValueError("corpus shorter than seq_len")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.global_batch, self.seq_len
        slot = np.arange(B, dtype=np.uint64)
        h = _splitmix64(slot + np.uint64(step) * np.uint64(B)
                        + np.uint64(self.seed) * np.uint64(0x9E3779B1))
        offs = (h % np.uint64(self._n)).astype(np.int64)
        toks = np.stack([np.asarray(self._data[o:o + S + 1], np.int32)
                         for o in offs])
        toks = np.clip(toks, 0, self.vocab_size - 1)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": step, "path": self.path}


def make_pipeline(model_cfg, global_batch: int, seq_len: int, seed: int = 0,
                  corpus: Optional[str] = None):
    fe_seq = model_cfg.frontend_seq if model_cfg.frontend else 0
    fe_dim = model_cfg.frontend_dim if model_cfg.frontend else 0
    if corpus:
        return MemmapCorpus(corpus, model_cfg.vocab_size, global_batch,
                            seq_len, seed)
    return SyntheticLM(model_cfg.vocab_size, global_batch, seq_len, seed,
                       fe_seq, fe_dim)
