"""Pure-jnp ABFT reference: checksum encode / verify / correct.

Algorithm-based fault tolerance for C = A @ B (Huang & Abraham; Bosilca et
al., arXiv:0806.3121): augment A with a column-checksum row and B with a
row-checksum column,

    A_c = [A ; 1^T A]   (m+1, n)        B_r = [B , B 1]   (n, k+1)

then the single product C_f = A_c @ B_r is a FULL-checksum matrix — its last
row/column hold the column/row sums of the data block C = C_f[:m, :k]. Any
corruption of one data element (i, j) during the multiplication violates
exactly the i-th row residual and the j-th column residual by the same
delta, which both LOCATES the element and gives the exact correction — a
forward repair, no rollback and no replica.

Float roundoff makes the residuals nonzero even fault-free, so detection is
thresholded: the checksum path and the data path each accumulate O(n + k)
rounding terms of size eps*|term|, giving the per-row/column bound used by
`residual_threshold`. Corruptions whose delta is below that noise floor are
numerically harmless but ESCAPE ABFT — the hybrid backend's periodic
fingerprint validation (and the replica backends) exist for exactly that
class (DESIGN.md §10).

Everything here is jit-able and is the interpret/CPU parity oracle for
`abft/kernels.py`; the report is a pytree of scalars so executors can branch
on it host-side after one device sync.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

EPS32 = float(np.finfo(np.float32).eps)
DEFAULT_TAU_FACTOR = 16.0


class AbftReport(NamedTuple):
    """Verification outcome of one checksummed kernel invocation.

    detected      -- any residual above the roundoff threshold.
    corrected     -- the violation matched the single-element pattern and the
                     output was repaired in place (includes hits in the
                     checksum row/column itself, where the data block needs
                     no repair).
    uncorrectable -- violations that do not localize to one element
                     (multi-element corruption): the output cannot be
                     trusted; route through on_detection().
    bad_rows/bad_cols -- residual-violation counts (diagnostics).
    max_residual  -- largest |residual| seen (diagnostics).
    """

    detected: jnp.ndarray
    corrected: jnp.ndarray
    uncorrectable: jnp.ndarray
    bad_rows: jnp.ndarray
    bad_cols: jnp.ndarray
    max_residual: jnp.ndarray

    @staticmethod
    def clean() -> "AbftReport":
        f = jnp.asarray(False)
        z = jnp.asarray(0, jnp.int32)
        return AbftReport(f, f, f, z, z, jnp.asarray(0.0, jnp.float32))


def checksum_encode(a: jnp.ndarray, b: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(m,n),(n,k) -> column-checksum A_c (m+1,n) and row-checksum B_r (n,k+1)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    a_c = jnp.concatenate([a, jnp.sum(a, axis=0, keepdims=True)], axis=0)
    b_r = jnp.concatenate([b, jnp.sum(b, axis=1, keepdims=True)], axis=1)
    return a_c, b_r


def residual_threshold(abs_sums: jnp.ndarray, n_terms: int,
                       tau_factor: float = DEFAULT_TAU_FACTOR) -> jnp.ndarray:
    """Roundoff bound for a checksum residual: the data-path and checksum-path
    sums each accumulate ~n_terms rounding errors of size eps*|term|."""
    return jnp.float32(tau_factor * EPS32 * n_terms) * (abs_sums + 1.0)


def verify_and_correct(c_full: jnp.ndarray, inner_dim: int,
                       tau_factor: float = DEFAULT_TAU_FACTOR
                       ) -> Tuple[jnp.ndarray, AbftReport]:
    """Check the full-checksum product and repair a single corrupted element.

    c_full: (m+1, k+1) as produced from checksum-encoded operands.
    inner_dim: the contraction length n (sets the roundoff threshold).
    Returns (C data block (m,k), AbftReport).
    """
    m, k = c_full.shape[0] - 1, c_full.shape[1] - 1
    c = c_full[:m, :k]
    row_ck = c_full[:m, k]                      # checksum column: row sums
    col_ck = c_full[m, :k]                      # checksum row: column sums

    row_res = jnp.sum(c, axis=1) - row_ck       # (m,)
    col_res = jnp.sum(c, axis=0) - col_ck       # (k,)
    n_terms = inner_dim + max(m, k)
    row_tau = residual_threshold(jnp.sum(jnp.abs(c), axis=1), n_terms,
                                 tau_factor)
    col_tau = residual_threshold(jnp.sum(jnp.abs(c), axis=0), n_terms,
                                 tau_factor)

    row_bad = jnp.abs(row_res) > row_tau
    col_bad = jnp.abs(col_res) > col_tau
    n_row = jnp.sum(row_bad).astype(jnp.int32)
    n_col = jnp.sum(col_bad).astype(jnp.int32)
    detected = (n_row + n_col) > 0

    # Single data-element corruption at (i, j) puts the SAME delta in
    # row residual i and column residual j. The thresholds are asymmetric
    # (row_tau scales with k-term sums, col_tau with m-term sums), so the
    # delta may cross only one of them — locate the partner index by the
    # largest residual on the other axis and test DELTA AGREEMENT, never
    # infer from the one-sided violation pattern alone (a delta between the
    # two thresholds would otherwise masquerade as a harmless checksum-entry
    # hit while the data stays corrupted).
    i = jnp.where(n_row >= 1, jnp.argmax(jnp.where(row_bad,
                                                   jnp.abs(row_res), 0.0)),
                  jnp.argmax(jnp.abs(row_res)))
    j = jnp.where(n_col >= 1, jnp.argmax(jnp.where(col_bad,
                                                   jnp.abs(col_res), 0.0)),
                  jnp.argmax(jnp.abs(col_res)))
    deltas_agree = jnp.abs(row_res[i] - col_res[j]) <= (row_tau[i] + col_tau[j])
    single_pattern = detected & (n_row <= 1) & (n_col <= 1)
    data_fix = single_pattern & deltas_agree
    # one-sided violation with NO agreeing partner residual: the corruption
    # sits in a checksum entry itself (row_ck[i] or col_ck[j]) — the data
    # block is intact and the checksums are discarded anyway
    ck_hit = single_pattern & ~deltas_agree & ((n_row == 1) ^ (n_col == 1))

    corrected = detected & (data_fix | ck_hit)
    uncorrectable = detected & ~corrected

    fix_delta = jnp.where(n_row >= 1, row_res[i], col_res[j])
    c = jnp.where(data_fix, c.at[i, j].add(-fix_delta), c)
    report = AbftReport(
        detected=detected, corrected=corrected, uncorrectable=uncorrectable,
        bad_rows=n_row, bad_cols=n_col,
        max_residual=jnp.maximum(jnp.max(jnp.abs(row_res)),
                                 jnp.max(jnp.abs(col_res))).astype(jnp.float32))
    return c, report


def abft_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, *,
                    inject: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
                    tau_factor: float = DEFAULT_TAU_FACTOR
                    ) -> Tuple[jnp.ndarray, AbftReport]:
    """Checksummed matmul oracle: encode -> jnp product -> verify/correct.

    `inject` (e.g. `injection.make_kernel_fault`) corrupts the full-checksum
    product between compute and verify — the in-kernel SDC model."""
    a_c, b_r = checksum_encode(a, b)
    c_full = jnp.dot(a_c, b_r, preferred_element_type=jnp.float32)
    if inject is not None:
        c_full = inject(c_full)
    return verify_and_correct(c_full, a.shape[1], tau_factor)


# ---------------------------------------------------------------------------
# Checksummed attention invariant (the PV-matmul protection)
# ---------------------------------------------------------------------------

def attention_checksum_encode(v: jnp.ndarray) -> jnp.ndarray:
    """Append a checksum channel sum_d v[..., d] to V's head dim.

    Attention output is linear in V (O = softmax(QK^T) V), so the extra
    channel of the output must equal the sum of the data channels — per
    (batch, head, query) row — whatever the attention weights are. This
    protects the PV matmul and the accumulate/normalize path; a corruption
    of the QK^T logits perturbs every channel CONSISTENTLY (checksum lane
    included) and therefore ESCAPES this invariant — see DESIGN.md §10."""
    return jnp.concatenate([v, jnp.sum(v, axis=-1, keepdims=True)], axis=-1)


def attention_verify(out_full: jnp.ndarray, seq_k: int,
                     tau_factor: float = DEFAULT_TAU_FACTOR
                     ) -> Tuple[jnp.ndarray, AbftReport]:
    """Check the output checksum channel; returns (out data, report).

    Detection only: a row residual flags WHICH query row is corrupt but not
    which channel, so there is no in-place correction — a violation is
    uncorrectable and routes through recovery."""
    out = out_full[..., :-1]
    res = jnp.sum(out, axis=-1) - out_full[..., -1]
    hd = out.shape[-1]
    tau = residual_threshold(jnp.sum(jnp.abs(out), axis=-1), hd + seq_k,
                             tau_factor)
    bad = jnp.abs(res) > tau
    n_bad = jnp.sum(bad).astype(jnp.int32)
    detected = n_bad > 0
    report = AbftReport(
        detected=detected, corrected=jnp.asarray(False),
        uncorrectable=detected, bad_rows=n_bad,
        bad_cols=jnp.asarray(0, jnp.int32),
        max_residual=jnp.max(jnp.abs(res)).astype(jnp.float32))
    return out, report


def abft_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                       inject: Optional[Callable] = None,
                       tau_factor: float = DEFAULT_TAU_FACTOR
                       ) -> Tuple[jnp.ndarray, AbftReport]:
    """Checksummed exact attention (oracle for kernels.abft_flash_attention)."""
    from repro.kernels.ref import mha_ref
    v_aug = attention_checksum_encode(jnp.asarray(v, jnp.float32))
    out_full = mha_ref(jnp.asarray(q, jnp.float32),
                       jnp.asarray(k, jnp.float32), v_aug,
                       causal=causal, window=window)
    if inject is not None:
        out_full = inject(out_full)
    return attention_verify(out_full, k.shape[2], tau_factor)
