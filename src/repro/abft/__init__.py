"""ABFT subsystem: checksum-carrying kernels + the replica-free executor.

Third detection axis beside replica count and checkpoint level (DESIGN.md
§10): row/column checksums carried through the computation detect — and for
single corruptions, correct — soft errors at a few percent overhead instead
of duplicated execution.
"""
from repro.abft.executor import AbftExecutor
from repro.abft.kernels import abft_flash_attention, abft_matmul, matmul_pallas
from repro.abft.ref import (AbftReport, abft_attention_ref, abft_matmul_ref,
                            attention_checksum_encode, attention_verify,
                            checksum_encode, residual_threshold,
                            verify_and_correct)

__all__ = [
    "AbftExecutor", "AbftReport", "abft_attention_ref", "abft_flash_attention",
    "abft_matmul", "abft_matmul_ref", "attention_checksum_encode",
    "attention_verify", "checksum_encode", "matmul_pallas",
    "residual_threshold", "verify_and_correct",
]
