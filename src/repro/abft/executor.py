"""Replica-free execution backend: ABFT detection through the SEDAR engine.

`AbftExecutor` runs ONE instance of the workload whose protected kernels
carry checksums (abft/kernels.py) and report per-invocation verification
outcomes. It plugs into `SedarEngine` as backend "abft" / "hybrid"
(core/policy.py::make_engine) and emits the SAME DetectionEvent stream as
the sequential/pod/vote executors, so L0-retry / L1 / L2 / L3 recovery in
`core/engine.py` work unchanged:

  * detected-corrected   -- the checksums localized a single corrupted
    element and the kernel repaired it in place. Surfaced as a commit-
    boundary TDC event whose `repair()` commits the corrected candidate
    FORWARD (rollbacks=0, kind="abft_correct") — the same forward-repair
    protocol the vote executor uses, minus the 2 extra replicas.
  * detected-uncorrectable -- residual violations that do not localize
    (multi-element corruption): the event routes through the recovery
    policy (retry / stop / rollback) exactly like a replica mismatch.
  * escaped -- corruption below the residual noise floor, in an unprotected
    kernel, or in the QK^T path of checksummed attention. Invisible to pure
    "abft"; the "hybrid" mode catches the resident-state subset: every
    commit fingerprints the committed state, and at the FSC cadence the
    NEXT execute first re-fingerprints the state it is about to consume
    and compares — at-rest corruption in the idle window is detected
    before it can propagate (it must be caught at entry: once a step
    executes from a corrupted state, the following commit fingerprint is
    self-consistently corrupt). L3's validated checkpoints keep their
    guarantee through the same `validated_fp` contract.

step_fn contract: `(state, batch, replica_id, armed) -> (candidate, fp,
aux[, report])` — the 3-tuple form of the replica backends still works
(report=None: no ABFT-instrumented kernels in this workload, detection then
comes only from hybrid validation), so the training/serving drivers run
under this backend without modification; ABFT-instrumented steps append an
`abft.ref.AbftReport`.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.detection import DetectionEvent
from repro.core.engine import ReplicaExecutor
from repro.core.fingerprint import fingerprints_equal


def _report_summary(report) -> Dict[str, Any]:
    return {"bad_rows": int(np.asarray(report.bad_rows)),
            "bad_cols": int(np.asarray(report.bad_cols)),
            "max_residual": float(np.asarray(report.max_residual))}


def logits_checksum_guard(logits, spec, step, armed):
    """ABFT output guard over one logits block (DESIGN.md §13): full-
    checksum encode (row + column sums of the CLEAN block), the
    kernel-domain corruption window (`InjectionSpec(target='kernel')`
    faults land between compute and verify), then residual verification
    with single-element forward correction (abft/ref.py). Returns
    (verified logits, AbftReport) — a corrected block flows straight into
    argmax, so the corrected commit emits its token with no re-execution.

    Shared by the decode step (runtime/serve.py) and the packed-prefill
    guard below: the checksummed block is (B, V) either way — decode rows
    are slots, prefill rows are pack prompts."""
    from repro.abft.ref import verify_and_correct
    from repro.core.injection import make_kernel_fault
    lg = jnp.asarray(logits, jnp.float32)
    row = jnp.sum(lg, axis=1, keepdims=True)                 # (B, 1)
    col = jnp.sum(lg, axis=0, keepdims=True)                 # (1, V)
    tot = jnp.sum(row, axis=0, keepdims=True)                # (1, 1)
    c_full = jnp.concatenate(
        [jnp.concatenate([lg, row], axis=1),
         jnp.concatenate([col, tot], axis=1)], axis=0)       # (B+1, V+1)
    if spec is not None and spec.target == "kernel":
        c_full = make_kernel_fault(spec, step=step, armed=armed)(c_full)
    out, report = verify_and_correct(c_full, inner_dim=lg.shape[1])
    return out.astype(logits.dtype), report


def pack_checksum_guard(logits, spec, tick, armed):
    """Per-PROMPT verdict on top of `logits_checksum_guard` for packed
    prefill (runtime/prefill.py): corrected/clean blocks admit every row;
    an uncorrectable fault localizes to the rows whose checksum residuals
    are violated (recomputed here — the report carries only counts), and
    only those rows are marked bad. An uncorrectable fault that violates
    no row residual (e.g. the checksum row itself under a multi-element
    hit) cannot be localized: the whole pack is marked bad (retry).

    The corruption window is `target='prefill_kernel'` — DISTINCT from the
    decode window's 'kernel', so a campaign aimed at one stage never fires
    (and gets consumed/disarmed) in the other.

    Returns (verified logits, verdict (K,) int32, AbftReport) with the
    VERDICT_* encoding from runtime/prefill.py."""
    import dataclasses
    from repro.abft.ref import residual_threshold, verify_and_correct
    from repro.core.injection import make_kernel_fault
    lg = jnp.asarray(logits, jnp.float32)
    K, V = lg.shape
    row = jnp.sum(lg, axis=1, keepdims=True)
    col = jnp.sum(lg, axis=0, keepdims=True)
    tot = jnp.sum(row, axis=0, keepdims=True)
    c_full = jnp.concatenate(
        [jnp.concatenate([lg, row], axis=1),
         jnp.concatenate([col, tot], axis=1)], axis=0)       # (K+1, V+1)
    if spec is not None and spec.target == "prefill_kernel":
        kspec = dataclasses.replace(spec, target="kernel")
        c_full = make_kernel_fault(kspec, step=tick, armed=armed)(c_full)
    out, report = verify_and_correct(c_full, inner_dim=V)
    # per-row violation mask — the same residual math verify_and_correct
    # thresholds internally (its report carries only the COUNTS)
    c = c_full[:K, :V]
    row_res = jnp.sum(c, axis=1) - c_full[:K, V]
    row_tau = residual_threshold(jnp.sum(jnp.abs(c), axis=1), V + max(K, V))
    row_bad = jnp.abs(row_res) > row_tau
    verdict = jnp.where(
        report.uncorrectable,
        jnp.where(jnp.any(row_bad),
                  jnp.where(row_bad, 0, 1),          # localized: bad rows only
                  jnp.zeros((K,), jnp.int32)),       # unlocalizable: whole pack
        jnp.where(report.corrected,
                  jnp.full((K,), 2, jnp.int32),      # VERDICT_CORRECTED
                  jnp.full((K,), 1, jnp.int32)))     # VERDICT_CLEAN
    return out.astype(logits.dtype), verdict.astype(jnp.int32), report


class AbftExecutor(ReplicaExecutor):
    """Single-instance executor with checksum-based detection (+ optional
    hybrid fingerprint validation for the escaped-fault classes)."""

    name = "abft"
    n_replicas = 1

    def __init__(self, step_fn: Callable, state_fp_fn: Callable,
                 fast_state_fp_fn: Optional[Callable] = None,
                 hybrid: bool = False, validate_interval: int = 0):
        self.step_fn = step_fn
        self.state_fp_fn = state_fp_fn
        self.fast_state_fp_fn = fast_state_fp_fn or state_fp_fn
        self.hybrid = hybrid
        self.validate_interval = validate_interval
        if hybrid:
            self.name = "hybrid"
        self.corrections: List[Dict[str, Any]] = []
        self._pending_commit = None    # corrected candidate awaiting repair()
        self._last_fp: Optional[np.ndarray] = None   # fp at last commit
        self._last_fp_step = -1        # step the committed state carries

    @property
    def can_validate(self) -> bool:
        # the engine-driven post-commit validate would compare the committed
        # state against the fingerprint _commit() just took of that SAME
        # state — a guaranteed-equal wasted pass. The periodic at-rest check
        # runs at step ENTRY instead (execute()), so the engine boundary
        # stays off even in hybrid mode...
        return False

    @property
    def can_validate_final(self) -> bool:
        # ...while the END-OF-RUN comparison is meaningful for hybrid: the
        # state is idle after the last commit, and validate() catches
        # corruption landing in that window before results are delivered
        return self.hybrid

    # -- lifecycle -----------------------------------------------------------

    def init_dual(self, single):
        self._last_fp = None           # restored/fresh state: new baseline
        self._last_fp_step = -1
        self._pending_commit = None
        return {"r0": single}

    adopt_single = init_dual

    def note_external_update(self) -> None:
        # the driver mutated the resident state via map_state (slot
        # admission / eviction / rollback merge): the commit-time
        # fingerprint baseline no longer describes what is resident, and
        # comparing against it would flag the legitimate mutation as
        # at-rest corruption
        self._last_fp = None
        self._last_fp_step = -1

    # -- execution -----------------------------------------------------------

    def _entry_check_due(self, step: int) -> bool:
        # `_last_fp_step == step` guards against a stale baseline after an
        # L2 rollback restored an OLDER state than the last commit — the
        # comparison is only meaningful against the fingerprint of the very
        # state this step is about to consume
        return (self.hybrid and self.validate_interval > 0
                and step % self.validate_interval == 0
                and self._last_fp is not None
                and self._last_fp_step == step)

    def execute(self, dual, batch, step: int, armed, compare: bool):
        # Resident-state FSC check at ENTRY: corruption of the idle state
        # between commit and the next step would be absorbed into the
        # trajectory by executing from it (the next commit fingerprint is
        # then self-consistently corrupt), so the comparison against the
        # commit-time fingerprint must happen before step_fn consumes the
        # state. aux is None — this step did not execute.
        if self._entry_check_due(step) and not self._resident_fp_equal(dual):
            return dual, None, DetectionEvent(
                step=step, boundary="validate", effect="FSC",
                detail={"reason": "resident state diverged from its "
                        "commit-time fingerprint"})
        outs = self.step_fn(dual["r0"], batch, jnp.asarray(0), armed)
        if len(outs) == 4:
            cand, _fp, aux, report = outs
        else:
            cand, _fp, aux = outs
            report = None

        if report is not None and bool(np.asarray(report.detected)):
            # ABFT verification runs on EVERY kernel invocation — unlike the
            # replica compare it is not gated by the commit cadence
            if bool(np.asarray(report.uncorrectable)):
                return dual, aux, DetectionEvent(
                    step=step, boundary="commit", effect="TDC",
                    detail={"abft": _report_summary(report)})
            # single-element corruption, repaired in place: commit the
            # corrected candidate forward via repair() — no rollback
            self._pending_commit = {"r0": cand}
            return dual, aux, DetectionEvent(
                step=step, boundary="commit", effect="TDC",
                detail={"abft": _report_summary(report),
                        "abft_corrected": True})
        return self._commit({"r0": cand}, step + 1), aux, None

    def _commit(self, dual, next_step: int):
        if self.hybrid:
            self._last_fp = np.asarray(self.fast_state_fp_fn(dual["r0"]))
            self._last_fp_step = next_step
        return dual

    def repair(self, event: DetectionEvent, dual
               ) -> Optional[Tuple[Any, Dict[str, Any]]]:
        if event.detail.get("abft_corrected") and \
                self._pending_commit is not None:
            committed = self._commit(self._pending_commit, event.step + 1)
            self._pending_commit = None
            record = {"kind": "abft_correct", "step": None, "rollbacks": 0}
            self.corrections.append(dict(record, at=event.step))
            return committed, record
        return None

    # -- FSC boundary (hybrid) -----------------------------------------------

    def _resident_fp_equal(self, dual) -> bool:
        if self._last_fp is None:
            return True
        cur = self.fast_state_fp_fn(dual["r0"])
        return bool(np.asarray(fingerprints_equal(
            jnp.asarray(self._last_fp), cur)))

    def validate(self, dual, step: int) -> Optional[DetectionEvent]:
        if not self.hybrid or self._resident_fp_equal(dual):
            return None
        return DetectionEvent(step=step, boundary="validate", effect="FSC",
                              detail={"reason": "resident state diverged "
                                      "from its commit-time fingerprint"})

    def validated_fp(self, dual) -> Tuple[np.ndarray, bool]:
        equal = self._resident_fp_equal(dual) if self.hybrid else True
        return np.asarray(self.state_fp_fn(dual["r0"])), equal

    def state_fp(self, dual):
        return self.state_fp_fn(dual["r0"])
