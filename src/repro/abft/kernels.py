"""Pallas TPU kernels for the ABFT subsystem.

Two checksum-carrying lowerings (jnp oracles in `abft/ref.py`):

  * `abft_matmul` — C = A @ B through the full-checksum product: encode the
    operands (O(mn + nk) jnp pass), run ONE tiled Pallas matmul on the
    augmented (m+1, n) x (n, k+1) operands, then verify the row/column
    residuals and repair a single corrupted element in place. The checksum
    row/column ride the same MXU tiles as the data (m+1/k+1 round up to the
    same tile multiples), so the detection cost is the O(mk) verification
    pass — a few percent of the O(mnk) multiply — instead of SEDAR's 2x
    duplicated execution.

  * `abft_flash_attention` — the existing `kernels/flash_attention.py`
    online-softmax kernel re-lowered with a checksum lane on V: the SAME
    kernel body runs with v/out BlockSpecs widened to hd+1, and the output's
    extra lane must equal the sum of its data lanes (attention is linear in
    V). This protects the PV matmul + accumulate/normalize path; QK^T-path
    corruption moves all lanes consistently and escapes to the fingerprint
    boundary (DESIGN.md §10).

Matmul grid is (nm, nk_tiles, nsteps) with the contraction innermost — TPU
grids run sequentially per core, so the f32 accumulator tile lives in VMEM
scratch across the contraction steps (same carry idiom as the flash kernel).
Blocks default to 128 (MXU-aligned) and are clamped/padded for small shapes.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.abft.ref import (DEFAULT_TAU_FACTOR, AbftReport,
                            attention_checksum_encode, attention_verify,
                            checksum_encode, verify_and_correct)
from repro.kernels.fingerprint import default_interpret
from repro.kernels.flash_attention import _flash_kernel, _vmem


def _matmul_kernel(nsteps, a_ref, b_ref, o_ref, acc_ref):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.float32),
                            b_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(ik == nsteps - 1)
    def _final():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                  block_m: int = 128, block_n: int = 128, block_k: int = 128,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Tiled (m,n)x(n,k) matmul, f32 accumulation. Shapes are zero-padded to
    block multiples (zero rows/cols contribute nothing to the product)."""
    if interpret is None:
        interpret = default_interpret()
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    m, n = a.shape
    n2, k = b.shape
    assert n == n2, (a.shape, b.shape)

    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    if pn or pk:
        b = jnp.pad(b, ((0, pn), (0, pk)))
    nm, nk_t, nsteps = a.shape[0] // bm, b.shape[1] // bk, a.shape[1] // bn

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps),
        grid=(nm, nk_t, nsteps),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, s: (i, s)),
            pl.BlockSpec((bn, bk), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), jnp.float32),
        scratch_shapes=[_vmem((bm, bk), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :k]


def abft_matmul(a: jnp.ndarray, b: jnp.ndarray, *,
                inject: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
                tau_factor: float = DEFAULT_TAU_FACTOR,
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, AbftReport]:
    """Checksummed matmul: encode -> Pallas compute -> verify/correct.

    `inject` (see `injection.make_kernel_fault`) corrupts the full-checksum
    product between compute and verify — modeling an SDC in the MXU
    accumulate/output path, i.e. INSIDE the protected computation, where the
    replica-free checksums are the only detector."""
    a_c, b_r = checksum_encode(a, b)
    c_full = matmul_pallas(a_c, b_r, block_m=block_m, block_n=block_n,
                           block_k=block_k, interpret=interpret)
    if inject is not None:
        c_full = inject(c_full)
    return verify_and_correct(c_full, a.shape[1], tau_factor)


# ---------------------------------------------------------------------------
# Checksummed flash attention
# ---------------------------------------------------------------------------

def abft_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         inject: Optional[Callable] = None,
                         tau_factor: float = DEFAULT_TAU_FACTOR,
                         interpret: Optional[bool] = None
                         ) -> Tuple[jnp.ndarray, AbftReport]:
    """q: (B,H,Sq,hd); k/v: (B,KV,Sk,hd). Returns ((B,H,Sq,hd) f32, report).

    The UNMODIFIED `_flash_kernel` body runs with V (and the output/
    accumulator tiles) widened by the checksum lane — the online-softmax
    carry is linear in V, so the invariant survives the m/l rescaling. The
    widened hd+1 breaks the 128-lane alignment of the v tiles on real TPUs
    (documented cost: pad-to-128 or accept the relayout); correctness is
    exercised in interpret mode and on TPU via the same BlockSpecs."""
    if interpret is None:
        interpret = default_interpret()
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v_aug = attention_checksum_encode(jnp.asarray(v, jnp.float32))
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV
    hv = hd + 1

    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v_aug = jnp.pad(v_aug, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nQ, nK = q.shape[2] // bq, k.shape[2] // bk

    kern = functools.partial(_flash_kernel, 1.0 / math.sqrt(hd), causal,
                             window, bq, bk, Sk)
    out_full = pl.pallas_call(
        kern,
        grid=(B, H, nQ, nK),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hv),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, q.shape[2], hv), jnp.float32),
        scratch_shapes=[
            _vmem((bq, hv), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v_aug)
    out_full = out_full[:, :, :Sq, :]
    if inject is not None:
        out_full = inject(out_full)
    return attention_verify(out_full, Sk, tau_factor)
