"""Bucketed packed protected prefill + AOT compile cache (DESIGN.md §14).

The two oracles: (1) bucketed/packed admission must be BITWISE equivalent
to the exact-shape per-request path — right-padding and packing are layout
transforms, not math changes; (2) the per-prompt detection contract — a
fault in one pack row's prefill retries/rejects ONLY that request, and the
survivors' streams equal the fault-free run. Plus the compile-accounting
property: after `warmup()` the traffic loop never compiles.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, TrainConfig, get_config, \
    reduce_for_smoke
from repro.core import hostsync
from repro.core.injection import InjectionSpec
from repro.runtime.prefill import (BucketedPrefill, bucket_for, count_compiles,
                                   group_packs, make_buckets, pack_for,
                                   pack_sizes)
from repro.runtime.scheduler import Request, ttft_percentiles_ms
from repro.runtime.serve import SedarServer

SLOTS = 3


def _cfg():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    return RunConfig(model=cfg, train=TrainConfig(global_batch=2, seq_len=8))


def _reqs():
    """Three t=0 arrivals spanning two buckets: lens 4, 6 -> bucket 8
    (one pack of 2), len 9 -> bucket 16 (pack of 1)."""
    return [Request(rid=i, prompt=np.arange(1, ln + 1, dtype=np.int32),
                    max_new_tokens=4, arrival=0)
            for i, ln in enumerate((4, 6, 9))]


def _row1_spec(**kw):
    """Transient SDC in pack row 1's prefill logits on replica 1 — hits the
    bucket-8 pack's second prompt (rid 1) at the t=0 admission."""
    kw.setdefault("target", "prefill")
    return InjectionSpec(leaf_idx=1, flat_idx=7, bit=30, step=0, replica=1,
                         **kw)


@pytest.fixture(scope="module")
def setup():
    rc = _cfg()
    srv = SedarServer(rc, dual=True)
    params = srv.model.init(jax.random.PRNGKey(0))
    clean, rep = srv.serve(params, _reqs(), slots=SLOTS)
    assert not rep.detections and rep.prefill_packs == 2
    return rc, params, {r.rid: list(r.tokens) for r in clean}


def _assert_streams_equal(out, clean_toks):
    for rid, r in out.items():
        assert list(r.tokens) == clean_toks[rid], f"request {rid} diverged"


# ---------------------------------------------------------------------------
# bucket / pack geometry
# ---------------------------------------------------------------------------

def test_bucket_ladder_geometry():
    assert make_buckets(100) == (8, 16, 32, 64, 128)
    assert make_buckets(8) == (8,)
    assert bucket_for(8, (8, 16)) == 8
    assert bucket_for(9, (8, 16)) == 16
    assert bucket_for(17, (8, 16)) is None       # overflow -> legacy path
    assert pack_sizes(4) == (1, 2, 4)
    assert pack_for(3, 4) == 4
    assert pack_for(1, 4) == 1
    with pytest.raises(ValueError):
        pack_for(5, 4)


def test_group_packs_by_bucket_and_chunk():
    items = list("abcdef")
    lengths = [4, 6, 9, 8, 5, 40]
    packs, overflow = group_packs(items, lengths, (8, 16), max_pack=2)
    assert overflow == ["f"]                     # 40 > largest bucket
    assert packs == [(8, ["a", "b"]), (8, ["d", "e"]), (16, ["c"])]


# ---------------------------------------------------------------------------
# bitwise equivalence of the padded / packed transforms
# ---------------------------------------------------------------------------

def test_padded_prefill_bitwise_equals_exact(setup):
    rc, params, _ = setup
    srv = SedarServer(rc, dual=True)
    toks = jnp.asarray(np.arange(1, 6, dtype=np.int32))[None, :]   # S=5
    max_len = 24
    exact_logits, _ = srv.model.prefill(params, {"tokens": toks}, max_len)
    padded = srv.prefiller.prefill_padded(params, toks, max_len)
    assert padded is not None
    np.testing.assert_array_equal(np.asarray(padded[0]),
                                  np.asarray(exact_logits))


def test_packed_serve_equals_legacy_admission(setup):
    """The whole point: packed bucketed admission produces bitwise the same
    streams as one-exact-launch-per-request admission."""
    rc, params, clean_toks = setup
    srv = SedarServer(rc, dual=True)
    out, rep = srv.serve(params, _reqs(), slots=SLOTS, packed_prefill=False)
    assert rep.prefill_packs == 0
    _assert_streams_equal({r.rid: r for r in out}, clean_toks)


def test_generate_reuses_bucketed_prefill(setup):
    """generate() rides the same bucket ladder: same-bucket prompt lengths
    share ONE compiled program, and the streams equal the legacy
    exact-shape prefill (forced via a ladder every prompt overflows)."""
    rc, params, _ = setup
    srv = SedarServer(rc, dual=True)
    srv_legacy = SedarServer(rc, dual=True, prefill_buckets=(1,))
    max_len = 32
    for S in (5, 7):                             # both -> bucket 8
        prompt = {"tokens": jnp.asarray(
            np.arange(1, S + 1, dtype=np.int32))[None, :]}
        toks, _ = srv.generate(params, prompt, steps=4, max_len=max_len)
        ref, _ = srv_legacy.generate(params, prompt, steps=4,
                                     max_len=max_len)
        np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))
    with count_compiles() as st:
        prompt = {"tokens": jnp.asarray(
            np.arange(2, 8, dtype=np.int32))[None, :]}       # S=6, bucket 8
        srv.generate(params, prompt, steps=4, max_len=max_len)
    assert st.compiles == 0, st.by_key


# ---------------------------------------------------------------------------
# AOT compile cache
# ---------------------------------------------------------------------------

def test_one_compile_per_bucket_pack_shape(setup):
    """Regression: repeated same-shape launches hit the cache; the compile
    count is exactly one per (kind, bucket, K) key."""
    rc, params, _ = setup
    pf = BucketedPrefill(SedarServer(rc, dual=True).model,
                         backend="sequential", max_pack=4)
    max_len = 24
    prompts2 = [np.arange(1, 5, dtype=np.int32)] * 2
    with count_compiles() as st:
        for _ in range(3):
            pf.protected_pack(params, prompts2, max_len, 0)      # K=2
        pf.protected_pack(params, prompts2 * 2, max_len, 1)      # K=4
        pf.prefill_padded(params, jnp.asarray(prompts2[0])[None, :],
                          max_len)
    assert st.compiles == 3, st.by_key
    assert all(v == 1 for v in st.by_key.values()), st.by_key


def test_warmup_kills_traffic_time_compiles(setup):
    """The acceptance property: after warmup() the ENTIRE serve loop —
    packed admission at every bucket/pack shape plus decode — performs
    zero prefill-program compiles."""
    rc, params, clean_toks = setup
    srv = SedarServer(rc, dual=True)
    reqs = _reqs()
    max_len = (max(r.prompt_len for r in reqs)
               + max(r.max_new_tokens for r in reqs) + 8)
    n = srv.warmup_prefill(params, max_len)
    assert n == len(srv.prefiller.usable_buckets(max_len)) * (1 + 3)
    with count_compiles() as st:
        out, rep = srv.serve(params, reqs, slots=SLOTS, max_len=max_len)
    assert rep.prefill_packs == 2
    assert st.compiles == 0, st.by_key
    _assert_streams_equal({r.rid: r for r in out}, clean_toks)


def test_admission_readback_is_one_batch_per_pack(setup):
    """Host-sync accounting: the pack's tokens AND verdicts come back in
    ONE batched transfer (2 items) per launch — not per request."""
    rc, params, _ = setup
    srv = SedarServer(rc, dual=True)
    srv.serve(params, _reqs(), slots=SLOTS)      # warm jits
    with hostsync.count_transfers() as st:
        out, rep = srv.serve(params, _reqs(), slots=SLOTS, validate_lag=8)
    assert rep.prefill_packs == 2
    assert st.by_label["prefill_emit"] == 2 * rep.prefill_packs
    tt50, tt99 = ttft_percentiles_ms(out)
    assert 0 < tt50 <= tt99                      # TTFT stamps functional


# ---------------------------------------------------------------------------
# per-prompt detection contract
# ---------------------------------------------------------------------------

def test_transient_pack_fault_retries_only_that_row(setup):
    """A transient SDC in pack row 1 is caught by the lane compare; only
    rid 1 is retried (the rest of the pack admits first pass) and every
    stream equals the fault-free run."""
    rc, params, clean_toks = setup
    srv = SedarServer(rc, dual=True, inj_spec=_row1_spec())
    out, rep = srv.serve(params, _reqs(), slots=SLOTS)
    out = {r.rid: r for r in out}
    assert all(r.status == "done" for r in out.values())
    assert rep.prefill_retries == 1
    tdc = [e for e in rep.detections if e.boundary == "prefill"]
    assert len(tdc) == 1 and tdc[0].detail["rids"] == [1]
    _assert_streams_equal(out, clean_toks)


def test_fused_backend_pack_fault_equality(setup):
    """Same contract on the fused backend (lanes from the same compiled
    executable run twice): row-localized retry, clean-run streams."""
    rc, params, clean_toks = setup
    srv = SedarServer(rc, backend="fused", inj_spec=_row1_spec())
    out, rep = srv.serve(params, _reqs(), slots=SLOTS)
    out = {r.rid: r for r in out}
    assert all(r.status == "done" for r in out.values())
    assert rep.prefill_retries == 1
    _assert_streams_equal(out, clean_toks)


def test_persistent_pack_fault_rejects_only_that_request(setup):
    """A stuck lane (persistent=True): retries RELAUNCH the original pack
    shape so the fault keeps hitting the same occupant, the budget
    exhausts, and ONLY rid 1 is rejected — the pack's other rows and the
    other pack complete with clean streams."""
    rc, params, clean_toks = setup
    notified = []
    srv = SedarServer(rc, dual=True, max_retries=3,
                      inj_spec=_row1_spec(persistent=True))
    out, rep = srv.serve(params, _reqs(), slots=SLOTS,
                         notify_reject=lambda r, e: notified.append(r.rid))
    out = {r.rid: r for r in out}
    assert rep.rejected == [1] == notified
    assert out[1].status == "rejected"
    assert "prefill validation" in out[1].reject_reason
    for rid in (0, 2):
        assert out[rid].status == "done"
        assert list(out[rid].tokens) == clean_toks[rid]


def test_abft_pack_forward_corrects_and_admits(setup):
    """Replica-free backend: a single-element fault in the packed-prefill
    checksum window is forward-corrected — every row admits FIRST pass
    (verdict CORRECTED, zero retries), the detection is recorded, and the
    streams equal the dual-replica clean run."""
    rc, params, clean_toks = setup
    spec = InjectionSpec(leaf_idx=0, flat_idx=5, bit=30, step=0, replica=0,
                         target="prefill_kernel")
    srv = SedarServer(rc, backend="abft", inj_spec=spec)
    out, rep = srv.serve(params, _reqs(), slots=SLOTS)
    out = {r.rid: r for r in out}
    assert all(r.status == "done" for r in out.values())
    assert rep.prefill_retries == 0
    corrected = [e for e in rep.detections if e.effect == "abft_corrected"]
    assert len(corrected) == 1 and corrected[0].boundary == "prefill"
    _assert_streams_equal(out, clean_toks)


def test_abft_pack_uncorrectable_localizes_rows(setup):
    """Multi-element corruption defeats single-element correction: the
    violated row residuals localize the bad rows, only those retry, and
    the re-execution (fault disarmed) converges to the clean run."""
    rc, params, clean_toks = setup
    spec = InjectionSpec(leaf_idx=0, flat_idx=5, bit=30, step=0, replica=0,
                         target="prefill_kernel", n_elems=2)
    srv = SedarServer(rc, backend="abft", inj_spec=spec)
    out, rep = srv.serve(params, _reqs(), slots=SLOTS)
    out = {r.rid: r for r in out}
    assert all(r.status == "done" for r in out.values())
    assert rep.prefill_retries >= 1
    tdc = [e for e in rep.detections if e.boundary == "prefill"
           and e.effect == "TDC"]
    assert tdc and len(tdc[0].detail["rids"]) < len(_reqs())   # localized
    _assert_streams_equal(out, clean_toks)


def test_hybrid_backend_clean_packed_serve(setup):
    """The checksum-guarded pack path also serves the hybrid backend, and
    its clean streams equal the dual-replica run."""
    rc, params, clean_toks = setup
    srv = SedarServer(rc, backend="hybrid")
    out, rep = srv.serve(params, _reqs(), slots=SLOTS)
    assert not rep.detections and rep.prefill_packs == 2
    _assert_streams_equal({r.rid: r for r in out}, clean_toks)
