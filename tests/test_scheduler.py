"""Continuous-batching scheduler: queue backpressure, slot lifecycle,
open-loop traffic generation (DESIGN.md §13)."""
import numpy as np

from repro.runtime.scheduler import (DONE, DRAINING, QUEUED, REJECTED,
                                     RUNNING, Request, RequestQueue,
                                     SlotScheduler, synthetic_requests,
                                     token_latencies)


def _req(rid, arrival=0, L=4, new=4):
    return Request(rid=rid, prompt=np.zeros((L,), np.int32),
                   max_new_tokens=new, arrival=arrival)


def test_queue_backpressure_rejects_immediately():
    q = RequestQueue(max_depth=2)
    assert q.offer(_req(0)) and q.offer(_req(1))
    shed = _req(2)
    assert not q.offer(shed)
    assert shed.status == REJECTED and shed.reject_reason == "backpressure"
    assert len(q) == 2 and q.rejected == [shed]


def test_queue_unbounded_by_default():
    q = RequestQueue()
    for i in range(64):
        assert q.offer(_req(i))
    assert len(q) == 64


def test_admit_pairs_free_slots_fifo():
    sched = SlotScheduler(2)
    for i in range(3):
        sched.queue.offer(_req(i))
    pairs = sched.admit(step=5)
    assert [(s, r.rid) for s, r in pairs] == [(0, 0), (1, 1)]
    assert all(r.status == RUNNING and r.admit_step == 5 for _, r in pairs)
    assert len(sched.queue) == 1 and not sched.free_slots()
    # freeing a slot lets the queued request join mid-flight
    sched.release(0)
    pairs = sched.admit(step=9)
    assert [(s, r.rid) for s, r in pairs] == [(0, 2)]


def test_slot_lifecycle_drain_reactivate_release():
    sched = SlotScheduler(1)
    sched.queue.offer(_req(7))
    [(slot, req)] = sched.admit(step=0)
    sched.drain(slot, finish_step=12)
    assert req.status == DRAINING and req.finish_step == 12
    sched.reactivate(slot)        # rollback hit the final window
    assert req.status == RUNNING and req.finish_step is None
    sched.drain(slot, finish_step=15)
    out = sched.release(slot)
    assert out is req and req.status == DONE and sched.free_slots() == [0]


def test_reject_frees_slot_with_reason():
    sched = SlotScheduler(1)
    sched.queue.offer(_req(3))
    [(slot, req)] = sched.admit(step=0)
    sched.reject(slot, "per-request safe stop")
    assert req.status == REJECTED and "safe stop" in req.reject_reason
    assert sched.free_slots() == [0] and not sched.busy


def test_synthetic_requests_deterministic_and_open_loop():
    a = synthetic_requests(8, arrival_rate=0.5, seed=11)
    b = synthetic_requests(8, arrival_rate=0.5, seed=11)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    # arrivals are non-decreasing and a faster rate compresses them
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    fast = synthetic_requests(8, arrival_rate=50.0, seed=11)
    assert fast[-1].arrival <= a[-1].arrival


def test_token_latencies_inter_token_gaps():
    r = _req(0)
    r.token_times = [1.0, 1.5, 2.5]
    assert token_latencies([r]) == [0.5, 1.0]
