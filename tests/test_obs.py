"""Unified observability layer (DESIGN.md §15): metrics registry, shared
percentile helper, fault journal, trace spans, KPIs, cluster gauges.

Also documents (as an executable spec) the `hostsync.TransferStats`
thread-local shim behavior: a scoped `count_transfers()` region counts only
the opening thread's readbacks, while the process-wide registry aggregates
across threads under its lock — the explicit cross-thread mode the shim
deliberately lacks."""
import json
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.checkpoint import store as ckpt_store
from repro.core import hostsync
from repro.obs.journal import FaultJournal, _jsonable, canonical, \
    event_to_record
from repro.obs.kpi import compute_kpis, reconcile_with_advice
from repro.obs.registry import MetricsRegistry, percentile
from repro.obs.trace import TraceRecorder
from repro.runtime import prefill


@pytest.fixture(autouse=True)
def _obs_teardown():
    yield
    obs.shutdown()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("a_total")
    m.inc("a_total", 3)
    m.inc("a_total", 2, label="x")
    m.set_gauge("g", 7.5)
    m.set_gauge("g", 2.5)
    for v in (1.0, 2.0, 3.0, 4.0):
        m.observe("h_ms", v)
    assert m.get("a_total") == 4
    assert m.get("a_total", label="x") == 2
    assert m.get("g") == 2.5
    h = m.get_histogram("h_ms")
    assert h.count == 4 and h.total == 10.0
    assert h.quantile(50) == 2.0 and h.quantile(99) == 4.0
    assert m.get("never_touched") == 0.0


def test_registry_kind_conflict_rejected():
    m = MetricsRegistry()
    m.inc("x")
    with pytest.raises(ValueError):
        m.set_gauge("x", 1.0)


def test_registry_prometheus_render():
    m = MetricsRegistry()
    m.inc("req_total", 5, route="a")
    m.inc("req_total", 1, route="b")
    m.set_gauge("depth", 3)
    m.observe("lat_ms", 10.0)
    text = m.render_prometheus()
    assert "# TYPE req_total counter" in text
    assert 'req_total{route="a"} 5' in text
    assert 'req_total{route="b"} 1' in text
    assert "depth 3" in text
    assert "lat_ms_count 1" in text and "lat_ms_sum 10" in text
    # real Prometheus histogram exposition: cumulative le-labeled buckets
    assert "# TYPE lat_ms histogram" in text
    assert 'lat_ms_bucket{le="5"} 0' in text
    assert 'lat_ms_bucket{le="10"} 1' in text
    assert 'lat_ms_bucket{le="1000"} 1' in text
    assert 'lat_ms_bucket{le="+Inf"} 1' in text


def test_prometheus_histogram_roundtrip():
    """render_prometheus -> parse_prometheus is lossless for counters,
    gauges, and histogram bucket/sum/count samples (labels included)."""
    from repro.obs.registry import parse_prometheus
    m = MetricsRegistry()
    m.set_buckets("lat_s", (0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        m.observe("lat_s", v, stage="x")
    m.inc("req_total", 2, route="a")
    m.set_gauge("depth", 4)
    types, samples = parse_prometheus(m.render_prometheus())
    assert types == {"lat_s": "histogram", "req_total": "counter",
                     "depth": "gauge"}
    bucket = samples["lat_s_bucket"]
    assert bucket[(("le", "0.1"), ("stage", "x"))] == 1
    assert bucket[(("le", "1"), ("stage", "x"))] == 2
    assert bucket[(("le", "10"), ("stage", "x"))] == 3
    assert bucket[(("le", "+Inf"), ("stage", "x"))] == 4
    assert samples["lat_s_sum"][(("stage", "x"),)] == \
        pytest.approx(55.55)
    assert samples["lat_s_count"][(("stage", "x"),)] == 4
    assert samples["req_total"][(("route", "a"),)] == 2
    assert samples["depth"][()] == 4


def test_registry_cross_thread_aggregation():
    """The registry's explicit cross-thread mode: increments from worker
    threads land in the same series (lock-protected)."""
    m = MetricsRegistry()

    def work():
        for _ in range(500):
            m.inc("t_total")

    ts = [threading.Thread(target=work) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert m.get("t_total") == 2000


# ---------------------------------------------------------------------------
# percentile (satellite: one shared nearest-rank implementation)
# ---------------------------------------------------------------------------

def test_percentile_property_vs_numpy():
    """Nearest-rank must agree with numpy's inverted_cdf method over random
    sizes/quantiles (seeded property sweep)."""
    rs = np.random.RandomState(7)
    for _ in range(200):
        n = int(rs.randint(1, 60))
        vals = rs.rand(n) * rs.choice([1.0, 1e3, 1e-3])
        q = float(rs.choice([0.0, 1.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0]))
        got = percentile(vals, q)
        want = float(np.percentile(vals, q, method="inverted_cdf"))
        assert got == want, (n, q, got, want)


def test_percentile_edges():
    assert percentile([], 50) == 0.0
    assert percentile([42.0], 99) == 42.0
    assert percentile([1, 2, 3, 4], 50) == 2.0     # true nearest-rank median
    assert percentile([1, 2, 3, 4], 99) == 4.0     # p99 clamps to max
    assert percentile([3, 1, 2], 0) == 1.0


def test_scheduler_percentiles_use_shared_helper():
    from repro.runtime.scheduler import Request, latency_percentiles_ms, \
        ttft_percentiles_ms
    reqs = []
    for rid in range(4):
        r = Request(rid=rid, prompt=np.zeros(4, np.int32), max_new_tokens=3)
        r.arrival_time = 0.0
        r.token_times = [0.010 * (rid + 1), 0.010 * (rid + 1) + 0.005]
        reqs.append(r)
    tt50, tt99 = ttft_percentiles_ms(reqs)
    lats = [r.token_times[0] for r in reqs]
    assert tt50 == pytest.approx(1e3 * percentile(lats, 50))
    assert tt99 == pytest.approx(1e3 * percentile(lats, 99))
    p50, p99 = latency_percentiles_ms(reqs)
    assert p50 == pytest.approx(5.0) and p99 == pytest.approx(5.0)
    assert ttft_percentiles_ms([]) == (0.0, 0.0)


def test_scheduler_ttlt_and_stream_stats():
    from repro.runtime.scheduler import Request, stream_stats_ms, \
        ttlt_latencies, ttlt_percentiles_ms
    reqs = []
    for rid in range(4):
        r = Request(rid=rid, prompt=np.zeros(4, np.int32), max_new_tokens=3)
        r.arrival_time = 0.0
        r.token_times = [0.010 * (rid + 1), 0.010 * (rid + 1) + 0.005]
        reqs.append(r)
    # TTLT = last token stamp - arrival, one sample per emitting request
    assert ttlt_latencies(reqs) == pytest.approx(
        [0.015, 0.025, 0.035, 0.045])
    tl50, tl99 = ttlt_percentiles_ms(reqs)
    lats = [r.token_times[-1] for r in reqs]
    assert tl50 == pytest.approx(1e3 * percentile(lats, 50))
    assert tl99 == pytest.approx(1e3 * percentile(lats, 99))
    assert ttlt_percentiles_ms([]) == (0.0, 0.0)
    # never-emitted requests are excluded, not zero samples
    ghost = Request(rid=9, prompt=np.zeros(4, np.int32), max_new_tokens=3)
    ghost.arrival_time = 0.0
    assert len(ttlt_latencies(reqs + [ghost])) == 4
    stats = stream_stats_ms(reqs)
    assert set(stats) == {"ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms",
                          "itl_p99_ms", "ttlt_p50_ms", "ttlt_p99_ms"}
    assert stats["ttlt_p50_ms"] == tl50
    assert stats["itl_p50_ms"] == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# legacy shim absorption
# ---------------------------------------------------------------------------

def test_metrics_absorb_hostsync_transfers():
    obs.enable_metrics()
    hostsync.read_scalar(jnp.asarray(1.0), label="probe")
    hostsync.batched_get([jnp.zeros(2), jnp.zeros(3)], label="pair")
    assert obs.metrics.get("hostsync_transfers_total", label="probe") == 1
    assert obs.metrics.get("hostsync_transfers_total", label="pair") == 2
    assert obs.metrics.get("hostsync_batches_total", label="pair") == 1


def test_metrics_off_is_noop():
    assert not obs.metrics_enabled()
    hostsync.read_scalar(jnp.asarray(1.0), label="probe")
    assert obs.metrics.snapshot() == {}
    # note_* intake is also inert with everything off
    obs.note_checkpoint(3)
    obs.note_tokens(5)
    assert obs.metrics.snapshot() == {}
    assert obs.get_journal() is None


def test_metrics_absorb_compiles_and_disk_reads():
    obs.enable_metrics()
    prefill._note_compile(("pack", 16, 2))
    prefill._note_compile(("pack", 32, 4))
    ckpt_store._note_disk_read("leaf", 3)
    ckpt_store._note_disk_read("manifest")
    assert obs.metrics.get("prefill_compiles_total", kind="pack") == 2
    assert obs.metrics.get("checkpoint_disk_reads_total", label="leaf") == 3
    assert obs.metrics.get("checkpoint_disk_reads_total",
                           label="manifest") == 1


def test_transfer_stats_thread_local_vs_registry():
    """Documents the shim contract: a count_transfers region on the main
    thread does NOT see a worker thread's readbacks (thread-local by
    design), but the registry DOES — the cross-thread aggregation mode."""
    obs.enable_metrics()
    done = threading.Event()

    def worker():
        hostsync.read_scalar(jnp.asarray(2.0), label="worker_read")
        done.set()

    with hostsync.count_transfers() as st:
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert done.is_set()
    assert st.transfers == 0, "shim must stay thread-local"
    assert st.by_label == {}
    assert obs.metrics.get("hostsync_transfers_total",
                           label="worker_read") == 1


def test_transfer_stats_cross_thread_region():
    """`count_transfers(cross_thread=True)` closes the thread-local blind
    spot: the scoped region counts readbacks issued by OTHER threads (the
    detokenize-drain consumer) while it is open — matching the registry —
    without changing the default thread-local contract."""
    done = threading.Event()

    def worker():
        hostsync.read_scalar(jnp.asarray(2.0), label="drain_read")
        hostsync.batched_get([jnp.zeros(2), jnp.zeros(3)],
                             label="drain_read")
        done.set()

    with hostsync.count_transfers(cross_thread=True) as xt, \
            hostsync.count_transfers() as local:
        hostsync.read_scalar(jnp.asarray(1.0), label="main_read")
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert done.is_set()
    # cross-thread region sees BOTH threads' readbacks
    assert xt.by_label == {"main_read": 1, "drain_read": 3}
    assert xt.batches == 3 and xt.transfers == 4
    # the plain region on the same thread stays thread-local
    assert local.by_label == {"main_read": 1}
    # deregistration: readbacks after the region close are not counted
    hostsync.read_scalar(jnp.asarray(3.0), label="late_read")
    assert "late_read" not in xt.by_label


def test_transfer_stats_cross_thread_nests_with_registry():
    """All three views are independent: thread-local region, cross-thread
    region, and the metrics registry each see their own scope."""
    obs.enable_metrics()

    def worker():
        hostsync.read_scalar(jnp.asarray(1.0), label="w")

    with hostsync.count_transfers(cross_thread=True) as xt:
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert xt.by_label == {"w": 1}
    assert obs.metrics.get("hostsync_transfers_total", label="w") == 1


# ---------------------------------------------------------------------------
# journal
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_canonical(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = FaultJournal(path)
    j.append("detection", step=np.int64(4),
             event={"step": np.int32(4), "detail": {3: np.float32(1.5),
                                                    "arr": np.arange(2)}})
    j.append("recovery", step=2, record={"kind": "restore", "at": 5})
    j.close()
    loaded = FaultJournal.load(path)
    assert [r["kind"] for r in loaded] == ["detection", "recovery"]
    assert loaded[0]["seq"] == 0 and loaded[1]["seq"] == 1
    assert loaded[0]["t_mono"] <= loaded[1]["t_mono"]
    # byte-for-byte: in-memory records equal their disk round trip
    for mem, disk in zip(j.entries, loaded):
        assert canonical(mem) == canonical(disk)
    # numpy scalars and int keys normalized identically on both sides
    assert loaded[0]["event"]["detail"]["3"] == 1.5
    assert loaded[0]["event"]["detail"]["arr"] == [0, 1]


def test_jsonable_normalizes_like_json():
    obj = {"a": np.int32(1), "b": (np.float64(2.0), np.bool_(True)),
           5: np.arange(3), "n": None}
    norm = _jsonable(obj)
    assert norm == json.loads(json.dumps(norm))


def test_event_to_record_and_reconcile():
    from repro.core.detection import DetectionEvent
    evs = [DetectionEvent(step=3, boundary="deferred", effect="TDC",
                          detail={"detected_at": 7, "lag": 4})]
    recs = [{"kind": "restore", "step": 2, "rollbacks": 1, "at": 3}]
    j = FaultJournal()
    for e in evs:
        j.append("detection", step=e.step, event=event_to_record(e))
    for r in recs:
        j.append("recovery", step=r["step"], record=r)
    verdict = obs.reconcile(j.records(), evs, recs)
    assert verdict == {"detections_match": True, "recoveries_match": True}
    verdict = obs.reconcile(j.records(), evs, [dict(recs[0], at=9)])
    assert not verdict["recoveries_match"]


def test_journal_fsync_cadence_and_explicit_sync(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = FaultJournal(path, fsync_every=2)
    j.append("checkpoint", step=1)
    assert j.synced_seq == -1              # first append only flushed
    j.append("checkpoint", step=2)
    assert j.synced_seq == 1               # cadence hit: both on disk
    j.append("checkpoint", step=3)
    assert j.synced_seq == 1
    j.sync()
    assert j.synced_seq == 2
    j.close()
    assert [r["step"] for r in FaultJournal.load(path)] == [1, 2, 3]


def test_journal_survives_torn_final_line(tmp_path):
    """Crash regression: a kill -9 mid-write leaves a torn last line; the
    loader must return every complete record and skip the fragment."""
    path = str(tmp_path / "j.jsonl")
    j = FaultJournal(path, fsync_every=1)
    for s in range(3):
        j.append("detection", step=s, event={"step": s})
    # simulate the crash: the file handle is abandoned (no close()) and the
    # next process finds a half-written line at the tail
    j._fh.write('{"kind": "detection", "seq": 3, "tr')
    j._fh.flush()
    j._fh = None                           # drop without close/atexit flush
    loaded = FaultJournal.load(path)
    assert [r["step"] for r in loaded] == [0, 1, 2]
    assert all(r["kind"] == "detection" for r in loaded)


def test_journal_rotation_preserves_full_stream(tmp_path):
    """Size rotation keeps ONE prior generation; across a single rotation
    `load()` still reconstructs the full stream in order (the documented
    bounded-campaign contract)."""
    path = str(tmp_path / "j.jsonl")
    j = FaultJournal(path, max_bytes=2048)
    s = 0
    while not os.path.exists(path + ".1"):     # fill to the first rotation
        j.append("checkpoint", step=s)
        s += 1
        assert s < 200, "rotation never triggered"
    for _ in range(3):                         # a short tail generation
        j.append("checkpoint", step=s)
        s += 1
    j.close()
    loaded = FaultJournal.load(path)
    assert [r["seq"] for r in loaded] == list(range(s))
    assert [r["step"] for r in loaded] == list(range(s))
    for mem, disk in zip(j.entries, loaded):
        assert canonical(mem) == canonical(disk)


# ---------------------------------------------------------------------------
# KPIs under elastic events (fail-in-place, DESIGN.md §16)
# ---------------------------------------------------------------------------

def test_kpi_elastic_remesh_not_counted_as_sdc_recovery():
    """An elastic_remesh recovery pairs with the heartbeat anomaly that
    triggered it — never with an SDC detection line — so `mttr_s` and
    `elastic_mttr_s` stay independent."""
    j = FaultJournal()
    j.append("detection", step=5,
             event={"step": 5, "boundary": "deferred", "effect": "TDC",
                    "detail": {"detected_at": 7, "lag": 4}})
    j.append("heartbeat_anomaly", host=2, gap_s=30.0, anomaly="stale")
    j.append("recovery", step=6,
             record={"kind": "elastic_remesh", "step": 6, "at": 8,
                     "downtime_s": 2.0})
    j.append("recovery", step=5,
             record={"kind": "restore", "step": 5, "rollbacks": 1, "at": 7})
    recs = j.records()
    k = compute_kpis(recs, steps=20, wall_s=100.0)
    assert k["detections"] == 1 and k["recoveries"] == 2
    assert k["elastic_remeshes"] == 1
    assert k["node_loss_downtime_s"] == pytest.approx(2.0)
    # the SDC restore pairs with the detection (seq 3 - seq 0)...
    assert k["mttr_s"] == pytest.approx(recs[3]["t_mono"] -
                                        recs[0]["t_mono"])
    # ...and the remesh pairs with the heartbeat anomaly (seq 2 - seq 1)
    assert k["elastic_mttr_s"] == pytest.approx(recs[2]["t_mono"] -
                                                recs[1]["t_mono"])
    # redone work folds in from BOTH; downtime additionally scales uptime
    assert k["redone_steps"] == (8 - 6) + (7 - 5)
    assert k["availability"] == pytest.approx((1 - 4 / 20) * (1 - 2 / 100))


def test_kpi_shrink_then_regrow_replay():
    """A shrink + regrow campaign replayed from the journal: each remesh
    claims its own heartbeat anomaly, none double-pair, and with no SDC
    detections the SDC MTTR stays zero."""
    j = FaultJournal()
    j.append("heartbeat_anomaly", host=3, gap_s=45.0, anomaly="stale")
    j.append("recovery", step=10,
             record={"kind": "elastic_remesh", "step": 10, "at": 12,
                     "direction": "shrink", "downtime_s": 1.0})
    j.append("heartbeat_anomaly", host=3, gap_s=0.0, anomaly="rejoin")
    j.append("recovery", step=20,
             record={"kind": "elastic_remesh", "step": 20, "at": 20,
                     "direction": "regrow", "downtime_s": 0.5})
    recs = j.records()
    k = compute_kpis(recs, steps=40, wall_s=200.0)
    assert k["detections"] == 0
    assert k["mttr_s"] == 0.0              # nothing SDC-shaped to pair
    assert k["elastic_remeshes"] == 2
    assert k["node_loss_downtime_s"] == pytest.approx(1.5)
    # each remesh claimed the anomaly immediately preceding it
    want = ((recs[1]["t_mono"] - recs[0]["t_mono"]) +
            (recs[3]["t_mono"] - recs[2]["t_mono"])) / 2
    assert k["elastic_mttr_s"] == pytest.approx(want)


def test_journal_replay_groups():
    j = FaultJournal()
    j.append("detection", step=1)
    j.append("rejection", step=2, rid=7)
    j.append("detection", step=3)
    groups = obs.replay(j.records())
    assert len(groups["detection"]) == 2
    assert groups["rejection"][0]["rid"] == 7


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------

def test_trace_spans_chrome_format(tmp_path):
    tr = TraceRecorder()
    with tr.span("decode_tick", step=3):
        with tr.span("validate"):
            pass
    path = str(tmp_path / "trace.json")
    tr.write(path)
    with open(path) as fh:
        doc = json.load(fh)
    names = [e["name"] for e in doc["traceEvents"]]
    assert names == ["validate", "decode_tick"]   # inner span closes first
    for e in doc["traceEvents"]:
        assert e["ph"] == "X" and e["dur"] >= 0 and e["ts"] >= 0
    assert doc["traceEvents"][1]["args"]["step"] == 3


def test_global_span_noop_until_enabled():
    ctx = obs.span("anything")
    with ctx:
        pass
    assert obs.get_trace() is None
    tr = obs.enable_trace()
    with obs.span("real", step=1):
        pass
    assert [e["name"] for e in tr.by_name("real")] == ["real"]


# ---------------------------------------------------------------------------
# note_* intake + KPIs
# ---------------------------------------------------------------------------

def test_note_functions_feed_metrics_and_journal():
    from repro.core.detection import DetectionEvent
    obs.enable_metrics()
    j = FaultJournal()
    obs.set_journal(j)
    ev = DetectionEvent(step=4, boundary="commit", effect="TDC", detail={})
    obs.note_detection(ev)
    obs.note_recovery({"kind": "restore", "step": 2, "rollbacks": 1,
                       "at": 4, "tier": "device"})
    obs.note_recovery({"kind": "retry", "step": None, "rollbacks": 0,
                       "at": 5})
    obs.note_checkpoint(6)
    obs.note_tier_save("host")
    obs.note_tier_restore("device", 3)
    obs.note_tier_event({"kind": "tier_fallback", "tier": "disk",
                         "version": 2, "error": "X"})
    obs.note_rejection(7, rid=1, slot=0, reason="persistent_fault")
    obs.note_tokens(3)
    m = obs.metrics
    assert m.get("sedar_detections_total", boundary="commit",
                 effect="TDC") == 1
    assert m.get("sedar_recoveries_total", kind="restore") == 1
    assert m.get("sedar_recoveries_total", kind="retry") == 1
    assert m.get("sedar_rollbacks_total") == 1
    assert m.get("sedar_retries_total") == 1
    assert m.get("sedar_checkpoints_total") == 1
    assert m.get("checkpoint_saves_total", tier="host") == 1
    assert m.get("checkpoint_restores_total", tier="device") == 1
    assert m.get("checkpoint_tier_fallbacks_total", tier="disk") == 1
    assert m.get("serve_rejections_total", reason="persistent_fault") == 1
    assert m.get("serve_tokens_emitted_total") == 3
    kinds = [r["kind"] for r in j.records()]
    assert kinds == ["detection", "recovery", "recovery", "checkpoint",
                     "tier_restore", "tier_fallback", "rejection"]


def test_compute_kpis_and_reconcile():
    j = FaultJournal()
    j.append("detection", step=3,
             event={"step": 3, "boundary": "deferred", "effect": "TDC",
                    "detail": {"detected_at": 7, "lag": 4}})
    j.append("recovery", step=2,
             record={"kind": "restore", "step": 2, "rollbacks": 1, "at": 3})
    j.append("detection", step=10,
             event={"step": 10, "boundary": "commit", "effect": "TDC",
                    "detail": {}})
    j.append("recovery", step=10,
             record={"kind": "retry", "step": None, "rollbacks": 0,
                     "at": 10})
    k = compute_kpis(j.records(), steps=20, tokens=40, injected=2)
    assert k["detections"] == 2 and k["recoveries"] == 2
    assert k["mttd_steps"] == pytest.approx(2.0)   # (4 + 0) / 2
    assert k["mttd_max_steps"] == 4.0
    assert k["redone_steps"] == 1                  # restore: 3 - 2
    assert k["availability"] == pytest.approx(1 - 1 / 20)
    assert k["goodput_tokens_per_step"] == pytest.approx(2.0)
    assert k["sdc_coverage"] == 1.0
    assert k["mttr_s"] >= 0.0
    rows = reconcile_with_advice(k, validate_lag=8)
    by_metric = {r["metric"]: r for r in rows}
    assert by_metric["mttd_max_steps"]["ok"]
    assert by_metric["sdc_coverage"]["ok"]
    rows = reconcile_with_advice(k, validate_lag=2)
    assert not [r for r in rows if r["metric"] == "mttd_max_steps"][0]["ok"]


# ---------------------------------------------------------------------------
# cluster gauges + heartbeat anomalies (satellite)
# ---------------------------------------------------------------------------

def test_cluster_monitor_publish(tmp_path):
    from repro.runtime.cluster import ClusterMonitor, Heartbeat
    obs.enable_metrics()
    j = FaultJournal()
    obs.set_journal(j)
    hb_dir = str(tmp_path / "hb")
    for host, step in ((0, 10), (1, 10), (2, 2)):
        Heartbeat(hb_dir, host).beat(step)
    mon = ClusterMonitor(hb_dir, n_hosts=4, timeout_s=60.0,
                         straggler_factor=2.0)
    import time as _time
    summary = mon.publish(now=_time.time())
    assert summary["stale"] == [3]            # host 3 never beat
    assert summary["stragglers"] == [2]
    m = obs.metrics
    assert m.get("cluster_hosts_seen") == 3
    assert m.get("cluster_hosts_expected") == 4
    assert m.get("cluster_stale_hosts") == 1
    assert m.get("cluster_stragglers") == 1
    assert m.get("cluster_host_step", host=2) == 2
    anomalies = j.records("heartbeat_anomaly")
    assert {(a["host"], a["anomaly"]) for a in anomalies} == \
        {(3, "stale"), (2, "straggler")}
    assert m.get("cluster_heartbeat_anomalies_total", kind="stale") == 1


# ---------------------------------------------------------------------------
# launcher bundle
# ---------------------------------------------------------------------------

def test_configure_finalize_writes_artifacts(tmp_path):
    mdir = str(tmp_path / "metrics")
    tpath = str(tmp_path / "trace.json")
    ob = obs.configure(metrics_dir=mdir, trace=tpath)
    assert obs.metrics_enabled() and obs.get_journal() is not None
    with obs.span("train_step", step=0):
        pass
    obs.note_checkpoint(4)
    snap = ob.finalize()
    assert "sedar_checkpoints_total 1" in snap
    with open(mdir + "/metrics.prom") as fh:
        assert fh.read() == snap
    loaded = FaultJournal.load(mdir + "/journal.jsonl")
    assert [r["kind"] for r in loaded] == ["checkpoint"]
    with open(tpath) as fh:
        assert [e["name"] for e in json.load(fh)["traceEvents"]] == \
            ["train_step"]
    assert obs.get_journal() is None   # finalize detaches the journal


# ---------------------------------------------------------------------------
# live status view (DESIGN.md §17)
# ---------------------------------------------------------------------------

def test_status_render_consolidates_run_artifacts(tmp_path):
    from repro.launch.status import render
    mdir = str(tmp_path / "metrics")
    ob = obs.configure(metrics_dir=mdir)
    for _ in range(4):
        with obs.span("train_step", step=0):
            pass
    obs.note_checkpoint(6)
    obs.note_alert({"name": "step_time_drift", "severity": "warning",
                    "step": 8, "message": "band fired", "detail": {}})
    obs.note_reconfig({"kind": "reconfig", "step": 12, "reason": "autotune",
                       "changes": {"validate_lag": {"from": 4, "to": 16}}})
    ob.finalize()
    page = render(mdir)
    assert "journal: 3 records" in page
    assert "train_step" in page and "n=4" in page
    assert "step_time_drift" in page and "band fired" in page
    assert "validate_lag: 4->16" in page and "autotune" in page
    assert "optimal validate lag" in page      # the calibrated-model line


def test_status_render_empty_dir_is_graceful(tmp_path):
    from repro.launch.status import render
    page = render(str(tmp_path))
    assert "journal: empty" in page


# ---------------------------------------------------------------------------
# CI bench-regression gate (benchmarks/compare.py)
# ---------------------------------------------------------------------------

def _summary(metrics=None, acceptance=None):
    return {"suites": {"s": {"artifact": "BENCH_s.json",
                             "metrics": metrics or {},
                             "acceptance": acceptance or {}}}}


def test_compare_direction_heuristics():
    from benchmarks.compare import direction
    assert direction("protected_steps_per_s") == +1
    assert direction("serve_goodput_tok_s") == +1
    assert direction("adaptive_wall_s") == -1
    assert direction("mttr_s") == -1
    assert direction("mystery_quantity") is None
    # PR-10 drain metrics: gated in the directions they must move
    assert direction("continuous_drain_tokens_per_s") == +1
    assert direction("emission_syncs_per_token") == -1


def test_compare_flags_directional_regressions():
    from benchmarks.compare import compare
    base = _summary(metrics={"steps_per_s": 100.0, "wall_s": 10.0},
                    acceptance={"converged": True})
    same = compare(base, base)
    assert same == []
    # throughput falls 50% -> regression; cost falls -> improvement
    cur = _summary(metrics={"steps_per_s": 50.0, "wall_s": 5.0},
                   acceptance={"converged": True})
    regs = compare(base, cur)
    assert [r["metric"] for r in regs] == ["steps_per_s"]
    # cost rises 50% -> regression, within threshold -> clean
    cur = _summary(metrics={"steps_per_s": 100.0, "wall_s": 15.0})
    assert [r["metric"] for r in compare(base, cur)][:1] == ["wall_s"]
    cur = _summary(metrics={"steps_per_s": 95.0, "wall_s": 11.0},
                   acceptance={"converged": True})
    assert compare(base, cur) == []


def test_compare_acceptance_flip_and_missing_suite():
    from benchmarks.compare import compare
    base = _summary(metrics={"wall_s": 10.0}, acceptance={"converged": True})
    cur = _summary(metrics={"wall_s": 10.0}, acceptance={"converged": False})
    regs = compare(base, cur)
    assert [(r["kind"], r["metric"]) for r in regs] == \
        [("acceptance", "converged")]
    regs = compare(base, {"suites": {}})
    assert regs[0]["kind"] == "missing"
    # undirectable metrics are never gated
    base = _summary(metrics={"mystery_quantity": 1.0})
    cur = _summary(metrics={"mystery_quantity": 100.0})
    assert compare(base, cur) == []


def test_compare_cli_skips_without_baseline(tmp_path, capsys, monkeypatch):
    from benchmarks import compare as cmp
    cur = tmp_path / "BENCH_summary.json"
    cur.write_text(json.dumps(_summary(metrics={"wall_s": 10.0})))
    monkeypatch.setattr("sys.argv", [
        "compare", "--baseline", str(tmp_path / "missing.json"),
        "--current", str(cur)])
    with pytest.raises(SystemExit) as e:
        cmp.main()
    assert e.value.code == 0
    assert "skipping" in capsys.readouterr().out


def test_compare_cli_fails_on_regression(tmp_path, capsys, monkeypatch):
    from benchmarks import compare as cmp
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(_summary(metrics={"wall_s": 10.0})))
    cur.write_text(json.dumps(_summary(metrics={"wall_s": 20.0})))
    monkeypatch.setattr("sys.argv", [
        "compare", "--baseline", str(base), "--current", str(cur)])
    with pytest.raises(SystemExit) as e:
        cmp.main()
    assert e.value.code == 1
    assert "wall_s" in capsys.readouterr().out
    # loosening the threshold clears it
    monkeypatch.setattr("sys.argv", [
        "compare", "--baseline", str(base), "--current", str(cur),
        "--threshold", "1.5"])
    with pytest.raises(SystemExit) as e:
        cmp.main()
    assert e.value.code == 0
