"""Unified SEDAR engine: executor x recovery-level matrix on a toy workload.

The engine decouples the detection/recovery protocol from the model, so the
full {sequential, pod, vote} x {L1, L2, L3} matrix runs on a tiny synthetic
step function — no transformer in the loop. Pod/vote cells need >1 device
and run in subprocesses with forced host device counts (the main pytest
process must keep seeing 1 device).

Also asserts the acceptance property of the refactor: the TRAINING driver
and the SERVING driver emit identical DetectionEvent streams for the same
class of injected fault, because both execute through
`SedarEngine.run_protected_step()`.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.detection import SedarSafeStop
from repro.core.engine import BoundarySchedule, SequentialExecutor
from repro.core.fingerprint import pytree_fingerprint, \
    pytree_fingerprint_fused
from repro.core.injection import InjectionSpec, MemoryInjectionFlag, \
    inject_tree
from repro.core.policy import make_engine
from repro.core.recovery import RetryRecovery
from repro.configs import SedarConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- toy workload -----------------------------------------------------------

def _toy_step_fn(spec):
    """state {"x": f32[16], "step": i32} -> decayed update + optional fault."""

    def step_fn(state, batch, replica_id, armed):
        delta = 0.1 * batch - 0.01 * state["x"]
        if spec is not None:
            delta = inject_tree({"d": delta}, spec, step=state["step"],
                                replica_id=replica_id, armed=armed)["d"]
        fp = pytree_fingerprint_fused({"d": delta})
        cand = {"x": state["x"] + delta, "step": state["step"] + 1}
        return cand, fp, jnp.sum(cand["x"])

    return jax.jit(step_fn)


def _toy_engine(workdir, level, spec=None, backend="sequential",
                ckpt_interval=3, validate_interval=4, toe_timeout_s=60.0,
                delay_source=None):
    sedar = SedarConfig(level=level, replication=backend,
                        validate_interval=1,
                        param_validate_interval=validate_interval,
                        checkpoint_interval=ckpt_interval,
                        checkpoint_dir=os.path.join(workdir, "ckpt"),
                        toe_timeout_s=toe_timeout_s)
    state_fp = jax.jit(lambda s: pytree_fingerprint({"x": s["x"]}))
    fast_fp = jax.jit(lambda s: pytree_fingerprint_fused({"x": s["x"]}))

    def init_single():
        return {"x": jnp.zeros((16,), jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    eng = make_engine(sedar, backend=backend, workdir=workdir,
                      step_fn=_toy_step_fn(spec), state_fp_fn=state_fp,
                      fast_state_fp_fn=fast_fp, inj_spec=spec,
                      inj_flag=MemoryInjectionFlag(),
                      init_fn=lambda: eng.executor.init_dual(init_single()),
                      notify=lambda e: None, delay_source=delay_source)
    return eng


def _drive(eng, num_steps, max_iters=80):
    """Minimal driver: the same protected-step loop train/serve use."""
    dual = eng.init_dual()
    eng.reset()
    stopped = False
    it = 0
    while int(np.asarray(dual["r0"]["step"])) < num_steps:
        it += 1
        assert it < max_iters, "engine did not converge"
        step = int(np.asarray(dual["r0"]["step"]))
        batch = jnp.full((16,), float(step + 1), jnp.float32)
        outcome = eng.run_protected_step(dual, batch, step)
        dual = outcome.dual
        if outcome.event is not None:
            try:
                dual = eng.on_detection(outcome.event, dual)
            except SedarSafeStop:
                stopped = True
                break
    return dual, stopped


# -- sequential x {L1, L2, L3} ----------------------------------------------

SPEC = InjectionSpec(leaf_idx=0, flat_idx=5, bit=20, step=4, replica=1,
                     target="grads")


@pytest.mark.parametrize("level,kinds", [
    (1, ["stop"]),
    (2, ["restore"]),
    (3, ["restore"]),
])
def test_matrix_sequential(tmp_workdir, level, kinds):
    eng = _toy_engine(tmp_workdir, level, spec=SPEC)
    dual, stopped = _drive(eng, 8)
    assert [e.boundary for e in eng.detections] == ["commit"]
    assert [e.effect for e in eng.detections] == ["TDC"]
    assert eng.detections[0].step == 4
    assert [r["kind"] for r in eng.recoveries] == kinds
    if level == 1:
        assert stopped
    else:
        assert not stopped
        assert eng.recoveries[0]["rollbacks"] == 1
        assert int(np.asarray(dual["r0"]["step"])) == 8
        # recovered trajectory == clean trajectory (bitwise)
        clean = _toy_engine(tmp_workdir + "_clean", level)
        dual_c, _ = _drive(clean, 8)
        np.testing.assert_array_equal(np.asarray(dual["r0"]["x"]),
                                      np.asarray(dual_c["r0"]["x"]))


def test_matrix_plain_baseline(tmp_workdir):
    """backend='none' is the UNPROTECTED baseline: a corruption on the one
    executing instance commits silently — zero detections, diverged state.
    This is the control row of the matrix (what SEDAR exists to prevent)."""
    spec = InjectionSpec(leaf_idx=0, flat_idx=5, bit=20, step=4, replica=0,
                        target="grads")
    eng = _toy_engine(tmp_workdir, 1, spec=spec, backend="none")
    dual, stopped = _drive(eng, 8)
    assert not stopped
    assert eng.detections == [] and eng.recoveries == []
    assert int(np.asarray(dual["r0"]["step"])) == 8
    clean = _toy_engine(tmp_workdir + "_clean", 1, backend="none")
    dual_c, _ = _drive(clean, 8)
    assert not np.array_equal(np.asarray(dual["r0"]["x"]),
                              np.asarray(dual_c["r0"]["x"]))


@pytest.mark.parametrize("level,kinds,stops", [
    (1, ["stop"], True),
    (2, ["restore"], False),
])
def test_matrix_sequential_toe_watchdog_timeout(tmp_workdir, level, kinds,
                                                stops):
    """TOE boundary: one replica's execution delayed past the configured
    lapse (the paper's replica flow separation). The delay hook is one-shot
    — the re-execution after recovery is not delayed again — so L2 finishes
    while L1 safe-stops. The lapse is wide enough that jit-compile skew on
    the first replica execution cannot trip it spuriously."""
    delays = {(4, 1): 2.5}
    eng = _toy_engine(tmp_workdir, level, toe_timeout_s=1.0,
                      delay_source=lambda: delays)
    dual, stopped = _drive(eng, 8)
    assert [e.boundary for e in eng.detections] == ["toe"]
    assert [e.effect for e in eng.detections] == ["TOE"]
    assert eng.detections[0].step == 4
    assert [r["kind"] for r in eng.recoveries] == kinds
    assert stopped == stops
    if not stops:
        assert int(np.asarray(dual["r0"]["step"])) == 8
        clean = _toy_engine(tmp_workdir + "_clean", level)
        dual_c, _ = _drive(clean, 8)
        np.testing.assert_array_equal(np.asarray(dual["r0"]["x"]),
                                      np.asarray(dual_c["r0"]["x"]))


def test_matrix_sequential_l2_restart_scratch(tmp_workdir):
    """Detection before the first checkpoint: Alg. 1 walks past the (empty)
    chain and relaunches from the beginning."""
    spec = InjectionSpec(leaf_idx=0, flat_idx=5, bit=20, step=1, replica=1,
                         target="grads")
    eng = _toy_engine(tmp_workdir, 2, spec=spec, ckpt_interval=5)
    dual, stopped = _drive(eng, 6)
    assert not stopped
    assert eng.recoveries[0]["kind"] == "restart_scratch"
    assert int(np.asarray(dual["r0"]["step"])) == 6


def test_matrix_sequential_retry_policy(tmp_workdir):
    """L0 retry policy (the serving default) through the same engine:
    detection -> retry (no rollback) -> clean re-execution completes."""
    eng = _toy_engine(tmp_workdir, 1, spec=SPEC)
    eng.recovery = RetryRecovery(max_retries=4)
    dual, stopped = _drive(eng, 8)
    assert not stopped
    assert [r["kind"] for r in eng.recoveries] == ["retry"]
    assert eng.recoveries[0]["rollbacks"] == 1
    assert int(np.asarray(dual["r0"]["step"])) == 8


def test_retry_budget_degrades_to_safe_stop(tmp_workdir):
    """A persistent (non-transient) divergence exhausts the retry budget and
    degrades to the L1 safe stop instead of looping forever."""

    def bad_step(state, batch, replica_id, armed):
        delta = 0.1 * batch + jnp.where(replica_id == 1, 1e-3, 0.0)
        fp = pytree_fingerprint_fused({"d": delta})
        cand = {"x": state["x"] + delta, "step": state["step"] + 1}
        return cand, fp, jnp.sum(cand["x"])

    sedar = SedarConfig(level=1, replication="sequential",
                        param_validate_interval=0, checkpoint_interval=0)
    eng = make_engine(
        sedar, backend="sequential", step_fn=jax.jit(bad_step),
        state_fp_fn=jax.jit(lambda s: pytree_fingerprint({"x": s["x"]})),
        recovery=RetryRecovery(max_retries=3),
        init_fn=lambda: SequentialExecutor.init_dual(
            None, {"x": jnp.zeros((16,), jnp.float32),
                   "step": jnp.zeros((), jnp.int32)}),
        notify=lambda e: None)
    dual, stopped = _drive(eng, 4, max_iters=20)
    assert stopped
    assert [r["kind"] for r in eng.recoveries] == ["retry"] * 3 + ["stop"]


# -- pod / vote x levels (subprocess: forced host devices) -------------------

def _run(script: str, devices: int, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout

_POD_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import SedarConfig
from repro.core.detection import (SedarSafeStop, make_pod_comparator,
                                  make_pod_broadcaster, _shard_map)
from repro.core.fingerprint import pytree_fingerprint, pytree_fingerprint_fused
from repro.core.injection import flip_bit
from repro.core.policy import make_engine
from repro.launch.mesh import make_test_mesh

N_POD = %(n_pod)d
mesh = make_test_mesh((N_POD, 2, 1), ("pod", "data", "model"))
cmp_fp = make_pod_comparator(mesh, "pod")

def pod_inject(x, step):
    def inner(xl, st):
        rid = jax.lax.axis_index("pod")
        fire = jnp.logical_and(rid == 1, st == 4)
        return jnp.where(fire, flip_bit(xl, 5, 20), xl)
    return _shard_map(inner, mesh, in_specs=(P(), P()), out_specs=P())(
        x, jnp.asarray(step))

def pod_step(state, batch, armed):
    delta = 0.1 * batch - 0.01 * state["x"]
    delta = jax.lax.cond(armed, lambda d: pod_inject(d, state["step"]),
                         lambda d: d, delta)
    fp = pytree_fingerprint_fused({"d": delta})
    eq, fp_all = cmp_fp(fp)
    cand = {"x": state["x"] + delta, "step": state["step"] + 1}
    new_state = jax.tree.map(lambda a, b: jnp.where(eq, a, b), cand, state)
    return new_state, eq, fp_all, jnp.sum(cand["x"])

def pod_validate(state):
    return cmp_fp(pytree_fingerprint_fused({"x": state["x"]}))

state_fp = jax.jit(lambda s: pytree_fingerprint({"x": s["x"]}))

class Flag:
    fired = False
    def already_injected(self): return self.fired
    def mark(self): self.fired = True
    def arm_spec(self, spec): return None if self.fired else spec

class Spec:   # duck-typed: the engine only reads .step
    step = 4

def drive(eng, num_steps):
    dual = eng.init_dual()
    eng.reset()
    it = 0
    while int(np.asarray(dual["r0"]["step"])) < num_steps:
        it += 1
        assert it < 60, "did not converge"
        step = int(np.asarray(dual["r0"]["step"]))
        batch = jnp.full((16,), float(step + 1), jnp.float32)
        outcome = eng.run_protected_step(dual, batch, step)
        dual = outcome.dual
        if outcome.event is not None:
            try:
                dual = eng.on_detection(outcome.event, dual)
            except SedarSafeStop:
                return dual, True
    return dual, False

def build(level, backend, workdir, bcast=None):
    sedar = SedarConfig(level=level, replication=backend,
                        validate_interval=1, param_validate_interval=4,
                        checkpoint_interval=3, checkpoint_dir=workdir)
    eng = make_engine(sedar, backend=backend, workdir=workdir,
                      state_fp_fn=state_fp, pod_step=jax.jit(pod_step),
                      pod_validate=jax.jit(pod_validate),
                      pod_broadcaster=bcast, n_replicas=N_POD,
                      inj_spec=Spec(), inj_flag=Flag(),
                      init_fn=lambda: {"r0": {
                          "x": jnp.zeros((16,), jnp.float32),
                          "step": jnp.zeros((), jnp.int32)}},
                      notify=lambda e: None)
    return eng
"""


def test_matrix_pod_levels(tmp_workdir):
    """Pod backend (space redundancy) x {L1, L2, L3}: same detection step,
    same boundary, level-appropriate recovery kinds and rollback counts."""
    script = _POD_PRELUDE % {"n_pod": 2} + f"""
import shutil
with mesh:
    for level, want in ((1, ["stop"]), (2, ["restore"]), (3, ["restore"])):
        wd = {tmp_workdir!r} + f"/pod_l{{level}}"
        shutil.rmtree(wd, ignore_errors=True)
        eng = build(level, "pod", wd)
        dual, stopped = drive(eng, 8)
        assert [e.boundary for e in eng.detections] == ["commit"], (
            level, eng.detections)
        assert eng.detections[0].step == 4 and eng.detections[0].effect == "TDC"
        assert [r["kind"] for r in eng.recoveries] == want, (
            level, eng.recoveries)
        assert stopped == (level == 1)
        if level > 1:
            assert eng.recoveries[0]["rollbacks"] == 1
            assert int(np.asarray(dual["r0"]["step"])) == 8
print("pod matrix OK")
"""
    out = _run(script, devices=4, timeout=420)
    assert "pod matrix OK" in out


def test_matrix_vote_forward_correction(tmp_workdir):
    """Vote backend (NMR, 3 replicas): a commit fault re-executes with no
    rollback at every level — the majority repairs forward."""
    script = _POD_PRELUDE % {"n_pod": 3} + f"""
import shutil
bcast = make_pod_broadcaster(mesh, "pod")
with mesh:
    for level in (1, 2, 3):
        wd = {tmp_workdir!r} + f"/vote_l{{level}}"
        shutil.rmtree(wd, ignore_errors=True)
        eng = build(level, "vote", wd, bcast=bcast)
        dual, stopped = drive(eng, 8)
        assert not stopped, level
        assert [e.boundary for e in eng.detections] == ["commit"], (
            level, eng.detections)
        assert [r["kind"] for r in eng.recoveries] == ["vote_retry"], (
            level, eng.recoveries)
        assert all(r["rollbacks"] == 0 for r in eng.recoveries)
        assert int(np.asarray(dual["r0"]["step"])) == 8
print("vote matrix OK")
"""
    out = _run(script, devices=6, timeout=420)
    assert "vote matrix OK" in out


# -- train/serve event-stream equivalence ------------------------------------

def test_train_serve_identical_event_streams(tmp_workdir):
    """Both workload drivers run the SAME engine code path, so the same
    class of injected fault (single bit-flip on replica 1, caught at the
    commit boundary, retried/recovered once) must produce identical
    DetectionEvent streams modulo the step index."""
    from repro.configs import (RunConfig, TrainConfig, get_config,
                               reduce_for_smoke)
    from repro.runtime.serve import SedarServer
    from repro.runtime.train import SedarTrainer

    cfg = reduce_for_smoke(get_config("paper-testapp"))
    rc = RunConfig(model=cfg,
                   train=TrainConfig(global_batch=2, seq_len=8, steps=6,
                                     warmup_steps=2, lr=1e-3),
                   sedar=SedarConfig(level=3, replication="sequential",
                                     validate_interval=1,
                                     param_validate_interval=4,
                                     checkpoint_interval=4))
    tr_spec = InjectionSpec(leaf_idx=3, flat_idx=5, bit=20, step=4,
                            replica=1, target="grads")
    tr = SedarTrainer(rc, tmp_workdir, inj_spec=tr_spec)
    _, tr_rep = tr.run(6)

    srv_clean = SedarServer(rc)
    params = srv_clean.model.init(jax.random.PRNGKey(0))
    prompt = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, 200, (2, 8)), jnp.int32)}
    clean, _ = srv_clean.generate(params, prompt, steps=6)

    srv_spec = InjectionSpec(leaf_idx=2, flat_idx=3, bit=30, step=10,
                             replica=1, target="params")
    srv = SedarServer(rc, dual=True, inj_spec=srv_spec)
    toks, sv_rep = srv.generate(params, prompt, steps=6)

    tr_stream = [(e.boundary, e.effect) for e in tr_rep.detections]
    sv_stream = [(e.boundary, e.effect) for e in sv_rep.detections]
    assert tr_stream == sv_stream == [("commit", "TDC")]
    assert type(tr_rep.detections[0]) is type(sv_rep.detections[0])
    # both recovered: training rolled back once, serving retried once,
    # and neither emitted a corrupted result
    assert tr_rep.recoveries[0]["rollbacks"] == 1
    assert sv_rep.retries == 1
    np.testing.assert_array_equal(toks, clean)


# -- hot-path satellites (DESIGN.md §11) --------------------------------------

def test_sequential_fast_path_never_blocks(tmp_workdir, monkeypatch):
    """The fast path must not `block_until_ready` just to measure wall time:
    per-replica sync happens only while the TOE machinery is armed (a
    scenario delay is pending or the watchdog was armed explicitly)."""
    import repro.core.engine as eng_mod
    calls = {"n": 0}
    real = jax.block_until_ready

    def spy(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(eng_mod.jax, "block_until_ready", spy)
    eng = _toy_engine(tmp_workdir, 1)
    dual, stopped = _drive(eng, 4)
    assert not stopped
    assert calls["n"] == 0
    assert eng.executor.ema_step_s is not None and eng.executor.ema_step_s > 0

    # arming the watchdog re-enables the per-replica timing sync
    calls["n"] = 0
    eng2 = _toy_engine(tmp_workdir + "_armed", 1)
    eng2.executor.watchdog.arm()
    _drive(eng2, 2)
    assert calls["n"] > 0


def test_sequential_delay_source_arms_timing(tmp_workdir):
    """A pending scenario delay implies TOE timing — the existing watchdog
    tests exercise the detection itself; this pins the arming condition."""
    eng = _toy_engine(tmp_workdir, 1, delay_source=lambda: {(1, 1): 0.0})
    assert eng.executor._timing_armed({(1, 1): 0.0})
    assert not eng.executor._timing_armed({})


def test_pod_validated_fp_reuses_validate_reduction(tmp_workdir):
    """Satellite bugfix: validated_fp must reuse the all-replica equality
    reduction validate() just computed on the same state instead of
    re-running the all-gather compare."""
    from repro.core.engine import PodExecutor
    calls = {"n": 0}

    def pod_validate(state):
        calls["n"] += 1
        return jnp.asarray(True), jnp.zeros((2, 1, 4), jnp.uint32)

    ex = PodExecutor(pod_step=None, pod_validate=pod_validate,
                     state_fp_fn=lambda s: pytree_fingerprint({"x": s["x"]}))
    dual = {"r0": {"x": jnp.zeros((4,), jnp.float32)}}
    assert ex.validate(dual, 4) is None
    fp0, equal = ex.validated_fp(dual)
    assert equal and calls["n"] == 1          # ONE reduction for both calls
    # a different committed state invalidates the cache
    dual2 = {"r0": {"x": jnp.ones((4,), jnp.float32)}}
    ex.validate(dual2, 8)
    assert calls["n"] == 2


def test_sequential_validated_fp_reuses_validate_reduction(tmp_workdir):
    calls = {"n": 0}
    fast = jax.jit(lambda s: pytree_fingerprint_fused({"x": s["x"]}))

    def counting_fast(s):
        calls["n"] += 1
        return fast(s)

    eng = _toy_engine(tmp_workdir, 1)
    eng.executor.fast_state_fp_fn = counting_fast
    dual = eng.init_dual()
    assert eng.executor.validate(dual, 4) is None
    _, equal = eng.executor.validated_fp(dual)
    assert equal
    assert calls["n"] == 2                    # one pass per replica, once
