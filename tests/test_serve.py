"""Serving loop: greedy generation + dual-replica detection on decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ServeConfig, TrainConfig, get_config, \
    reduce_for_smoke
from repro.core.injection import InjectionSpec
from repro.runtime.serve import SedarServer


def _setup(dual=False, inj=None, backend=None):
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    rc = RunConfig(model=cfg, train=TrainConfig(global_batch=2, seq_len=8))
    srv = SedarServer(rc, dual=dual, inj_spec=inj, backend=backend)
    params = srv.model.init(jax.random.PRNGKey(0))
    prompt = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(0, 200, (2, 8)), jnp.int32)}
    return srv, params, prompt


def test_greedy_generate():
    srv, params, prompt = _setup()
    toks, rep = srv.generate(params, prompt, steps=6)
    assert toks.shape == (2, 6)
    assert rep.tokens_emitted == 12
    assert not rep.detections


def test_generate_deterministic():
    srv, params, prompt = _setup()
    t1, _ = srv.generate(params, prompt, steps=5)
    t2, _ = srv.generate(params, prompt, steps=5)
    np.testing.assert_array_equal(t1, t2)


@pytest.mark.parametrize("backend", ["abft", "hybrid"])
def test_replica_free_serve_backends(backend):
    """The abft/hybrid backends serve from ONE decode state through the
    same engine path and emit the same tokens as the plain server."""
    srv_c, params, prompt = _setup()
    clean, _ = srv_c.generate(params, prompt, steps=6)
    srv, _, _ = _setup(backend=backend)
    assert srv.engine.executor.name == backend
    assert srv.engine.executor.n_replicas == 1
    toks, rep = srv.generate(params, prompt, steps=6)
    assert not rep.detections and not rep.stopped
    np.testing.assert_array_equal(toks, clean)


def test_dual_serve_detects_and_retries():
    """Transient fault on one serve replica: detected (logits fingerprint
    mismatch), the step retries, output equals the clean run."""
    srv_c, params, prompt = _setup()
    clean, _ = srv_c.generate(params, prompt, steps=6)
    # exponent-bit flip in final_ln (touches every token's logits); a
    # mantissa flip of a 0.0 bias would be a denormal -> a true LE
    spec = InjectionSpec(leaf_idx=2, flat_idx=3, bit=30, step=10, replica=1,
                         target="params")   # fires at pos==10 on replica 1
    srv, params2, _ = _setup(dual=True, inj=spec)
    toks, rep = srv.generate(params, prompt, steps=6)
    assert rep.detections, "fault not detected on serve path"
    assert rep.retries >= 1
    np.testing.assert_array_equal(toks, clean)
