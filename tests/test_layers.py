"""Layer-level numerics: chunked attention == exact, mLSTM chunkwise ==
sequential, RG-LRU scan == stepwise, chunked CE == full CE, decode == train."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models import layers as nn
from repro.models import recurrent as rec
from repro.models import xlstm as xl

RS = np.random.RandomState(0)


def test_chunked_causal_matches_exact():
    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(RS.randn(B, S, H, hd).astype(np.float32))
    k = jnp.asarray(RS.randn(B, S, KV, hd).astype(np.float32))
    v = jnp.asarray(RS.randn(B, S, KV, hd).astype(np.float32))
    o1 = nn.causal_attention(q, k, v)
    o2 = nn.chunked_causal_attention(q, k, v, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_chunked_window_matches_band_mask():
    from repro.kernels.ref import mha_ref
    B, S, H, KV, hd, w = 2, 96, 4, 2, 8, 32
    q = jnp.asarray(RS.randn(B, S, H, hd).astype(np.float32))
    k = jnp.asarray(RS.randn(B, S, KV, hd).astype(np.float32))
    v = jnp.asarray(RS.randn(B, S, KV, hd).astype(np.float32))
    o_ref = mha_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), causal=True,
                    window=w).transpose(0, 2, 1, 3)
    o = nn.chunked_window_attention(q, k, v, w, q_chunk=16)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o), atol=2e-5)


def test_mlstm_chunkwise_matches_sequential():
    B, H, S, hd = 2, 3, 64, 8
    q = jnp.asarray(RS.randn(B, H, S, hd).astype(np.float32))
    k = jnp.asarray(RS.randn(B, H, S, hd).astype(np.float32)) / np.sqrt(hd)
    v = jnp.asarray(RS.randn(B, H, S, hd).astype(np.float32))
    i_raw = jnp.asarray(RS.randn(B, H, S).astype(np.float32))
    f_raw = jnp.asarray(2.0 + RS.randn(B, H, S).astype(np.float32))
    h_seq = xl.ref_mlstm_sequential(q, k, v, i_raw, f_raw)
    for chunk in (8, 16, 64):
        h_ck, _ = xl.mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk)
        np.testing.assert_allclose(np.asarray(h_seq), np.asarray(h_ck),
                                   atol=2e-4, rtol=1e-3)


def test_mlstm_chunkwise_state_continuation():
    """Processing [first half | second half with carried state] == full."""
    B, H, S, hd = 1, 2, 32, 8
    q = jnp.asarray(RS.randn(B, H, S, hd).astype(np.float32))
    k = jnp.asarray(RS.randn(B, H, S, hd).astype(np.float32)) / np.sqrt(hd)
    v = jnp.asarray(RS.randn(B, H, S, hd).astype(np.float32))
    i_raw = jnp.asarray(RS.randn(B, H, S).astype(np.float32))
    f_raw = jnp.asarray(2.0 + RS.randn(B, H, S).astype(np.float32))
    h_full, st_full = xl.mlstm_chunkwise(q, k, v, i_raw, f_raw, 8)
    h1, st1 = xl.mlstm_chunkwise(q[:, :, :16], k[:, :, :16], v[:, :, :16],
                                 i_raw[:, :, :16], f_raw[:, :, :16], 8)
    h2, st2 = xl.mlstm_chunkwise(q[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                                 i_raw[:, :, 16:], f_raw[:, :, 16:], 8,
                                 state=st1)
    np.testing.assert_allclose(np.asarray(h_full),
                               np.asarray(jnp.concatenate([h1, h2], axis=2)),
                               atol=2e-4, rtol=1e-3)
    for a, b in zip(st_full, st2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                                   rtol=1e-3)


def test_rglru_scan_matches_stepwise():
    cfg = reduce_for_smoke(get_config("recurrentgemma-2b"))
    p, _ = rec.init_recurrent_block(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    u = jnp.asarray(RS.randn(B, S, cfg.d_rnn).astype(np.float32))
    y_scan, h_last = rec.rg_lru_scan(p, u)
    h = jnp.zeros((B, cfg.d_rnn), jnp.float32)
    ys = []
    for t in range(S):
        y_t, h = rec.rg_lru_step(p, u[:, t], h)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h), atol=1e-5)


def test_chunked_ce_matches_full():
    cfg = reduce_for_smoke(get_config("qwen2-72b"))
    from repro.models.layers import (chunked_cross_entropy,
                                     cross_entropy_loss, init_embedding,
                                     logits_from_hidden)
    emb, _ = init_embedding(jax.random.PRNGKey(0), cfg)
    B, S = 2, 40
    h = jnp.asarray(RS.randn(B, S, cfg.d_model).astype(np.float32))
    tgt = jnp.asarray(RS.randint(0, cfg.vocab_size, (B, S)).astype(np.int32))
    full = cross_entropy_loss(logits_from_hidden(cfg, emb, h), tgt)
    ck = chunked_cross_entropy(cfg, emb, h, tgt, chunk=16)
    np.testing.assert_allclose(float(full), float(ck), rtol=1e-5)


def test_decode_matches_full_forward():
    """Greedy decode logits == teacher-forced forward logits, per position."""
    cfg = dataclasses.replace(reduce_for_smoke(get_config("qwen2-0.5b")))
    from repro.models import build_model
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jnp.asarray(RS.randint(0, 200, (B, S)).astype(np.int32))
    # full forward logits at final position
    from repro.models.transformer import lm_hidden
    from repro.models.layers import logits_from_hidden
    h, _, _ = lm_hidden(cfg, params, toks)
    full_logits = logits_from_hidden(cfg, params["embed"], h)
    # prefill over S-1, then decode token S-1
    logits_p, cache = m.prefill(params, {"tokens": toks[:, :-1]}, S + 4)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, -2]), atol=2e-4)
    # decode reads the bf16 KV cache -> bf16-level tolerance
    logits_d, _ = m.decode_step(params, cache, toks[:, -1],
                                jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full_logits[:, -1]), atol=5e-2)
    assert np.array_equal(np.argmax(np.asarray(logits_d), -1),
                          np.argmax(np.asarray(full_logits[:, -1]), -1))


def test_rope_positions():
    x = jnp.asarray(RS.randn(1, 4, 2, 8).astype(np.float32))
    sin, cos = nn.rope_tables(jnp.arange(4), 8, 10_000.0)
    y = nn.apply_rope(x, sin, cos)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]),
                               atol=1e-6)
    # norms preserved (rotation)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
