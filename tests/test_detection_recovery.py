"""End-to-end SEDAR: inject -> detect -> recover, per protection level.

These are the system-level analogues of the paper's Sec. 4.2 experiments:
the recovered trajectory must be bitwise identical to a fault-free run."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (RunConfig, SedarConfig, TrainConfig, get_config,
                           reduce_for_smoke)
from repro.core.injection import InjectionSpec
from repro.data import SyntheticLM
from repro.runtime.train import SedarTrainer

CFG = reduce_for_smoke(get_config("paper-testapp"))
TRAIN = TrainConfig(global_batch=4, seq_len=16, steps=10, warmup_steps=2,
                    lr=1e-3)


def _trainer(workdir, level, inj=None, data=None, **sedar_kw):
    kw = dict(level=level, replication="sequential", validate_interval=1,
              param_validate_interval=4, checkpoint_interval=4,
              toe_timeout_s=60.0)
    kw.update(sedar_kw)
    rc = RunConfig(model=CFG, train=TRAIN, sedar=SedarConfig(**kw))
    return SedarTrainer(rc, workdir, inj_spec=inj, data=data)


def _clean_fp(workdir, data=None):
    tr = _trainer(workdir + "_clean", 1, data=data)
    _, rep = tr.run(10)
    assert not rep.detections
    return rep.final_state_fp


def test_l1_detects_and_stops(tmp_workdir):
    spec = InjectionSpec(leaf_idx=3, flat_idx=5, bit=20, step=4, replica=1,
                         target="grads")
    tr = _trainer(tmp_workdir, 1, inj=spec)
    _, rep = tr.run(10)
    assert rep.stopped                                  # safe stop
    assert rep.detections and rep.detections[0].step == 4
    assert rep.detections[0].boundary == "commit"      # pre-send validation


def test_l3_tdc_single_rollback_bitexact(tmp_workdir):
    clean = _clean_fp(tmp_workdir)
    spec = InjectionSpec(leaf_idx=3, flat_idx=5, bit=20, step=5, replica=1,
                         target="grads")
    tr = _trainer(tmp_workdir, 3, inj=spec)
    _, rep = tr.run(10)
    assert len(rep.detections) == 1
    assert rep.recoveries[0]["kind"] == "restore"
    assert rep.recoveries[0]["rollbacks"] == 1          # Alg. 2: at most one
    assert np.array_equal(rep.final_state_fp[:, :2], clean[:, :2])


def test_l2_dirty_checkpoint_double_rollback(tmp_workdir):
    """FSC corruption in a never-touched embedding row: grad compare stays
    silent, the checkpoint cut after the fault is DIRTY, and Algorithm 1
    needs two rollbacks (paper Fig. 2b / scenario 50)."""
    data = SyntheticLM(vocab_size=200, global_batch=4, seq_len=16, seed=0)
    clean = _clean_fp(tmp_workdir, data=data)
    D = CFG.d_model
    spec = InjectionSpec(leaf_idx=1, flat_idx=250 * D + 3, bit=22, step=4,
                         replica=1, target="params")
    tr = _trainer(tmp_workdir, 2, inj=spec, data=data,
                  checkpoint_interval=3, param_validate_interval=8)
    _, rep = tr.run(10)
    assert [e.effect for e in rep.detections] == ["FSC", "FSC"]
    assert [r["rollbacks"] for r in rep.recoveries] == [1, 2]
    assert rep.recoveries[0]["step"] == 6               # dirty ckpt
    assert rep.recoveries[1]["step"] == 3               # clean ckpt
    assert np.array_equal(rep.final_state_fp[:, :2], clean[:, :2])


def test_le_dead_data_not_detected(tmp_workdir):
    """LE: corrupt a gradient row whose update is identical anyway? No —
    true LE is dead data. Corrupting replica-1's *optimizer v* for an unused
    row decays but never propagates to grads; param-validate catches it as
    state divergence (FSC). A genuinely dead fault = injection armed for a
    step that never executes -> zero detections, results valid."""
    data = SyntheticLM(vocab_size=200, global_batch=4, seq_len=16, seed=0)
    clean = _clean_fp(tmp_workdir, data=data)
    spec = InjectionSpec(leaf_idx=1, flat_idx=3, bit=22, step=99, replica=1,
                         target="params")                # beyond the run: LE
    tr = _trainer(tmp_workdir, 3, inj=spec, data=data)
    _, rep = tr.run(10)
    assert not rep.detections
    assert np.array_equal(rep.final_state_fp[:, :2], clean[:, :2])


def test_toe_detected_and_recovered(tmp_workdir):
    tr = _trainer(tmp_workdir, 3, toe_timeout_s=0.5)
    tr.toe_delay = {(5, 1): 0.8}                        # replica 1 stalls
    _, rep = tr.run(10)
    assert any(e.boundary == "toe" for e in rep.detections)
    assert rep.steps_completed == 10                    # recovered, finished


def test_l3_single_valid_checkpoint_invariant(tmp_workdir):
    tr = _trainer(tmp_workdir, 3)
    _, rep = tr.run(10)
    store = tr.recovery.store
    assert len(store.steps()) == 1                      # exactly one retained
    assert store.manifest(store.steps()[0]).valid is True


def test_l2_chain_never_pruned(tmp_workdir):
    tr = _trainer(tmp_workdir, 2, checkpoint_interval=2)
    _, rep = tr.run(10)
    assert len(tr.recovery.store.steps()) == len(rep.checkpoints) >= 4


def test_injection_flag_prevents_reinjection(tmp_workdir):
    """Paper's injected.txt: after recovery, re-execution of the same step
    does NOT re-inject (otherwise L3 would loop forever)."""
    spec = InjectionSpec(leaf_idx=3, flat_idx=5, bit=20, step=5, replica=1,
                         target="grads")
    tr = _trainer(tmp_workdir, 3, inj=spec)
    _, rep = tr.run(10)
    assert len(rep.detections) == 1                     # fired exactly once
    assert rep.steps_completed == 10


def test_plain_mode_ignores_faults(tmp_workdir):
    """Unprotected baseline silently commits the corruption (the paper's
    motivating failure mode)."""
    data = SyntheticLM(vocab_size=200, global_batch=4, seq_len=16, seed=0)
    clean = _clean_fp(tmp_workdir, data=data)
    spec = InjectionSpec(leaf_idx=3, flat_idx=5, bit=20, step=5, replica=0,
                         target="grads")
    tr = _trainer(tmp_workdir, 1, inj=spec, data=data, replication="none")
    _, rep = tr.run(10)
    assert not rep.detections
    assert not np.array_equal(rep.final_state_fp[:, :2], clean[:, :2])
