"""Shared fixtures. NB: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses with forced host
device counts (see test_multidevice.py).

Also installs a deterministic mini-`hypothesis` shim when the real package
is absent, so the property-test modules (test_checkpoint / test_data /
test_fingerprint / test_optim) still collect and run: each @given test is
executed over a fixed number of seeded pseudo-random examples instead of
being skipped wholesale. The shim covers exactly the API surface those
modules use (given, settings, st.integers / sampled_from / composite).
"""
import functools
import inspect
import os
import random
import sys
import types

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Initialize repro.core before any test module can import repro.checkpoint
# first: checkpoint.tiers -> obs.estimator -> repro.core -> recovery ->
# checkpoint.tiers is a cycle that only resolves when repro.core is already
# in sys.modules (running a single checkpoint-first test file used to die
# on a partially-initialized-module ImportError).
import repro.core  # noqa: E402,F401


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    SHIM_EXAMPLES = 5

    class _Strategy:
        """A strategy is just a seeded generator function."""

        def __init__(self, gen):
            self._gen = gen

        def __repr__(self):
            return "<shim strategy>"

    def integers(min_value=0, max_value=2**31 - 1):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def sampled_from(items):
        items = list(items)
        return _Strategy(lambda r: items[r.randrange(len(items))])

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda r: bool(r.randrange(2)))

    def lists(elements, min_size=0, max_size=8, **_kw):
        return _Strategy(lambda r: [elements._gen(r) for _ in
                                    range(r.randint(min_size, max_size))])

    def just(value):
        return _Strategy(lambda r: value)

    def composite(fn):
        def build(*args, **kwargs):
            def gen(r):
                return fn((lambda strat: strat._gen(r)), *args, **kwargs)
            return _Strategy(gen)
        return build

    def given(*strats, **kwstrats):
        def deco(test):
            sig = inspect.signature(test)
            names = list(sig.parameters)
            # hypothesis semantics: positional strategies bind to the LAST
            # parameters; anything before them is a pytest fixture
            bound = names[len(names) - len(strats):] if strats else []
            fixture_names = [n for n in names
                             if n not in bound and n not in kwstrats]

            @functools.wraps(test)
            def wrapper(**fixture_kwargs):
                rnd = random.Random(0)
                for _ in range(SHIM_EXAMPLES):
                    vals = {n: s._gen(rnd) for n, s in zip(bound, strats)}
                    vals.update({k: s._gen(rnd)
                                 for k, s in kwstrats.items()})
                    test(**fixture_kwargs, **vals)

            # hide strategy-bound params from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(
                parameters=[sig.parameters[n] for n in fixture_names])
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            wrapper._hypothesis_shim = True
            return wrapper
        return deco

    def settings(*args, **_kwargs):
        # used both as @settings(...) and settings(...)(fn)
        if args and callable(args[0]):
            return args[0]
        return lambda fn: fn

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.floats = floats
    st.booleans = booleans
    st.lists = lists
    st.just = just
    st.composite = composite

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    hyp.__version__ = "0.0-shim"

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_shim()


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


@pytest.fixture()
def tmp_workdir(tmp_path):
    return str(tmp_path / "work")
