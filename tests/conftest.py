"""Shared fixtures. NB: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses with forced host
device counts (see test_multidevice.py)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


@pytest.fixture()
def tmp_workdir(tmp_path):
    return str(tmp_path / "work")
