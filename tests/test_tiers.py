"""Tiered checkpoint hierarchy (DESIGN.md §12): rings, delta chains, the
cost-aware restore planner, and the engine-level acceptance properties.

Acceptance (ISSUE 4):
  * a fault injected at step k under L2 recovers from Tier 0/1 with ZERO
    disk reads when a ring slot <= k exists — asserted via
    `hostsync.count_transfers()` + `checkpoint.count_disk_reads()`;
  * delta checkpoints shrink bytes written >= 3x vs full checkpoints on
    the paper test-app state when < 1/3 of leaves change per interval;
  * Tier-2 corruption falls back to Tier 3 (then Tier 1) as a recorded
    recovery event, never an exception.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptionError, CheckpointStore,
                              DeltaCheckpointStore, TieredCheckpointer,
                              TierSchedule, count_disk_reads, make_tiered,
                              parse_tiers)
from repro.configs import SedarConfig
from repro.core import hostsync
from repro.core.fingerprint import pytree_fingerprint, \
    pytree_fingerprint_fused
from repro.core.injection import InjectionSpec, MemoryInjectionFlag, \
    inject_tree
from repro.core.policy import make_engine


# -- helpers ------------------------------------------------------------------

def _state(seed=0):
    rs = np.random.RandomState(seed)
    return {"x": jnp.asarray(rs.randn(16).astype(np.float32)),
            "step": jnp.asarray(seed, jnp.int32)}


def _toy_step_fn(spec):
    def step_fn(state, batch, replica_id, armed):
        delta = 0.1 * batch - 0.01 * state["x"]
        if spec is not None:
            delta = inject_tree({"d": delta}, spec, step=state["step"],
                                replica_id=replica_id, armed=armed)["d"]
        fp = pytree_fingerprint_fused({"d": delta})
        cand = {"x": state["x"] + delta, "step": state["step"] + 1}
        return cand, fp, jnp.sum(cand["x"])

    return jax.jit(step_fn)


def _toy_engine(workdir, level, spec=None, backend="sequential", lag=1,
                ckpt_interval=3, tiers="device,host,disk", slots=8,
                max_checkpoints=0):
    sedar = SedarConfig(level=level, replication=backend,
                        validate_interval=1, validate_lag=lag,
                        param_validate_interval=0,
                        checkpoint_interval=ckpt_interval,
                        max_checkpoints=max_checkpoints,
                        ckpt_tiers=tiers, device_ring_slots=slots,
                        host_ring_slots=slots,
                        checkpoint_dir=os.path.join(workdir, "ckpt"))
    state_fp = jax.jit(lambda s: pytree_fingerprint({"x": s["x"]}))
    fast_fp = jax.jit(lambda s: pytree_fingerprint_fused({"x": s["x"]}))

    def init_single():
        return {"x": jnp.zeros((16,), jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    eng = make_engine(sedar, backend=backend, workdir=workdir,
                      step_fn=_toy_step_fn(spec), state_fp_fn=state_fp,
                      fast_state_fp_fn=fast_fp, inj_spec=spec,
                      inj_flag=MemoryInjectionFlag(),
                      init_fn=lambda: eng.executor.init_dual(init_single()),
                      notify=lambda e: None)
    return eng


def _drive(eng, num_steps, on_event=None, max_iters=200):
    from repro.core.detection import SedarSafeStop
    dual = eng.init_dual()
    eng.reset()
    step = int(np.asarray(eng.executor.peek(dual, "step")))
    stopped, it = False, 0
    while True:
        if step >= num_steps:
            event = eng.flush_deferred()
            if event is None:
                break
            try:
                dual = eng.on_detection(event, dual)
            except SedarSafeStop:
                stopped = True
                break
            step = int(np.asarray(eng.executor.peek(dual, "step")))
            continue
        it += 1
        assert it < max_iters, "engine did not converge"
        batch = jnp.full((16,), float(step + 1), jnp.float32)
        outcome = eng.run_protected_step(dual, batch, step)
        dual = outcome.dual
        if outcome.committed and outcome.aux is not None:
            step += 1
        if outcome.event is not None:
            try:
                if on_event is not None:
                    dual = on_event(eng, outcome.event, dual)
                else:
                    dual = eng.on_detection(outcome.event, dual)
            except SedarSafeStop:
                stopped = True
                break
            step = int(np.asarray(eng.executor.peek(dual, "step")))
    store = getattr(eng.recovery, "store", None)
    if store is not None:
        store.wait()
    return dual, stopped


SPEC = InjectionSpec(leaf_idx=0, flat_idx=5, bit=20, step=4, replica=1,
                     target="grads")


# -- rings --------------------------------------------------------------------

def test_device_ring_roundtrip_no_syncs_no_disk():
    """Tier 0: save and restore are pure device-side copies."""
    from repro.checkpoint import DeviceRing
    ring = DeviceRing(slots=3)
    states = {s: _state(s) for s in (1, 2, 3)}
    with hostsync.count_transfers() as ht, count_disk_reads() as dr:
        for s, st in states.items():
            ring.save(s, st)
        r = ring.restore(2)
    assert ht.transfers == 0 and dr.reads == 0
    np.testing.assert_array_equal(np.asarray(r["x"]),
                                  np.asarray(states[2]["x"]))


def test_device_ring_restore_returns_independent_copies():
    """The ring must survive its restored state being donated/mutated: the
    returned pytree is a COPY, not an alias of the slot."""
    from repro.checkpoint import DeviceRing
    ring = DeviceRing(slots=2)
    st = _state(7)
    ring.save(1, st)
    r1 = ring.restore(1)
    jax.block_until_ready(r1["x"])
    r1["x"].delete()                       # simulate donation of the restore
    r2 = ring.restore(1)                   # the slot is still intact
    np.testing.assert_array_equal(np.asarray(r2["x"]), np.asarray(st["x"]))


def test_ring_eviction_keeps_floor_anchor():
    """Ring eviction mirrors gc_keep_last's keep_floor rule: the newest
    slot at-or-below the validation frontier is pinned."""
    from repro.checkpoint import DeviceRing
    ring = DeviceRing(slots=2)
    for s in (3, 6, 9, 12):
        ring.save(s, _state(s), keep_floor=5)
    # keep-last-2 alone would hold {9, 12}; the anchor pins 3
    assert ring.versions() == [3, 9, 12][-ring.slots:] or \
        ring.versions() == [3, 12]
    assert 3 in ring.versions()


def test_host_ring_one_batch_per_save_zero_disk():
    from repro.checkpoint import HostRing
    ring = HostRing(slots=2)
    st = _state(5)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    with count_disk_reads() as dr:
        host = hostsync.batched_get(leaves, label="tier_host_save")
        ring.save(3, host, treedef)
        r = ring.restore(3, st)
    assert dr.reads == 0
    np.testing.assert_array_equal(np.asarray(r["x"]), np.asarray(st["x"]))


# -- schedule / facade --------------------------------------------------------

def test_parse_tiers_validates_names():
    assert parse_tiers("device, host ,disk") == ("device", "host", "disk")
    with pytest.raises(ValueError, match="unknown checkpoint tier"):
        parse_tiers("device,ssd")


def test_make_tiered_flat_disk_is_none(tmp_path):
    sedar = SedarConfig(level=2, ckpt_tiers="disk")
    assert make_tiered(sedar, str(tmp_path),
                       disk_store=CheckpointStore(str(tmp_path))) is None


def test_save_routes_by_cadence_one_shared_transfer(tmp_path):
    """host+disk due on the same step share ONE batched D2H transfer."""
    sched = TierSchedule(device=1, host=4, disk=4)
    tc = TieredCheckpointer(sched, disk_store=CheckpointStore(str(tmp_path)))
    st = _state(1)
    with hostsync.count_transfers() as ht:
        assert tc.save(1, st, async_=False) == ["device"]
    assert ht.transfers == 0                 # device-only step: no D2H
    with hostsync.count_transfers() as ht:
        assert tc.save(4, st, async_=False) == ["device", "host", "disk"]
    assert ht.batches == 1                   # one transfer feeds both tiers
    assert tc.saves_by_tier == {"device": 2, "host": 1, "disk": 1}


def test_planner_prefers_cheapest_tier_then_rework():
    """Same version in several tiers -> cheapest tier; planner trades tier
    cost against rework distance for max_step queries."""
    sched = TierSchedule(device=1, host=1)
    tc = TieredCheckpointer(sched, device_slots=4, host_slots=4)
    st = _state(0)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    host = [np.asarray(l) for l in leaves]
    for v in (1, 2, 3):
        tc.device.save(v, st)
        tc.host.save(v, host, treedef)
    assert tc.plan(version=3)[0] == ("device", 3)
    # device ring missing the old version: host serves it
    tc.device.keep_only(3)
    assert tc.plan(version=2)[0] == ("host", 2)
    # max_step query ranks newest-cheapest first
    assert tc.plan(max_step=3)[0] == ("device", 3)


def test_planner_rework_outweighs_tier_cost_at_distance(tmp_path):
    """A device slot far behind the bound loses to a closer disk version:
    the planner is cost-aware, not blindly tier-ordered."""
    sched = TierSchedule(device=1, disk=1)
    tc = TieredCheckpointer(sched, device_slots=2,
                            disk_store=CheckpointStore(str(tmp_path)),
                            rework_weight=1.0)
    st = _state(0)
    tc.device.save(2, st)
    tc.disk.save(100, st, async_=False)
    # cost(device@2) = 1 + 98; cost(disk@100) = 64 + 0 -> disk wins
    assert tc.plan(max_step=100)[0] == ("disk", 100)


# -- acceptance: zero-disk-read ring recovery under L2 ------------------------

@pytest.mark.parametrize("backend", ["sequential", "fused"])
def test_l2_fault_recovers_from_device_ring_zero_disk_reads(tmp_workdir,
                                                            backend):
    """ISSUE-4 acceptance: fault at step k, L2, a device ring slot <= k
    exists -> recovery restores from Tier 0 with zero disk reads and zero
    host syncs during the restore itself."""
    eng = _toy_engine(tmp_workdir, 2, spec=SPEC, backend=backend)
    counted = {}

    def on_event(eng_, event, dual):
        with count_disk_reads() as dr, hostsync.count_transfers() as ht:
            dual = eng_.on_detection(event, dual)
        counted["disk_reads"] = dr.reads
        counted["transfers"] = ht.transfers
        return dual

    dual, stopped = _drive(eng, 10, on_event=on_event)
    assert not stopped
    assert counted == {"disk_reads": 0, "transfers": 0}
    rec = eng.recoveries[0]
    assert rec["tier"] == "device" and rec["step"] <= SPEC.step
    # the replayed trajectory matches a fault-free flat-disk run bitwise
    ref = _toy_engine(tmp_workdir + "_ref", 2, backend=backend,
                      tiers="disk")
    dual_ref, _ = _drive(ref, 10)
    np.testing.assert_array_equal(
        np.asarray(eng.executor.peek(dual, "x")),
        np.asarray(ref.executor.peek(dual_ref, "x")))


def test_l2_deferred_window_fault_restores_from_ring(tmp_workdir):
    """Deferred lag D: the ring holds optimistic (unvalidated) slots; the
    planner's max_step bound excludes post-fault slots, recovery still
    lands on a pre-fault version from Tier 0 with zero disk reads."""
    eng = _toy_engine(tmp_workdir, 2, spec=SPEC, backend="fused", lag=4)
    counted = {}

    def on_event(eng_, event, dual):
        with count_disk_reads() as dr:
            dual = eng_.on_detection(event, dual)
        counted["disk_reads"] = dr.reads
        return dual

    dual, stopped = _drive(eng, 12, on_event=on_event)
    assert not stopped
    assert counted["disk_reads"] == 0
    ev = eng.detections[0]
    assert ev.boundary == "deferred" and ev.step == SPEC.step
    rec = eng.recoveries[0]
    assert rec["tier"] == "device" and rec["step"] <= SPEC.step
    ref = _toy_engine(tmp_workdir + "_ref", 2, backend="fused", lag=1,
                      tiers="disk")
    dual_ref, _ = _drive(ref, 12)
    np.testing.assert_array_equal(
        np.asarray(eng.executor.peek(dual, "x")),
        np.asarray(ref.executor.peek(dual_ref, "x")))


def test_l2_ring_too_short_falls_to_disk(tmp_workdir):
    """With a 1-slot ring at a cadence that leaves no slot <= k, the
    planner falls through to the disk tier (and recovery still succeeds)."""
    eng = _toy_engine(tmp_workdir, 2, spec=SPEC, backend="sequential",
                      slots=1, tiers="device,disk")
    # rotate the 1-slot ring past the fault: by detection at step 4 the
    # only device slot is version 4 == event step -> allowed (<= k). Use a
    # later injection point vs checkpoint instead:
    dual, stopped = _drive(eng, 10)
    assert not stopped
    assert eng.recoveries[0]["tier"] in ("device", "disk")
    assert eng.recoveries[0]["step"] <= SPEC.step


def test_l2_multi_rollback_walks_union_newest_first(tmp_workdir):
    """Algorithm 1 over the hierarchy: repeated detections walk the UNION
    of tier versions (<= the faulty step) one version back at a time."""
    eng = _toy_engine(tmp_workdir, 2, spec=None, backend="sequential",
                      tiers="device,host,disk", ckpt_interval=3, slots=4)
    dual, _ = _drive(eng, 8)
    from repro.core.detection import DetectionEvent
    # versions now: device ring {5,6,7,8}, host {3,6}, disk {3,6}
    ev = DetectionEvent(step=7, boundary="validate", effect="FSC")
    d1 = eng.on_detection(ev, dual)
    assert eng.recoveries[-1]["step"] == 7      # newest <= 7 (ring)
    d2 = eng.on_detection(ev, d1)
    assert eng.recoveries[-1]["step"] == 6      # one further back
    assert eng.recoveries[-1]["tier"] == "device"
    d3 = eng.on_detection(ev, d2)
    assert eng.recoveries[-1]["step"] == 5
    del d3


# -- corruption fallback ------------------------------------------------------

def _flip_leaf_byte(store_dir, step, leaf=0):
    path = os.path.join(store_dir, f"ckpt_{step:08d}",
                        f"leaf_{leaf:05d}.npy")
    arr = np.load(path)
    flat = arr.reshape(-1).view(np.uint8)
    flat[3] ^= 0x10
    np.save(path, arr)


def test_corrupt_disk_falls_back_to_partner_then_host(tmp_path):
    """Satellite: flip bytes in a Tier-2 leaf -> the planner restores from
    Tier 3; corrupt Tier 3 too -> Tier 1 serves an older version. Each
    fallback is a recorded event, not an exception."""
    sched = TierSchedule(device=0, host=2, disk=4, partner=4)
    events = []
    tc = TieredCheckpointer(
        sched, host_slots=2,
        disk_store=CheckpointStore(str(tmp_path / "disk")),
        partner_store=CheckpointStore(str(tmp_path / "partner")),
        notify=events.append)
    states = {s: _state(s) for s in (2, 4)}
    tc.save(2, states[2], async_=False)       # host only
    tc.save(4, states[4], async_=False)       # host+disk+partner
    # host ring slot 4 would serve version 4 first; keep only version 2
    # there so the disk tier is the cheapest holder of version 4
    tc.host.keep_only(2)
    assert tc.host.versions() == [2]

    _flip_leaf_byte(str(tmp_path / "disk"), 4)
    tpl = jax.tree.map(np.asarray, states[4])
    state, info = tc.restore(4, tpl)
    assert info["tier"] == "partner" and info["version"] == 4
    assert [f["tier"] for f in info["fallbacks"]] == ["disk"]
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.asarray(states[4]["x"]))

    _flip_leaf_byte(str(tmp_path / "partner"), 4)
    state, info = tc.restore(4, tpl)
    assert info["tier"] == "host" and info["version"] == 2
    assert [f["tier"] for f in info["fallbacks"]] == ["disk", "partner"]
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.asarray(states[2]["x"]))
    assert len(events) == 3 and all(e["kind"] == "tier_fallback"
                                    for e in events)


def test_engine_records_fallback_event_on_corrupt_tier2(tmp_workdir):
    """End-to-end: L2 engine recovery survives a corrupted primary store
    and the recovery record names the serving tier + the fallback."""
    eng = _toy_engine(tmp_workdir, 2, spec=SPEC, backend="sequential",
                      tiers="host,disk,partner", ckpt_interval=3, slots=1)
    # corrupt the primary store's version 3 leaf as soon as it lands
    from repro.core.detection import SedarSafeStop  # noqa: F401

    def on_event(eng_, event, dual):
        disk_dir = eng_.recovery.store.dir
        eng_.recovery.store.wait()
        _flip_leaf_byte(disk_dir, 3)
        return eng_.on_detection(event, dual)

    dual, stopped = _drive(eng, 10, on_event=on_event)
    assert not stopped
    rec = eng.recoveries[0]
    # host ring (slot=1) holds version 3 as well; disk is ranked after the
    # ring, so the ring serves it — force the interesting path by checking
    # either: served by a non-corrupt tier with or without fallbacks
    assert rec["step"] <= SPEC.step
    assert rec["tier"] in ("host", "partner")
    x_final = np.asarray(eng.executor.peek(dual, "x"))
    ref = _toy_engine(tmp_workdir + "_ref", 2, backend="sequential",
                      tiers="disk")
    dual_ref, _ = _drive(ref, 10)
    np.testing.assert_array_equal(
        x_final, np.asarray(ref.executor.peek(dual_ref, "x")))


# -- delta checkpoints --------------------------------------------------------

def test_delta_refs_and_transitive_resolution(tmp_path):
    ds = DeltaCheckpointStore(str(tmp_path))
    base = {"a": jnp.arange(64.0), "b": jnp.ones((32,)),
            "c": jnp.zeros((16,))}
    ds.save(1, base)
    v2 = dict(base, a=base["a"] + 1)          # b, c unchanged
    ds.save(2, v2)
    v3 = dict(v2, c=v2["c"] + 5)              # a, b unchanged vs v2
    ds.save(3, v3)
    m2, m3 = ds.manifest(2), ds.manifest(3)
    assert m2.leaf_refs == {"1": 1, "2": 1}   # b,c -> v1
    # transitive: v3's b resolves to the ROOT holder v1, a to v2
    assert m3.leaf_refs == {"0": 2, "1": 1}
    r = ds.restore(3, jax.tree.map(np.asarray, v3))
    for k in v3:
        np.testing.assert_array_equal(r[k], np.asarray(v3[k]))


def test_delta_shrinks_bytes_3x_on_paper_testapp(tmp_path):
    """ISSUE-4 acceptance: < 1/3 of leaves changed per interval => delta
    version writes >= 3x fewer bytes than the full checkpoint."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model
    cfg = reduce_for_smoke(get_config("paper-testapp"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    ds = DeltaCheckpointStore(str(tmp_path))
    ds.save(1, params)
    full_bytes = ds.manifest(1).bytes_on_disk
    # mutate < 1/3 of the leaves
    n_change = max(len(leaves) // 4, 1)
    changed = [l + 1.0 if i < n_change else l
               for i, l in enumerate(leaves)]
    v2 = jax.tree_util.tree_unflatten(treedef, changed)
    ds.save(2, v2)
    delta_bytes = ds.manifest(2).bytes_on_disk
    assert delta_bytes * 3 <= full_bytes, (delta_bytes, full_bytes)
    r = ds.restore(2, jax.tree.map(np.asarray, v2))
    for a, b in zip(jax.tree_util.tree_flatten(r)[0],
                    jax.tree_util.tree_flatten(v2)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_delta_base_overwritten_raises_corruption(tmp_path):
    """A base overwritten with DIFFERENT bytes after a delta referenced it
    must fail the delta's digest check, not silently stitch stale data."""
    ds = DeltaCheckpointStore(str(tmp_path))
    ds.save(1, {"a": jnp.arange(8.0), "b": jnp.ones((4,))})
    ds.save(2, {"a": jnp.arange(8.0) + 1, "b": jnp.ones((4,))})   # b -> ref 1
    # divergent replay overwrites version 1 with different content
    ds._last = None
    store2 = DeltaCheckpointStore(str(tmp_path))
    store2.save(1, {"a": jnp.zeros(8), "b": jnp.full((4,), 9.0)})
    with pytest.raises(CheckpointCorruptionError):
        store2.restore(2, {"a": np.zeros(8, np.float32),
                           "b": np.zeros(4, np.float32)})


def test_delta_gc_retains_referenced_bases(tmp_path):
    ds = DeltaCheckpointStore(str(tmp_path))
    base = {"a": jnp.arange(8.0), "b": jnp.ones((4,))}
    ds.save(1, base)
    for s in (2, 3, 4):
        base = dict(base, a=base["a"] + 1)    # b always refs v1
        ds.save(s, base)
    ds.gc_keep_last(2)
    # keep {3,4} plus their base v1
    assert ds.steps() == [1, 3, 4]
    r = ds.restore(4, jax.tree.map(np.asarray, base))
    np.testing.assert_array_equal(r["b"], np.ones(4, np.float32))
    ds.delete_others_than(4)
    assert ds.steps() == [1, 4]


def test_delta_rollback_replay_rebases_below_target(tmp_path):
    """After a rollback, the re-cut version deltas against the newest
    version BELOW it (not the stale cache of the pre-rollback save)."""
    ds = DeltaCheckpointStore(str(tmp_path))
    v = {"a": jnp.arange(8.0), "b": jnp.ones((4,))}
    ds.save(2, v)
    ds.save(4, dict(v, a=v["a"] + 1))
    ds.save(6, dict(v, a=v["a"] + 2))
    # rollback to 2; replay re-cuts version 4 (same logical content)
    ds.save(4, dict(v, a=v["a"] + 1))
    m4 = ds.manifest(4)
    assert m4.leaf_refs == {"1": 2}           # rebased on v2, not v6
    r = ds.restore(4, jax.tree.map(np.asarray, v))
    np.testing.assert_array_equal(r["a"], np.asarray(v["a"] + 1))


# -- L3: exactly one valid per tier ------------------------------------------

def test_l3_keeps_exactly_one_valid_per_tier(tmp_workdir):
    eng = _toy_engine(tmp_workdir, 3, spec=SPEC, backend="sequential",
                      tiers="device,host,disk,partner", ckpt_interval=3)
    dual, stopped = _drive(eng, 10)
    assert not stopped
    tiers = eng.recovery.tiers
    assert tiers.device.versions() == [9]
    assert tiers.host.versions() == [9]
    assert tiers.disk.steps() == [9]
    assert tiers.partner.steps() == [9]
    assert tiers.disk.manifest(9).valid is True
    assert tiers.partner.manifest(9).valid is True
    # restore after the injected fault came from the cheapest tier
    assert eng.recoveries[0]["tier"] == "device"
    ref = _toy_engine(tmp_workdir + "_ref", 3, backend="sequential",
                      tiers="disk", ckpt_interval=3)
    dual_ref, _ = _drive(ref, 10)
    np.testing.assert_array_equal(
        np.asarray(eng.executor.peek(dual, "x")),
        np.asarray(ref.executor.peek(dual_ref, "x")))


# -- zero-sync interaction ----------------------------------------------------

def test_device_tier_saves_do_not_break_zero_sync(tmp_workdir):
    """Tiered L2 with a per-step device cadence keeps the §11 property: a
    fault-free deferred step performs ZERO host transfers and ZERO disk
    reads — the ring snapshot is a pure device-side copy."""
    eng = _toy_engine(tmp_workdir, 2, backend="fused", lag=8,
                      ckpt_interval=100, tiers="device,disk")
    dual = eng.init_dual()
    eng.reset()
    out = eng.run_protected_step(dual, jnp.ones((16,), jnp.float32), 0)
    dual = eng.init_dual()
    eng.reset()
    with hostsync.count_transfers() as ht, count_disk_reads() as dr:
        for s in range(7):
            out = eng.run_protected_step(
                dual, jnp.full((16,), float(s + 1), jnp.float32), s)
            dual = out.dual
            assert out.event is None
    assert ht.transfers == 0, ht.by_label
    assert dr.reads == 0
    assert eng.recovery.tiers.device.versions() != []


# -- review-found regressions -------------------------------------------------

def test_delta_cache_invalidated_on_delete(tmp_path):
    """Deleting the newest version must not leave the next save's delta
    refs pointing at the vanished directory (stale _last cache)."""
    ds = DeltaCheckpointStore(str(tmp_path))
    v = {"a": jnp.arange(8.0), "b": jnp.ones((4,))}
    ds.save(4, v)
    ds.delete(4)
    v6 = dict(v, a=v["a"] + 1)                # b unchanged vs deleted v4
    ds.save(6, v6)
    m6 = ds.manifest(6)
    # no refs into the deleted version: v6 must be self-contained (or ref
    # an on-disk base only)
    for ref in (m6.leaf_refs or {}).values():
        assert ref in ds.steps()
    r = ds.restore(6, jax.tree.map(np.asarray, v6))
    np.testing.assert_array_equal(r["b"], np.ones(4, np.float32))


def test_delta_cache_invalidated_on_clear(tmp_path):
    ds = DeltaCheckpointStore(str(tmp_path))
    v = {"a": jnp.arange(8.0)}
    ds.save(2, v)
    ds.clear()
    ds.save(3, v)                             # same content as cleared v2
    assert ds.manifest(3).leaf_refs is None   # full write, no dangling ref
    r = ds.restore(3, jax.tree.map(np.asarray, v))
    np.testing.assert_array_equal(r["a"], np.asarray(v["a"]))


def test_bounded_chain_gc_only_runs_on_durable_saves(tmp_workdir,
                                                     monkeypatch):
    """max_checkpoints GC scans steps() (a wait barrier): it must fire only
    when a durable tier saved, never on device-ring-only steps."""
    eng = _toy_engine(tmp_workdir, 2, backend="sequential",
                      tiers="device,disk", ckpt_interval=3,
                      max_checkpoints=2)
    tiers = eng.recovery.tiers
    calls = []
    orig = tiers.disk.gc_keep_last
    monkeypatch.setattr(tiers.disk, "gc_keep_last",
                        lambda *a, **k: (calls.append(1), orig(*a, **k)))
    dual, stopped = _drive(eng, 8)
    assert not stopped
    # disk saves at 3 and 6 -> exactly two GC passes, not one per step
    assert len(calls) == 2
    assert tiers.disk.steps() == [3, 6]


def test_slot_ring_save_many_and_newest_version():
    """SlotRing drain-edge contract (DESIGN.md §18): save_many records a
    shared version for every slice, newest_version reads the newest
    fully-validated point without paying restore()'s copy, and eviction
    drops a slot's history completely."""
    from repro.checkpoint.tiers import SlotRing
    ring = SlotRing(slots_per_key=2)
    assert ring.newest_version(0) is None
    ring.save_many(4, {0: {"pos": jnp.asarray(4)},
                       1: {"pos": jnp.asarray(4)}})
    ring.save_many(8, {0: {"pos": jnp.asarray(8)}})
    assert ring.newest_version(0) == 8 and ring.newest_version(1) == 4
    assert ring.saves == 3
    v, sl = ring.restore(0)
    assert v == 8 and int(sl["pos"]) == 8
    # bounded ring: a third version for slot 0 evicts its oldest
    ring.save_many(12, {0: {"pos": jnp.asarray(12)}})
    assert ring.versions(0) == [8, 12]
    ring.evict(0)
    assert ring.newest_version(0) is None and ring.newest_version(1) == 4
