"""Multi-device tests (sharding resolver, pod-backend SEDAR, dry-run smoke).

These need >1 device, so each test runs a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count set BEFORE jax import
(the main pytest process must keep seeing 1 device for the smoke tests)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_resolver_sharding_and_fallbacks():
    out = _run("""
import jax
from repro.launch.mesh import make_test_mesh
from repro.sharding import Resolver, ShardingRules
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
r = Resolver(mesh, ShardingRules(data_axes=("data",)))
# heads divisible -> model axis on heads
s = r.spec(("embed", "heads", "head_dim"), (8, 4, 16), "wq")
assert "model" in str(s) and "data" in str(s), s
# heads NOT divisible -> falls through to head_dim
s2 = r.spec(("embed", "heads", "head_dim"), (8, 3, 16), "wq_bad")
assert s2[1] is None and any(f.logical == "heads" for f in r.fallbacks), s2
# batch_dm grabs data*model together when divisible
s3 = r.spec(("batch_dm", None, None), (4, 5, 7), "act")
assert s3[0] == ("data", "model"), s3
# batch_dm falls back to plain data when not divisible by data*model
s4 = r.spec(("batch_dm", None, None), (2, 5, 7), "act2")
assert s4[0] == "data", s4
print("resolver OK")
""")
    assert "resolver OK" in out


def test_pod_backend_sedar_detection():
    """Replicas on the pod axis: injected fault detected via the shard_map
    fingerprint exchange; commit gated; recovery completes."""
    out = _run("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import (RunConfig, SedarConfig, TrainConfig, get_config,
                           reduce_for_smoke)
from repro.core.injection import InjectionSpec
from repro.launch.mesh import make_test_mesh
from repro.runtime.train import SedarTrainer
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduce_for_smoke(get_config("paper-testapp"))
rc = RunConfig(model=cfg,
               train=TrainConfig(global_batch=4, seq_len=16, steps=8,
                                 warmup_steps=2, lr=1e-3),
               sedar=SedarConfig(level=3, replication="pod",
                                 validate_interval=1,
                                 param_validate_interval=4,
                                 checkpoint_interval=4))
spec = InjectionSpec(leaf_idx=3, flat_idx=5, bit=20, step=5, replica=1,
                     target="grads")
import shutil; shutil.rmtree("/tmp/sedar_pod_test", ignore_errors=True)
with mesh:
    tr = SedarTrainer(rc, "/tmp/sedar_pod_test", mesh=mesh, inj_spec=spec)
    dual, rep = tr.run(8)
assert len(rep.detections) == 1 and rep.detections[0].step == 5, rep.detections
assert rep.recoveries[0]["kind"] == "restore"
assert rep.steps_completed == 8
# per-shard lane localization (DESIGN.md 16): the event names the lane the
# corrupted element hashes into, and the host owning that data shard
from repro.core.fingerprint import lane_of_leaf_index
grads_tree = jax.tree.map(np.asarray, tr.init_state()["params"])
lane = lane_of_leaf_index(grads_tree, 3, 5, 2)
assert rep.detections[0].detail.get("lanes") == [lane], rep.detections[0].detail
assert rep.detections[0].detail.get("hosts") == [lane], rep.detections[0].detail
print("pod backend OK", rep.summary())
""", devices=8, timeout=600)
    assert "pod backend OK" in out


def test_pod_backend_zero_sync_fault_free():
    """DESIGN.md 11 extended to spatial replication: the cross-replica
    compare happens via collectives INSIDE the jitted step, so a fault-free
    deferred-window run never reads the commit predicate back per step."""
    out = _run("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import (RunConfig, SedarConfig, TrainConfig, get_config,
                           reduce_for_smoke)
from repro.core import hostsync
from repro.launch.mesh import make_test_mesh
from repro.runtime.train import SedarTrainer
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduce_for_smoke(get_config("paper-testapp"))
rc = RunConfig(model=cfg,
               train=TrainConfig(global_batch=4, seq_len=16, steps=16,
                                 warmup_steps=2, lr=1e-3),
               sedar=SedarConfig(level=3, replication="pod",
                                 validate_interval=1, validate_lag=4,
                                 param_validate_interval=100,
                                 checkpoint_interval=8,
                                 ckpt_tiers="device,partner"))
import shutil; shutil.rmtree("/tmp/sedar_pod_zs", ignore_errors=True)
with mesh:
    tr = SedarTrainer(rc, "/tmp/sedar_pod_zs", mesh=mesh)
    with hostsync.count_transfers() as st:
        dual, rep = tr.run(16)
assert not rep.detections
assert rep.steps_completed == 16
assert "commit_compare" not in st.by_label, st.by_label
assert st.by_label.get("deferred_flush", 0) <= 16 // 4 + 2, st.by_label
print("zero-sync pod OK", rep.summary())
""", devices=8, timeout=600)
    assert "zero-sync pod OK" in out


def test_pod_elastic_fail_in_place_acceptance():
    """The issue's acceptance scenario: 8-device replicated mesh, host loss
    mid-run -> automatic shrink with the anchor restored from the Tier-3
    partner store onto the survivors, regrow when the host returns, final
    state bitwise identical to an uninterrupted run — and zero fault-free
    commit-predicate readbacks throughout."""
    out = _run("""
import json, os, shutil
import jax, numpy as np
from repro.configs import (MeshConfig, RunConfig, SedarConfig, TrainConfig,
                           get_config, reduce_for_smoke)
from repro.core import hostsync
from repro.launch.mesh import make_test_mesh
from repro.runtime.elastic import ElasticTrainer
from repro.runtime.train import SedarTrainer

mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduce_for_smoke(get_config("paper-testapp"))
rc = RunConfig(model=cfg,
               train=TrainConfig(global_batch=4, seq_len=16, steps=12,
                                 warmup_steps=2, lr=1e-3),
               mesh=MeshConfig(shape=(2, 2, 2),
                               axis_names=("pod", "data", "model")),
               sedar=SedarConfig(level=3, replication="pod",
                                 validate_interval=1, validate_lag=4,
                                 param_validate_interval=100,
                                 checkpoint_interval=4,
                                 ckpt_tiers="device,partner"))
base = "/tmp/sedar_pod_elastic"
shutil.rmtree(base, ignore_errors=True)

with mesh:
    ref = SedarTrainer(rc, base + "/ref", mesh=mesh)
    _, ref_rep = ref.run(12)
assert not ref_rep.detections

wd = base + "/run"
hb = os.path.join(wd, "heartbeats")
sim = {"now": 0.0}

def tick(step):
    sim["now"] += 100.0
    os.makedirs(hb, exist_ok=True)
    for h in range(2):
        if h == 1 and 250.0 <= sim["now"] < 550.0:   # host 1 dark mid-run
            continue
        with open(os.path.join(hb, f"host_{h:05d}.json"), "w") as f:
            json.dump({"host": h, "step": int(step or 0), "t": sim["now"]}, f)

et = ElasticTrainer(rc, wd, mesh=mesh, n_hosts=2, scan_interval=2,
                    clock=lambda: sim["now"], tick=tick)
with hostsync.count_transfers() as st:
    rep = et.run(12)
phases = [r.phase for r in rep.remeshes]
assert phases == ["shrink", "regrow"], phases
assert rep.remeshes[0].restore_tier == "partner", rep.remeshes[0]
assert rep.remeshes[0].new_data == 1 and rep.remeshes[0].new_batch == 2
assert rep.steps_completed == 12 and not rep.stopped
assert np.array_equal(np.asarray(rep.final_state_fp)[:, :2],
                      np.asarray(ref_rep.final_state_fp)[:, :2])
assert "commit_compare" not in st.by_label, st.by_label
print("pod elastic OK", rep.summary())
""", devices=8, timeout=600)
    assert "pod elastic OK" in out


def test_dryrun_cell_small_arch():
    """Full dry-run machinery on the production 512-device mesh for the
    smallest assigned arch (lower+compile+memory+cost+collectives)."""
    out = _run("""
import repro.launch.dryrun as dr
cell = dr.run_cell("xlstm-125m", "decode_32k", "single", "baseline",
                   "/tmp/dryrun_test", with_probes=False)
assert cell["status"] == "ok", cell.get("error")
assert cell["memory"]["fits_16GiB"]
assert cell["roofline"]["dominant"] in ("compute", "memory", "collective")
print("dryrun OK", cell["roofline"]["dominant"])
""", devices=512, timeout=600)
    assert "dryrun OK" in out


def test_vote_mode_forward_correction():
    """Beyond-paper NMR: 3 replicas, state corrupted on one pod, majority
    vote repairs it forward (no rollback) and training completes."""
    out = _run("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import (RunConfig, SedarConfig, TrainConfig, get_config,
                           reduce_for_smoke)
from repro.core.injection import InjectionSpec
from repro.launch.mesh import make_test_mesh
from repro.runtime.train import SedarTrainer
mesh = make_test_mesh((3, 2, 1), ("pod", "data", "model"))
cfg = reduce_for_smoke(get_config("paper-testapp"))
rc = RunConfig(model=cfg,
               train=TrainConfig(global_batch=4, seq_len=16, steps=8,
                                 warmup_steps=2, lr=1e-3),
               sedar=SedarConfig(level=3, replication="vote",
                                 validate_interval=1,
                                 param_validate_interval=2,
                                 checkpoint_interval=100))
spec = InjectionSpec(leaf_idx=2, flat_idx=3, bit=30, step=3, replica=1,
                     target="params")
import shutil; shutil.rmtree("/tmp/sedar_vote_test", ignore_errors=True)
with mesh:
    tr = SedarTrainer(rc, "/tmp/sedar_vote_test", mesh=mesh, inj_spec=spec)
    dual, rep = tr.run(8)
assert any(r["kind"] == "vote_repair" for r in rep.recoveries), rep.recoveries
assert all(r["rollbacks"] == 0 for r in rep.recoveries)
assert rep.steps_completed == 8
print("vote OK", rep.summary())
""", devices=6, timeout=600)
    assert "vote OK" in out


def test_loopaware_collective_parser():
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.dryrun import (parse_collective_bytes,
                                 parse_collective_bytes_loopaware)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"), devices=jax.devices())
def step(w, x):
    def body(c, wl):
        h = jnp.einsum('bd,de->be', c, wl)
        return jax.lax.with_sharding_constraint(
            h, NamedSharding(mesh, P("data", None))), None
    out, _ = jax.lax.scan(body, x, w)
    return jnp.mean(out ** 2)
with mesh:
    comp = jax.jit(step, in_shardings=(
        NamedSharding(mesh, P(None, "model", None)),
        NamedSharding(mesh, P("data", None)))).lower(
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()
naive = parse_collective_bytes(comp.as_text())["total_bytes"]
loop = parse_collective_bytes_loopaware(comp.as_text())["total_bytes"]
# the in-loop all-reduce must be counted ~5x (trip count), not once
assert loop > 3 * naive, (naive, loop)
print("parser OK", naive, loop)
""", devices=8)
    assert "parser OK" in out
