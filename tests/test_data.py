"""Data pipeline: determinism and restart-replay (SEDAR's input contract)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import MemmapCorpus, SyntheticLM


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 10_000))
def test_synthetic_deterministic(seed, step):
    a = SyntheticLM(vocab_size=97, global_batch=3, seq_len=8, seed=seed)
    b = SyntheticLM(vocab_size=97, global_batch=3, seq_len=8, seed=seed)
    ba, bb = a.batch(step), b.batch(step)
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    np.testing.assert_array_equal(ba["targets"], bb["targets"])


def test_batches_differ_across_steps():
    d = SyntheticLM(vocab_size=997, global_batch=2, seq_len=32, seed=0)
    assert not np.array_equal(d.batch(3)["tokens"], d.batch(4)["tokens"])


def test_targets_are_shifted_tokens():
    d = SyntheticLM(vocab_size=97, global_batch=2, seq_len=8, seed=1)
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_restart_replay():
    """A rollback to step s replays exactly the failed execution's batches."""
    d = SyntheticLM(vocab_size=97, global_batch=2, seq_len=8, seed=0)
    trajectory1 = [d.batch(s)["tokens"] for s in range(6)]
    # "restart" from step 3 with a new pipeline instance
    d2 = SyntheticLM(vocab_size=97, global_batch=2, seq_len=8, seed=0)
    trajectory2 = [d2.batch(s)["tokens"] for s in range(3, 6)]
    for a, b in zip(trajectory1[3:], trajectory2):
        np.testing.assert_array_equal(a, b)


def test_tokens_within_vocab():
    d = SyntheticLM(vocab_size=53, global_batch=4, seq_len=16, seed=2)
    b = d.batch(7)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 53


def test_frontend_embeds():
    d = SyntheticLM(vocab_size=53, global_batch=2, seq_len=8, seed=0,
                    frontend_seq=6, frontend_dim=16)
    b = d.batch(0)
    assert b["frontend_embeds"].shape == (2, 6, 16)
    assert np.isfinite(b["frontend_embeds"]).all()


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    np.arange(10_000, dtype=np.uint16).tofile(path)
    d = MemmapCorpus(path, vocab_size=70_000, global_batch=3, seq_len=16,
                     seed=0)
    b1, b2 = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (3, 16)
    # windows are contiguous slices of the corpus
    row = b1["tokens"][0]
    assert (np.diff(row) == 1).all()
