"""Lag-aligned token emission (DESIGN.md §18): TokenRing unit semantics,
rollback retraction, the detokenize consumer, and the serving-level oracle
— delivered streams bitwise identical to lag=1 under injected faults, with
un-drained tokens retracted by construction (never delivered then undone).
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, TrainConfig, get_config, \
    reduce_for_smoke
from repro.core import hostsync
from repro.core.injection import InjectionSpec
from repro.runtime.emission import DetokenizeConsumer, DrainBatch, \
    TokenRing, deliver_batch
from repro.runtime.scheduler import Request, synthetic_requests
from repro.runtime.serve import SedarServer

SLOTS = 3
FAULT_SLOT = 1


def _req(rid=0, pos0=4, prefill_tok=11):
    r = Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                max_new_tokens=8)
    r.pos0 = pos0
    r.tokens = [prefill_tok]
    r.token_times = [0.0]
    r.truncated_tokens = 0
    return r


def _park_window(ring, req, toks, start_pos):
    """Park len(toks) single-slot ticks with consecutive positions."""
    ring.owners = {0: req}
    for i, tk in enumerate(toks):
        ring.park(i, (jnp.asarray([[tk]], jnp.int32),
                      jnp.asarray([start_pos + i], jnp.int32)))


# ---------------------------------------------------------------------------
# TokenRing unit semantics
# ---------------------------------------------------------------------------

def test_ring_cadence_gates_provide():
    ring = TokenRing(cadence=3)
    req = _req()
    _park_window(ring, req, [21, 22], start_pos=5)
    assert len(ring) == 2 and ring.parked == 2
    assert ring.provide() is None            # 2 < cadence
    leaves = ring.provide(final=True)        # final forces the drain
    assert leaves is not None and len(leaves) == 2
    assert leaves[0].shape == (2, 1, 1) and leaves[1].shape == (2, 1)
    ring.park(2, (jnp.asarray([[23]], jnp.int32),
                  jnp.asarray([7], jnp.int32)))
    assert ring.provide() is not None        # cadence met

    vals = hostsync.batched_get(ring.provide(), label="test")
    batch = ring.deliver(vals)
    assert len(ring) == 0 and ring.drains == 1
    assert batch.steps == [0, 1, 2]
    assert req.tokens == [11, 21, 22, 23]    # inline sink delivered in order
    assert ring.delivered == 3 and ring.retracted == 0


def test_ring_owner_snapshot_survives_slot_reuse():
    """park() copies the owner map, so re-admitting a new request into the
    slot mid-window cannot reroute already-parked rows."""
    ring = TokenRing(cadence=4)
    old, new = _req(rid=0), _req(rid=1, pos0=10, prefill_tok=50)
    _park_window(ring, old, [21, 22], start_pos=5)
    ring.owners = {0: new}                   # slot re-admitted
    ring.park(2, (jnp.asarray([[61]], jnp.int32),
                  jnp.asarray([11], jnp.int32)))
    ring.park(3, (jnp.asarray([[62]], jnp.int32),
                  jnp.asarray([12], jnp.int32)))
    vals = [np.asarray(x) for x in jax.device_get(ring.provide())]
    ring.deliver(vals)
    assert old.tokens == [11, 21, 22]
    assert new.tokens == [50, 61, 62]


def test_truncate_retracts_at_or_after_first_bad():
    """slot_first_bad dead-marks the faulty slot's rows from its first bad
    step on: earlier rows deliver, later rows count as truncated."""
    ring = TokenRing(cadence=4)
    req = _req()
    _park_window(ring, req, [21, 22, 23, 24], start_pos=5)
    ring.truncate({0: 1})                    # steps 1..3 are bad for slot 0
    vals = [np.asarray(x) for x in jax.device_get(ring.provide())]
    ring.deliver(vals)
    assert req.tokens == [11, 21]            # step-0 row was clean
    assert req.truncated_tokens == 3
    assert ring.delivered == 1 and ring.retracted == 3


def test_truncate_global_bad_and_frozen_dedup():
    """Scalar-predicate fallback (no slot localization) dead-marks whole
    rows; a frozen slot's REPEATED position is retracted once, not per
    occurrence (the virtual-length walk)."""
    ring = TokenRing(cadence=4)
    req = _req()
    ring.owners = {0: req}
    for step, pos in [(0, 5), (1, 6), (2, 6), (3, 6)]:   # frozen at pos 6
        ring.park(step, (jnp.asarray([[30 + step]], jnp.int32),
                         jnp.asarray([pos], jnp.int32)))
    ring.truncate(None, global_bad=1)
    vals = [np.asarray(x) for x in jax.device_get(ring.provide())]
    ring.deliver(vals)
    assert req.tokens == [11, 30]
    assert req.truncated_tokens == 1         # pos 6 counted once


def test_deliver_batch_prefix_guard_is_exactly_once():
    """Delivered-prefix property: a token lands only when its position
    extends the stream by exactly one — duplicate drains and regressed
    positions are no-ops."""
    req = _req()
    toks = np.asarray([[[21]], [[21]], [[22]]], np.int32)   # dup row
    poss = np.asarray([[5], [5], [6]], np.int32)
    batch = DrainBatch(steps=[0, 1, 2], toks=toks, poss=poss,
                       owners=[{0: req}] * 3, dead=[set(), set(), set()],
                       dead_all=[False] * 3)
    d, r = deliver_batch(batch, now=1.0)
    assert (d, r) == (2, 0)
    assert req.tokens == [11, 21, 22]
    assert req.token_times[1:] == [1.0, 1.0]
    # replaying the whole batch delivers nothing new
    assert deliver_batch(batch, now=2.0) == (0, 0)
    assert req.tokens == [11, 21, 22]


def test_on_token_streams_in_order():
    seen = []
    req = _req()
    ring = TokenRing(cadence=2,
                     on_token=lambda r, tok, i: seen.append((r.rid, i, tok)))
    _park_window(ring, req, [21, 22], start_pos=5)
    vals = [np.asarray(x) for x in jax.device_get(ring.provide())]
    ring.deliver(vals)
    assert seen == [(0, 1, 21), (0, 2, 22)]


# ---------------------------------------------------------------------------
# detokenize consumer
# ---------------------------------------------------------------------------

def _batch_for(req, toks, start_pos):
    n = len(toks)
    return DrainBatch(
        steps=list(range(n)),
        toks=np.asarray(toks, np.int32).reshape(n, 1, 1),
        poss=np.asarray([start_pos + i for i in range(n)],
                        np.int32).reshape(n, 1),
        owners=[{0: req}] * n, dead=[set() for _ in range(n)],
        dead_all=[False] * n)


def test_consumer_threaded_delivery_and_quiesce():
    req = _req()
    cons = DetokenizeConsumer(max_queue=4).start()
    cons.submit(_batch_for(req, [21, 22], 5))
    cons.submit(_batch_for(req, [23], 7))
    cons.quiesce()                           # blocks until both are walked
    assert req.tokens == [11, 21, 22, 23]
    assert cons.batches == 2 and cons.delivered == 3
    cons.close()


def test_consumer_inline_fallback_without_start():
    req = _req()
    cons = DetokenizeConsumer()
    cons.submit(_batch_for(req, [21], 5))    # no thread: delivered inline
    assert req.tokens == [11, 21] and cons.batches == 1
    cons.close()                             # no-op, no thread to join


def test_consumer_close_surfaces_worker_error():
    cons = DetokenizeConsumer(max_queue=2).start()
    bad = DrainBatch(steps=[0], toks=np.zeros((1, 1, 1), np.int32),
                     poss=np.zeros((1, 1), np.int32),
                     owners=[{0: object()}],   # no .pos0 -> worker raises
                     dead=[set()], dead_all=[False])
    cons.submit(bad)
    with pytest.raises(AttributeError):
        cons.close()
    assert cons.errors


def test_consumer_backpressure_blocks_submit():
    """A full queue makes submit() wait for the worker — memory stays
    bounded behind a slow client instead of batches piling up."""
    gate = threading.Event()
    req = _req()
    cons = DetokenizeConsumer(
        on_token=lambda *a: gate.wait(timeout=5.0), max_queue=1).start()
    cons.submit(_batch_for(req, [21], 5))    # worker blocks inside on_token
    time.sleep(0.02)
    cons.submit(_batch_for(req, [22], 6))    # fills the queue
    t0 = time.monotonic()
    release = threading.Timer(0.15, gate.set)
    release.start()
    cons.submit(_batch_for(req, [23], 7))    # must WAIT for the worker
    assert time.monotonic() - t0 > 0.05
    cons.quiesce()
    cons.close()
    release.join()
    assert req.tokens == [11, 21, 22, 23]
    assert cons.backlog_peak >= 1


# ---------------------------------------------------------------------------
# serving-level oracle: streams bitwise identical to lag=1 under faults
# ---------------------------------------------------------------------------

def _cfg():
    cfg = reduce_for_smoke(get_config("qwen2-0.5b"))
    return RunConfig(model=cfg, train=TrainConfig(global_batch=2, seq_len=8))


def _requests():
    return synthetic_requests(5, arrival_rate=2.0, prompt_lengths=(4, 8),
                              max_new_choices=(4, 8), seed=1)


def _slot_spec(step, **kw):
    kw.setdefault("target", "slot")
    return InjectionSpec(leaf_idx=FAULT_SLOT, flat_idx=7, bit=30,
                         step=step, replica=1, **kw)


@pytest.fixture(scope="module")
def oracle():
    """Fault-free lag=1 streams: the bitwise ground truth every drain-mode
    campaign must reproduce."""
    rc = _cfg()
    srv = SedarServer(rc, dual=True)
    params = srv.model.init(jax.random.PRNGKey(0))
    reqs, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=1)
    assert not rep.detections
    return rc, params, {r.rid: list(r.tokens) for r in reqs}


def _assert_streams_equal(out, clean_toks):
    for r in out:
        assert list(r.tokens) == clean_toks[r.rid], f"request {r.rid}"


@pytest.mark.parametrize("lag,fault_step", [(4, 5), (8, 3)])
def test_midwindow_fault_retracts_and_matches_lag1(oracle, lag, fault_step):
    """A slot SDC strictly inside the deferred window: the failed flush
    dead-marks the slot's un-drained rows (retraction by construction —
    they were never delivered), the slot rolls back and re-decodes, and
    EVERY stream — affected and unaffected — is bitwise identical to the
    lag=1 run."""
    rc, params, clean_toks = oracle
    srv = SedarServer(rc, dual=True, inj_spec=_slot_spec(fault_step))
    out, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=lag)
    assert len(rep.detections) == 1
    ev = rep.detections[0]
    assert ev.boundary == "deferred" and ev.step == fault_step
    assert ev.detail["slots"] == [FAULT_SLOT]
    assert rep.rollbacks == 1
    assert rep.truncated_tokens > 0          # un-drained rows were retracted
    assert all(r.status == "done" for r in out)
    _assert_streams_equal(out, clean_toks)
    assert sum(1 for r in out if r.truncated_tokens > 0) == 1


@pytest.mark.parametrize("lag", [4, 8])
def test_persistent_stuck_bit_rejects_under_drain(oracle, lag):
    """A stuck bit re-injected every step: the per-request budget exhausts,
    THAT request is rejected after the consumer quiesces (the notify
    callback sees a settled stream), and everyone else's delivered stream
    still equals lag=1."""
    rc, params, clean_toks = oracle
    notified = []
    srv = SedarServer(rc, dual=True, max_retries=3,
                      inj_spec=_slot_spec(3, persistent=True))
    out, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=lag,
                         notify_reject=lambda r, e: notified.append(r.rid))
    rejected = [r for r in out if r.status == "rejected"]
    assert len(rejected) == 1
    assert rep.rejected == [rejected[0].rid] == notified
    assert not rep.stopped
    for r in out:
        if r.status == "done":
            assert list(r.tokens) == clean_toks[r.rid]


def test_fused_backend_drain_equality(oracle):
    rc, params, clean_toks = oracle
    srv = SedarServer(rc, backend="fused", inj_spec=_slot_spec(3))
    out, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=4)
    assert rep.detections and rep.detections[0].boundary == "deferred"
    assert rep.detections[0].detail["slots"] == [FAULT_SLOT]
    assert rep.rollbacks == 1
    _assert_streams_equal(out, clean_toks)


def test_abft_backend_drain_equality(oracle):
    """Replica-free backend under drain: a kernel-domain fault inside the
    checksummed logits block is forward-corrected in place, so the window
    flushes clean and the drained streams equal the dual-replica lag=1
    oracle with zero rollbacks."""
    rc, params, clean_toks = oracle
    V = rc.model.vocab_size
    spec = InjectionSpec(leaf_idx=0, flat_idx=FAULT_SLOT * (V + 1) + 5,
                         bit=30, step=3, replica=0, target="kernel")
    srv = SedarServer(rc, backend="abft", inj_spec=spec)
    out, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=4)
    assert rep.rollbacks == 0
    assert all(r.status == "done" for r in out)
    _assert_streams_equal(out, clean_toks)


def test_delivered_prefix_property_under_fault(oracle):
    """on_token observes the stream AS DELIVERED (from the consumer
    thread): per request, indices are gapless and strictly increasing, and
    the observed sequence IS the final stream — nothing was ever delivered
    and later taken back, even though a mid-window fault forced retraction
    of parked rows."""
    rc, params, clean_toks = oracle
    streamed, first_idx = {}, {}

    def on_token(req, tok, idx):
        seq = streamed.setdefault(req.rid, [])
        if not seq:
            first_idx[req.rid] = idx
        assert idx == first_idx[req.rid] + len(seq), \
            "delivery skipped or repeated a position"
        seq.append(tok)

    srv = SedarServer(rc, dual=True, inj_spec=_slot_spec(3))
    out, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=8,
                         on_token=on_token)
    assert rep.rollbacks == 1
    _assert_streams_equal(out, clean_toks)
    for r in out:
        # index 0 is the prefill token, delivered at admission (not
        # streamed); everything after it streamed gaplessly in order
        seq = streamed.get(r.rid, [])
        if seq:
            assert first_idx[r.rid] == 1
        assert seq == list(r.tokens)[1:]


def test_run_ending_midwindow_releases_exactly_once(oracle):
    """Regression (satellite 6): a drainer whose finishing window is
    drained by the FINAL partial flush must release exactly once — every
    completed rid appears once in rep.completed, no slot is stranded
    DRAINING, and the delivered tokens survive the early exit."""
    rc, params, clean_toks = oracle
    srv = SedarServer(rc, dual=True)
    # cap mid-window: lag=8 but only ~6 decode ticks fit
    out, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=8,
                         max_steps=6)
    assert sorted(rep.completed) == sorted(set(rep.completed))
    assert all(r.status != "draining" for r in out)
    done = [r for r in out if r.status == "done"]
    assert {r.rid for r in done} == set(rep.completed)
    for r in done:
        assert list(r.tokens) == clean_toks[r.rid]
    # partial streams are PREFIXES of the oracle (delivered-prefix holds
    # even for requests the cap cut off)
    for r in out:
        assert list(r.tokens) == clean_toks[r.rid][:len(r.tokens)]


def test_drain_cadence_one_is_bitwise_baseline(oracle):
    """drain_cadence=1 keeps the legacy per-tick readback; its streams are
    bitwise identical to lag-aligned drain at the same lag."""
    rc, params, clean_toks = oracle
    srv = SedarServer(rc, dual=True)
    out, rep = srv.serve(params, _requests(), slots=SLOTS, validate_lag=8,
                         drain_cadence=1)
    _assert_streams_equal(out, clean_toks)
    assert rep.tokens_emitted == sum(len(r.tokens) for r in out)


def test_drain_cadence_above_lag_accumulates(oracle):
    """drain_cadence > lag: sub-cadence flushes validate predicates while
    rows ride along; tokens surface in even fewer, bigger batches and the
    streams still match."""
    rc, params, clean_toks = oracle
    srv = SedarServer(rc, dual=True)
    with hostsync.count_transfers(cross_thread=True) as st:
        out, rep = srv.serve(params, _requests(), slots=SLOTS,
                             validate_lag=4, drain_cadence=12)
    _assert_streams_equal(out, clean_toks)
    # fewer token_emit items than one 3-leaf batch per lag-4 window
    assert st.by_label.get("token_emit", 0) < 3 * (rep.steps // 4 + 2)
